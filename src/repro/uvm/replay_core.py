"""Backend-agnostic UVM replay core.

The replay stack is split into three explicit layers:

1. **Replay core (this module).**  The chunk classification /
   clock-cumsum / event-subsequence state machine that used to live inside
   ``VectorizedUVMSimulator`` (``repro.uvm.engine``), expressed as a pure
   array program over a dense page span: :func:`replay_chunked` plus the
   per-prefetcher scan/callback adapters.  It also defines the narrow
   :class:`ReplayBackend` interface and the backend registry.
2. **Backends (``repro.uvm.backends``).**  Implementations of
   :class:`ReplayBackend`: the reference per-access loop (``legacy``), the
   NumPy-chunked engine (``numpy``, bit-identical to legacy), and a
   jax_pallas multi-lane engine (``pallas``) that packs many compatible
   cells into one lane-batched kernel for accelerator-resident grid replay.
3. **Scheduler (``repro.uvm.sweep``).**  Groups pending sweep cells into
   lane batches by span/config compatibility, dispatches them to the
   selected backend, and falls back per cell to the NumPy path for
   anything unpackable — recording the backend that actually ran in every
   result row.

The timing model itself is defined by ``repro.uvm.simulator.UVMSimulator``;
every backend must reproduce it on the golden matrix
(``tests/test_uvm_golden.py``): integer counters exactly, float
accumulators to 1e-6 relative.

Replay-core state machine
-------------------------

* Residency lives in a dense per-page ``arrival``-cycle array over the
  (2 MB-aligned) page span of the trace instead of an ``OrderedDict``, so a
  whole chunk of accesses is classified with one gather.
* The per-access clock is reconstructed with ``np.cumsum`` seeded at the
  chunk-start clock.  NumPy's cumsum is the same sequential chain of float64
  additions as the legacy ``clock += cycles_per_access``, so every
  hit/late/fault comparison sees the exact same IEEE-754 values.
* Only the *event* subsequence — far-faults, accesses to in-flight pages
  (late prefetches), prefetch issues, MSHR stalls, and evictions — runs
  through a scalar step that is a line-for-line port of the legacy loop,
  driving the *real* prefetcher callbacks (``on_fault`` / ``on_migrate`` /
  ``on_evict``) so prefetcher state stays exact.
* Per-prefetcher scan adapters find the first continuous-prefetch event in a
  chunk without calling ``on_access`` per access; adapters also own the
  ``on_fault`` / ``on_migrate`` / ``on_evict`` callbacks (the tree
  prefetcher's dict is replaced by dense per-level count arrays, the block
  prefetcher's 64 KB window scan by one slice compare).
* LRU order for eviction under oversubscription is kept as monotone touch
  stamps plus a lazy min-heap, reproducing ``OrderedDict`` order exactly,
  including the reinsert-at-MRU of in-flight victims.
* Eviction is policy-pluggable (``UVMConfig.eviction``, see
  ``repro.uvm.eviction``): ``random`` keeps per-page insert-time priority
  draws in a lazy heap, ``hotcold`` a (frequency, stamp) lazy heap — all
  three reproduce the reference policy objects' victim sequence exactly.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.traces.trace import BASIC_BLOCK_PAGES, ROOT_PAGES, Trace
from repro.uvm.config import UVMConfig
from repro.uvm.eviction import (EVICTION_POLICIES, eviction_score,
                                resolve_tenancy, validate_policy)
from repro.uvm.prefetchers import (BlockPrefetcher, LearnedPrefetcher,
                                   NoPrefetcher, OraclePrefetcher, Prefetcher,
                                   TreePrefetcher)
from repro.uvm.simulator import UVMSimulator, UVMStats, _tenant_accesses

# Beyond this many pages of span the dense state arrays stop paying for
# themselves; fall back to the legacy dict-based loop.
MAX_SPAN_PAGES = 1 << 24

_INF = float("inf")


class TransientBackendFault(RuntimeError):
    """A backend failure that is expected to succeed on retry (device
    preemption, transient OOM, an injected chaos fault — see
    ``repro.uvm.faults``).

    :func:`dispatch` and the sweep's lane scheduler re-raise these instead
    of degrading down the fallback chain: degrading would permanently
    record a different ``backend`` for the cell, so a retried sweep could
    never converge byte-identically to a fault-free run.  The sweep's
    lease/retry layer (or a driver restart) retries the whole cell on the
    originally-resolved backend instead."""


# ---------------------------------------------------------------------------
# request / backend interface
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplayRequest:
    """One (trace × prefetcher × config) replay cell, backend-agnostic.

    The prefetcher object is *consumed* by the replay (its ``reset()`` is
    called and its state mutated); build a fresh one per request.
    """

    trace: Trace
    prefetcher: Prefetcher
    config: UVMConfig
    record_timeline: bool = False
    strict_checks: bool = False
    max_span_pages: int = MAX_SPAN_PAGES
    #: optional non-decreasing exclusive end indices into the access
    #: stream: the replay records the clock after the last access of each
    #: window in ``UVMStats.step_clocks`` (serving traces use decode-step
    #: boundaries here — see ``repro.offload.serve_trace``).  All
    #: backends honor it bit-identically: legacy/numpy record host-side,
    #: the pallas lanes capture the clocks in-kernel (a per-window f64
    #: carry keyed by an access->window id stream).
    step_bounds: Optional[np.ndarray] = None


class ReplayBackend:
    """Narrow contract every replay backend implements.

    * ``name`` — recorded in :attr:`UVMStats.backend` of every stats object
      the backend produces, and surfaced in sweep result rows so fallbacks
      are visible instead of silent.
    * ``can_replay(request)`` — purely structural test (prefetcher type,
      page span, feature flags); must not mutate the request.
    * ``replay(requests)`` — replay every request, order-preserving.
      Backends may batch internally (the pallas backend packs requests into
      multi-lane kernels) but must return one ``UVMStats`` per request,
      equivalent to the legacy engine within the golden tolerance
      (integer counters exact, cycles/pcie_bytes to 1e-6 relative).
    """

    name: str = "abstract"

    #: experimental backends may fail at *runtime* on exotic platforms
    #: (lowering errors, device OOM); :func:`dispatch` degrades their
    #: runtime failures to the next backend of the chain with a warning.
    #: Non-experimental backends' errors always propagate — a failure
    #: there is a bug, and silently serving legacy results would let the
    #: golden equivalence suite pass vacuously.
    experimental: bool = False

    def can_replay(self, request: ReplayRequest) -> bool:
        raise NotImplementedError

    def replay(self, requests: Sequence[ReplayRequest]) -> List[UVMStats]:
        raise NotImplementedError

    def is_native(self) -> bool:
        """True when this backend runs on the locally available hardware
        without emulation (used by ``backend="auto"`` resolution)."""
        return True


_REGISTRY: Dict[str, ReplayBackend] = {}


def register_backend(backend: ReplayBackend) -> ReplayBackend:
    _REGISTRY[backend.name] = backend
    return backend


def _ensure_registry() -> None:
    if not _REGISTRY:
        import repro.uvm.backends  # noqa: F401  (registers on import)


def get_backend(name: str) -> ReplayBackend:
    _ensure_registry()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown replay backend {name!r}; "
                         f"available: {sorted(_REGISTRY)}") from None


def available_backends() -> List[str]:
    _ensure_registry()
    return sorted(_REGISTRY)


def backend_chain(backend: str = "auto") -> List[str]:
    """Fallback order for a requested backend.

    Every chain ends in ``legacy`` (which can replay anything), so
    dispatch always succeeds; the stats record which backend actually ran.
    ``auto`` prefers the pallas lanes only where they compile natively
    (TPU, or ``REPRO_PALLAS_COMPILE=1`` on other accelerators) — anywhere
    the lanes would run in interpret mode, the NumPy engine is both exact
    and faster.
    """
    if backend == "legacy":
        return ["legacy"]
    if backend == "numpy":
        return ["numpy", "legacy"]
    if backend == "pallas":
        return ["pallas", "numpy", "legacy"]
    if backend == "auto":
        _ensure_registry()
        pallas = _REGISTRY.get("pallas")
        if pallas is not None and pallas.is_native():
            return ["pallas", "numpy", "legacy"]
        return ["numpy", "legacy"]
    raise ValueError(f"unknown replay backend {backend!r}")


def resolve_backend(request: ReplayRequest,
                    backend: str = "auto") -> ReplayBackend:
    """First backend in the fallback chain that can replay ``request``."""
    for name in backend_chain(backend):
        b = get_backend(name)
        if b.can_replay(request):
            return b
    raise AssertionError("legacy backend must accept every request")


def dispatch(request: ReplayRequest, backend: str = "auto") -> UVMStats:
    """Replay one cell on the first capable backend of the chain.

    A *runtime* failure in an :attr:`~ReplayBackend.experimental`
    non-final backend (e.g. a pallas lowering error on an exotic
    platform) degrades to the next backend of the chain with a warning
    instead of aborting the caller's whole grid — the stats still record
    the backend that actually ran.  Runtime errors of non-experimental
    backends (numpy, legacy) propagate: they indicate bugs, and silently
    serving the fallback's results would make the golden equivalence
    harness pass vacuously.
    """
    chain = [get_backend(name) for name in backend_chain(backend)]
    capable = [b for b in chain if b.can_replay(request)]
    for b in capable[:-1]:
        if not b.experimental:
            return b.replay([request])[0]
        try:
            return b.replay([request])[0]
        except TransientBackendFault:
            # retryable by contract: degrading would record a different
            # backend for the cell, breaking chaos convergence — let the
            # caller's retry layer re-run the cell on the same chain
            raise
        except Exception as e:
            import warnings
            warnings.warn(f"replay backend {b.name!r} failed at runtime "
                          f"({e!r}); falling back", RuntimeWarning)
    return capable[-1].replay([request])[0]


# ---------------------------------------------------------------------------
# shared pure helpers (both the NumPy machine and the pallas lane packer
# derive their scalar constants through these, so the float values agree
# bit-for-bit across backends)
# ---------------------------------------------------------------------------

def cycles_per_access(trace: Trace, config: UVMConfig) -> float:
    """Per-access cost in GPU cycles — the exact legacy-loop expression."""
    n = len(trace.pages)
    return (config.page_table_walk_cycles + config.dram_cycles
            + config.access_overhead_cycles
            + (trace.n_instructions / max(n, 1)) / config.issue_ipc)


def prefetcher_page_range(pf: Prefetcher) -> Optional[Tuple[int, int]]:
    """Extra page range a prefetcher can touch beyond the accessed span."""
    if type(pf) is LearnedPrefetcher:
        preds = np.asarray(pf.predicted_pages, dtype=np.int64)
        valid = preds[preds >= 0]
        if valid.size:
            return int(valid.min()), int(valid.max())
    return None


def dense_bounds(trace: Trace, prefetcher: Prefetcher) -> Tuple[int, int]:
    """2 MB-aligned ``[lo, hi)`` page bounds of the dense state arrays
    (aligned so block/tree extras always fall inside the span)."""
    pages = trace.pages
    if len(pages):
        lo, hi = int(pages.min()), int(pages.max())
    else:
        lo, hi = 0, 0
    pr = prefetcher_page_range(prefetcher)
    if pr is not None:
        lo, hi = min(lo, pr[0]), max(hi, pr[1])
    lo = (lo // ROOT_PAGES) * ROOT_PAGES
    hi = ((hi // ROOT_PAGES) + 1) * ROOT_PAGES
    return lo, hi


def span_ok(request: ReplayRequest) -> bool:
    lo, hi = dense_bounds(request.trace, request.prefetcher)
    return lo >= 0 and (hi - lo) <= request.max_span_pages


# ---------------------------------------------------------------------------
# prefetcher adapters
# ---------------------------------------------------------------------------

class _ResidencyView:
    """Read-only dict façade over the arrival array for prefetcher callbacks
    (they only ever use ``page in resident``)."""

    __slots__ = ("arrival", "lo")

    def __init__(self, arrival: np.ndarray, lo: int) -> None:
        self.arrival = arrival
        self.lo = lo

    def __contains__(self, page) -> bool:
        i = int(page) - self.lo
        return 0 <= i < self.arrival.size and self.arrival[i] != _INF


class _BaseAdapter:
    """Engine-side façade over one prefetcher.

    Adapters own *all* prefetcher interaction inside the chunked replay:
    the chunk-wise ``scan`` for the next continuous-prefetch event, and the
    ``on_fault`` / ``on_migrate`` / ``on_evict`` callbacks raised by the
    scalar event step.  The base class delegates the callbacks to the real
    prefetcher object; state-heavy prefetchers (tree) override them with
    dense-array implementations that stay bit-identical to the legacy
    object while doing O(levels) array arithmetic instead of per-page
    Python dict walks.
    """

    def __init__(self, pf: Prefetcher) -> None:
        self.pf = pf

    def scan(self, i0: int, clocks: np.ndarray, seg: np.ndarray,
             limit: int) -> Optional[int]:
        return None

    def on_access(self, i: int, p: int, clock: float) -> List[int]:
        return []

    def on_fault(self, i: int, p: int, resident):
        return self.pf.on_fault(i, p, resident)

    def on_migrate(self, pages) -> None:
        self.pf.on_migrate(list(pages))

    def on_evict(self, page: int) -> None:
        self.pf.on_evict(page)


class _NullAccessAdapter(_BaseAdapter):
    """Prefetchers whose ``on_access`` is the no-op base implementation."""


class _BlockAdapter(_BaseAdapter):
    """Vectorized :class:`BlockPrefetcher`.

    The legacy object probes all 16 pages of the faulting 64 KB basic block
    through per-page ``in resident`` calls; here the whole window is
    classified with one slice compare on the arrival array.  The demand
    page is excluded automatically — the engine inserts it before raising
    ``on_fault``, so its arrival is already finite — and the ascending
    page order of the legacy list comprehension is preserved by
    ``np.nonzero``.
    """

    _SHIFT = BASIC_BLOCK_PAGES.bit_length() - 1      # 16 pages -> 4 bits

    def __init__(self, pf: BlockPrefetcher, arrival: np.ndarray,
                 lo: int) -> None:
        super().__init__(pf)
        self.arrival = arrival
        self.lo = lo

    def on_fault(self, i: int, p: int, resident) -> np.ndarray:
        pi = int(p) - self.lo
        blk = (pi >> self._SHIFT) << self._SHIFT
        out = np.nonzero(
            self.arrival[blk:blk + BASIC_BLOCK_PAGES] == _INF)[0]
        return out + (blk + self.lo)


class _TreeAdapter(_BaseAdapter):
    """Vectorized :class:`TreePrefetcher` state.

    The legacy object keeps a ``(level, node) -> count`` dict and walks it
    per page in pure Python; with up-to-512-page escalation batches that
    makes the tree path the slowest replay.  Here node occupancy lives in
    dense per-level ``int32`` arrays over the trace's (2 MB-aligned) page
    span, so:

    * ``on_migrate`` of a k-page batch is ``LEVELS+1`` ``np.add.at`` calls
      instead of ``6k`` dict updates,
    * ``on_evict`` is ``LEVELS+1`` scalar decrements,
    * ``on_fault`` classifies the whole 2 MB root window (residency,
      pending, escalation counts) with array slices and emits the exact
      extras list — same pages, same ascending order per level — that the
      legacy dict walk produces, which the golden harness pins bit-exact.

    ``lo`` is ROOT_PAGES-aligned, so relative node indices coincide with
    the legacy object's absolute ``page // span`` nodes at every level.
    """

    LEVELS = TreePrefetcher.LEVELS
    _SHIFT = BASIC_BLOCK_PAGES.bit_length() - 1      # 16 pages -> 4 bits

    def __init__(self, pf: TreePrefetcher, arrival: np.ndarray,
                 lo: int) -> None:
        super().__init__(pf)
        self.arrival = arrival
        self.lo = lo
        span = arrival.size
        self.counts = [
            np.zeros(span >> (self._SHIFT + lv), dtype=np.int32)
            for lv in range(self.LEVELS + 1)
        ]

    def on_migrate(self, pages) -> None:
        if len(pages) == 1:
            pi = int(pages[0]) - self.lo
            for lv in range(self.LEVELS + 1):
                self.counts[lv][pi >> (self._SHIFT + lv)] += 1
            return
        rel = np.asarray(pages, dtype=np.int64) - self.lo
        for lv in range(self.LEVELS + 1):
            np.add.at(self.counts[lv], rel >> (self._SHIFT + lv), 1)

    def on_evict(self, page: int) -> None:
        pi = int(page) - self.lo
        for lv in range(self.LEVELS + 1):
            self.counts[lv][pi >> (self._SHIFT + lv)] -= 1

    def on_fault(self, i: int, p: int, resident) -> np.ndarray:
        pi = int(p) - self.lo
        root = (pi // ROOT_PAGES) * ROOT_PAGES
        rel = pi - root
        nonres = self.arrival[root:root + ROOT_PAGES] == _INF
        # 1) the faulting basic block (the demand page is already resident
        #    here — the engine inserts it before raising on_fault — so
        #    ``nonres`` excludes it exactly like the legacy checks)
        blk = (rel >> self._SHIFT) << self._SHIFT
        out = np.nonzero(nonres[blk:blk + BASIC_BLOCK_PAGES])[0] + blk
        # 2) >50% escalation walk, counting the about-to-arrive pages too
        pend = np.zeros(ROOT_PAGES, dtype=bool)
        pend[out] = True
        pend[rel] = True
        for lv in range(1, self.LEVELS + 1):
            span = BASIC_BLOCK_PAGES << lv
            nb = (rel // span) * span
            node = (root + nb) >> (self._SHIFT + lv)
            cnt = int(self.counts[lv][node]) + int(pend[nb:nb + span].sum())
            if cnt * 2 > span:
                extra = np.nonzero(nonres[nb:nb + span]
                                   & ~pend[nb:nb + span])[0] + nb
                out = np.concatenate([out, extra])
                pend[extra] = True
            else:
                break
        return out + (root + self.lo)


class _LearnedAdapter(_BaseAdapter):
    """Replays ``LearnedPrefetcher.on_access`` arithmetically.

    The gate is a serialized inference server: an access fires iff
    ``clock >= next_free`` and then sets ``next_free = clock + extra``.
    Within a chunk the exact clocks are known, so firing positions are a
    deterministic chain; only a firing whose top-1 prediction is valid,
    different from the demand page, and non-resident is an *event*.
    """

    def __init__(self, pf: LearnedPrefetcher, arrival: np.ndarray, lo: int,
                 cpa: float) -> None:
        self.pf = pf
        self.preds = np.asarray(pf.predicted_pages, dtype=np.int64)
        self.extra = float(pf.extra_latency_cycles)
        self.arrival = arrival
        self.lo = lo
        self.cpa = cpa
        self.nf = float(pf._next_free)  # 0.0 after reset()

    def scan(self, i0, clocks, seg, limit) -> Optional[int]:
        if limit <= 0:
            return None
        cl = clocks[:limit]
        j0 = 0 if self.nf <= cl[0] else int(
            np.searchsorted(cl, self.nf, side="left"))
        if j0 >= limit:
            return None                      # gate closed for the whole prefix
        if self.extra <= self.cpa:
            # once open, the gate fires on every access (extra <= 1/rate)
            pr = self.preds[i0 + j0:i0 + limit]
            abspg = seg[j0:limit] + self.lo
            valid = (pr >= 0) & (pr != abspg)
            act = np.zeros(limit - j0, dtype=bool)
            if valid.any():
                act[valid] = ~np.isfinite(self.arrival[pr[valid] - self.lo])
            if act.any():
                c = j0 + int(np.argmax(act))
                if c > j0:                   # commit the no-op firings
                    self.nf = float(cl[c - 1]) + self.extra
                return c
            self.nf = float(cl[limit - 1]) + self.extra
            return None
        # sparse gating (extra > cycles/access): firings step by a nearly
        # constant stride ceil(extra/cpa) — generate the candidate chain at
        # that stride and verify it with vector comparisons (the chunk clocks
        # are an exact fp chain, so each step can wobble by at most one)
        k_star = max(1, int(np.ceil(self.extra / self.cpa)))
        poss = np.arange(j0, limit, k_star)
        thr = cl[poss] + self.extra          # nf value set by each firing
        chain_ok = True
        if poss.size > 1:
            nxt = poss[1:]
            chain_ok = bool(np.all(cl[nxt] >= thr[:-1])
                            and np.all(cl[nxt - 1] < thr[:-1]))
        if chain_ok and poss[-1] + k_star - 1 < limit:
            # tail: no extra firing may sneak in before the chunk ends
            chain_ok = bool(cl[poss[-1] + k_star - 1] < thr[-1])
        if chain_ok:
            prs = self.preds[i0 + poss]
            abspg = seg[poss] + self.lo
            valid = (prs >= 0) & (prs != abspg)
            act = np.zeros(poss.size, dtype=bool)
            if valid.any():
                act[valid] = ~np.isfinite(self.arrival[prs[valid] - self.lo])
            if act.any():
                mi = int(np.argmax(act))
                if mi > 0:                   # commit the no-op firings
                    self.nf = float(thr[mi - 1])
                return int(poss[mi])
            self.nf = float(thr[-1])
            return None
        # fp wobble broke the constant stride: exact scalar walk
        j = j0
        while j < limit:
            pred = int(self.preds[i0 + j])
            if (pred >= 0 and pred != int(seg[j]) + self.lo
                    and self.arrival[pred - self.lo] == _INF):
                return j                     # on_access at j handles the rest
            self.nf = float(cl[j]) + self.extra
            j = int(np.searchsorted(cl, self.nf, side="left"))
        return None

    def on_access(self, i, p, clock) -> List[int]:
        # line-for-line port of LearnedPrefetcher.on_access (shadowed gate)
        if clock < self.nf:
            return []
        self.nf = clock + self.extra
        pred = int(self.preds[i])
        if (pred >= 0 and pred != p
                and self.arrival[pred - self.lo] == _INF):
            return [pred]
        return []


class _OracleAdapter(_BaseAdapter):
    """Oracle lookahead windows checked with one cumulative sum per chunk.

    ``pf.pos`` is a pure function of the access index (it only advances), so
    the real object self-heals when ``on_access`` finally runs at an event.
    """

    def __init__(self, pf: OraclePrefetcher, arrival: np.ndarray, lo: int,
                 view: _ResidencyView) -> None:
        self.pf = pf
        self.arrival = arrival
        self.lo = lo
        self.view = view

    def scan(self, i0, clocks, seg, limit) -> Optional[int]:
        if limit <= 0:
            return None
        ft_idx = self.pf.ft_index
        ft_pages = self.pf.ft_pages
        look = self.pf.lookahead
        pos = np.searchsorted(ft_idx, np.arange(i0, i0 + limit), side="right")
        a = int(pos[0])
        b = min(int(pos[-1]) + look, len(ft_pages))
        if a >= b:
            return None
        nr = ~np.isfinite(self.arrival[ft_pages[a:b].astype(np.int64) - self.lo])
        cs = np.concatenate(([0], np.cumsum(nr)))
        start = pos - a
        end = np.minimum(pos + look, len(ft_pages)) - a
        act = (cs[end] - cs[start]) > 0
        if act.any():
            return int(np.argmax(act))
        return None

    def on_access(self, i, p, clock) -> List[int]:
        return self.pf.on_access(i, p, self.view, clock)


#: exact prefetcher types with a scan adapter and a known page extent (all
#: pages they can emit fit the 2MB-aligned span of accesses + predictions).
#: Unknown subclasses fall back to the legacy engine wholesale — they could
#: prefetch pages outside the dense state arrays.
SUPPORTED_PREFETCHERS = (NoPrefetcher, BlockPrefetcher, TreePrefetcher,
                         LearnedPrefetcher, OraclePrefetcher)


def _make_adapter(pf: Prefetcher, arrival: np.ndarray, lo: int,
                  view: _ResidencyView, cpa: float):
    t = type(pf)
    if t is NoPrefetcher:
        return _NullAccessAdapter(pf)
    if t is BlockPrefetcher:
        return _BlockAdapter(pf, arrival, lo)
    if t is TreePrefetcher:
        return _TreeAdapter(pf, arrival, lo)
    if t is LearnedPrefetcher:
        return _LearnedAdapter(pf, arrival, lo, cpa)
    if t is OraclePrefetcher:
        return _OracleAdapter(pf, arrival, lo, view)
    raise AssertionError(f"unsupported prefetcher type {t!r}")


# ---------------------------------------------------------------------------
# the chunked replay state machine (NumPy array program)
# ---------------------------------------------------------------------------

def replay_chunked(request: ReplayRequest) -> UVMStats:
    """Replay one request with the NumPy-chunked state machine.

    Bit-identical to ``UVMSimulator`` for every supported prefetcher type;
    callers are expected to have checked :data:`SUPPORTED_PREFETCHERS` and
    :func:`span_ok` (the NumPy backend does) — unsupported requests raise.
    """
    trace, prefetcher, cfg = (request.trace, request.prefetcher,
                              request.config)
    if type(prefetcher) not in SUPPORTED_PREFETCHERS:
        raise ValueError(f"unsupported prefetcher {type(prefetcher)!r}; "
                         "route through the legacy backend")
    prefetcher.reset()
    pages = np.ascontiguousarray(trace.pages, dtype=np.int64)
    n = len(pages)
    cpa = cycles_per_access(trace, cfg)

    # --- dense page-state span (2MB-aligned so block/tree extras fit)
    lo, hi = dense_bounds(trace, prefetcher)
    span = hi - lo
    if lo < 0 or span > request.max_span_pages:
        raise ValueError(f"page span [{lo}, {hi}) too large for dense "
                         "replay; route through the legacy backend")

    arrival = np.full(span, _INF, dtype=np.float64)
    pfu = np.zeros(span, dtype=bool)      # prefetched-but-unused flags
    pg = pages - lo
    cap = cfg.device_pages
    track_lru = cap is not None
    policy = validate_policy(cfg.eviction)
    hotcold = policy == "hotcold"
    randomp = policy == "random"
    stamp = np.zeros(span, dtype=np.int64) if track_lru else None
    # hotcold: per-page touches since migration; random: per-page
    # insert-time priority draws (lazy heaps over both, like the LRU one)
    freq = np.zeros(span, dtype=np.int64) if (track_lru and hotcold) else None
    prio = np.zeros(span, dtype=np.int64) if (track_lru and randomp) else None
    # multi-tenant traces (repro.traces.interleave): per-tenant hit
    # counters always; per-tenant residency counters + tenant-masked
    # victim selection only under hard quotas (Tenancy.split).  The lazy
    # heaps shard by tenant at insert time — without a split everything
    # lands in shard 0, so the single-tenant pop order is untouched.
    tenancy = resolve_tenancy(trace, cfg)
    split = track_lru and tenancy is not None and tenancy.split
    bnd = (tenancy.boundary - lo) if tenancy is not None else 0
    rc = [0, 0]                            # per-tenant resident counts
    th = [0, 0]                            # per-tenant hits
    lru_heaps: List[List[Tuple[int, int]]] = [[], []]
    hc_heaps: List[List[Tuple[int, int, int]]] = [[], []]
    rand_heaps: List[List[Tuple[int, int]]] = [[], []]
    counter = 0                            # monotone LRU touch counter
    resident_count = 0

    def _shard(pi: int) -> int:
        return 1 if (split and pi >= bnd) else 0

    clock = 0.0
    pcie_free = 0.0
    outstanding: List[float] = []
    hits = late = faults = 0
    prefetch_issued = prefetch_used = 0
    pages_migrated = pages_evicted = 0
    pcie_bytes = 0.0
    timeline: List[Tuple[float, float]] = []

    page_tx = cfg.page_transfer_cycles
    ff = cfg.far_fault_cycles
    mshr = cfg.mshr_entries
    record = request.record_timeline
    strict = request.strict_checks

    # step-window clock capture (ReplayRequest.step_bounds): windows are
    # marked as the replay crosses their exclusive end index — in the
    # scalar event step and in the vector-hit path, where the chunk's
    # exact cumsum clocks are available per access
    if request.step_bounds is not None:
        sb = np.asarray(request.step_bounds, dtype=np.int64)
        if sb.size and (np.any(np.diff(sb) < 0) or sb[-1] > n):
            raise ValueError("step_bounds must be non-decreasing end "
                             "indices <= n_accesses")
        step_clocks = np.zeros(sb.size, dtype=np.float64)
    else:
        sb = None
        step_clocks = None
    sp = 0
    while sb is not None and sp < sb.size and sb[sp] == 0:
        sp += 1                      # leading empty windows end at clock 0.0

    view = _ResidencyView(arrival, lo)
    adapter = _make_adapter(prefetcher, arrival, lo, view, cpa)

    # --- scalar event step: line-for-line port of UVMSimulator.run ----
    def _insert(pi: int, t: float) -> None:
        """Page becomes resident/in-flight at MRU position."""
        nonlocal resident_count, counter
        if arrival[pi] == _INF:
            resident_count += 1
            if split:
                rc[1 if pi >= bnd else 0] += 1
            if track_lru:
                stamp[pi] = counter
                sh = _shard(pi)
                if hotcold:
                    freq[pi] = 0
                    heapq.heappush(hc_heaps[sh], (0, counter, pi))
                elif randomp:
                    pr = eviction_score(pi + lo, counter)
                    prio[pi] = pr
                    heapq.heappush(rand_heaps[sh], (pr, pi))
                else:
                    heapq.heappush(lru_heaps[sh], (counter, pi))
            counter += 1
        arrival[pi] = t                    # overwrite keeps LRU position

    def _retouch(pi: int) -> None:
        """move_to_end: stale heap entries self-heal at pop time."""
        nonlocal counter
        if track_lru:
            stamp[pi] = counter
            if hotcold:
                freq[pi] += 1
        counter += 1

    def _schedule(extras, batch: bool) -> None:
        nonlocal pcie_free, pages_migrated, pcie_bytes, prefetch_issued
        nonlocal resident_count, counter
        k = len(extras)
        ex_ready = (clock + cfg.prefetch_overhead_cycles
                    + prefetcher.extra_latency_cycles)
        ex_start = max(pcie_free, ex_ready)
        end = ex_start + k * page_tx
        if batch and not track_lru and k > 1:
            # batch DMA without LRU tracking: every page arrives at
            # batch completion, extras are unique and non-resident by
            # the supported prefetchers' contract — apply in one shot
            idx = np.asarray(extras, dtype=np.int64) - lo
            ex_arr = end + cfg.pcie_latency_cycles
            if strict:
                assert not np.isfinite(arrival[idx]).any(), \
                    "prefetch batch contains resident pages"
            arrival[idx] = ex_arr
            pfu[idx] = True
            resident_count += k
            counter += k
            pages_migrated += k
            pcie_bytes += k * cfg.page_size
            if record:
                timeline.extend([(ex_arr, float(cfg.page_size))] * k)
        else:
            t = ex_start
            for q in extras:
                t += page_tx
                ex_arr = (end if batch else t) + cfg.pcie_latency_cycles
                _insert(int(q) - lo, ex_arr)
                pfu[int(q) - lo] = True
                pages_migrated += 1
                pcie_bytes += cfg.page_size
                if record:
                    timeline.append((ex_arr, float(cfg.page_size)))
        pcie_free = end
        prefetch_issued += k
        adapter.on_migrate(extras)

    def _select_victim(sh: int) -> int:
        """Policy victim from heap shard ``sh`` (the over-quota tenant, or
        0 without a split): lazy-heap min of (stamp) / (prio, page) /
        (freq, stamp) — stale entries self-heal at pop time.  The LRU
        branch pops its entry (the spare path re-pushes); the other
        policies peek (their stale tops heal on the next selection)."""
        if hotcold:
            heap = hc_heaps[sh]
            while True:
                f, s, vi = heap[0]
                if arrival[vi] == _INF:
                    heapq.heappop(heap)        # evicted since: stale
                    continue
                if freq[vi] != f or stamp[vi] != s:
                    heapq.heapreplace(heap,
                                      (int(freq[vi]), int(stamp[vi]), vi))
                    continue
                return vi
        if randomp:
            heap = rand_heaps[sh]
            while True:
                pr, vi = heap[0]
                if arrival[vi] == _INF or prio[vi] != pr:
                    heapq.heappop(heap)        # evicted or re-drawn
                    continue
                return vi
        heap = lru_heaps[sh]
        while True:                        # lazy-heap pop of the true LRU
            s, vi = heapq.heappop(heap)
            if arrival[vi] == _INF:
                continue                   # evicted since: stale entry
            if stamp[vi] != s:
                heapq.heappush(heap, (int(stamp[vi]), vi))
                continue
            return vi

    def _over() -> bool:
        """Eviction pressure: over total capacity, or (quota split) any
        tenant over its current allowance."""
        if not track_lru:
            return False
        if split:
            a0, a1 = tenancy.allowed(rc[0], rc[1])
            return rc[0] > a0 or rc[1] > a1
        return resident_count > cap

    def _evict_loop() -> None:
        nonlocal resident_count, pages_evicted, pcie_bytes, pcie_free
        nonlocal counter
        while True:
            if split:
                # per-tenant quotas: trim whichever tenant is over its
                # allowance, tenant 0 first — same order as the legacy
                # loop and the pallas kernel
                a0, a1 = tenancy.allowed(rc[0], rc[1])
                if rc[0] > a0:
                    u = 0
                elif rc[1] > a1:
                    u = 1
                else:
                    break
            else:
                if resident_count <= cap:
                    break
                u = 0
            vi = _select_victim(u)
            v_arr = float(arrival[vi])
            if v_arr > clock:
                # never evict in-flight pages; retouch at MRU (the
                # legacy loop's reinsert) — random keeps its insert-time
                # priority, so only the shared counter ticks for it
                stamp[vi] = counter
                if hotcold:
                    freq[vi] += 1
                elif not randomp:
                    heapq.heappush(lru_heaps[u], (counter, vi))
                counter += 1
                break
            if strict:
                assert v_arr <= clock, "evicted an in-flight page"
            arrival[vi] = _INF
            resident_count -= 1
            if split:
                rc[u] -= 1
            pfu[vi] = False
            adapter.on_evict(vi + lo)
            pages_evicted += 1
            # writeback traffic (assume half the evictions dirty)
            if pages_evicted % 2 == 0:
                pcie_bytes += cfg.page_size
                pcie_free += page_tx

    def _step(i: int) -> None:
        nonlocal clock, hits, late, faults, prefetch_used
        nonlocal pcie_free, pages_migrated, pcie_bytes, sp
        prev = clock
        clock += cpa
        p = int(pages[i])
        pi = p - lo
        a = arrival[pi]
        if a != _INF:
            if a <= clock:
                hits += 1
                if tenancy is not None:
                    th[1 if pi >= bnd else 0] += 1
            else:
                late += 1
                heapq.heappush(outstanding, float(a))
            if pfu[pi]:
                prefetch_used += 1
                pfu[pi] = False
            _retouch(pi)
        else:
            faults += 1
            ready = ((clock // ff) + 2.0) * ff + cfg.page_table_walk_cycles
            start = max(ready, pcie_free)
            arr_v = start + cfg.pcie_latency_cycles + page_tx
            pcie_free = start + page_tx
            _insert(pi, arr_v)
            pages_migrated += 1
            pcie_bytes += cfg.page_size
            if record:
                timeline.append((arr_v, float(cfg.page_size)))
            heapq.heappush(outstanding, arr_v)
            adapter.on_migrate([p])
            extras = adapter.on_fault(i, p, view)
            if len(extras):
                _schedule(extras, True)
        extras = adapter.on_access(i, p, clock)
        if len(extras):
            _schedule(extras, False)
        while len(outstanding) > mshr:
            clock = max(clock, heapq.heappop(outstanding))
        if track_lru:
            _evict_loop()
        if strict:
            assert clock >= prev, "clock moved backwards"
        if sb is not None:
            # the step for access i completes windows ending at i+1
            # (duplicate bounds = empty windows repeating this clock)
            while sp < sb.size and sb[sp] <= i + 1:
                step_clocks[sp] = clock
                sp += 1

    # --- chunked main loop -------------------------------------------
    i = 0
    chunk = 512
    dense = 0      # consecutive chunk scans that hit an event at offset 0
    while i < n:
        if _over():
            # eviction dribble: legacy retries the victim pop every
            # access (total cap, or any tenant over its quota allowance)
            _step(i)
            i += 1
            continue
        if dense >= 4:
            # event storm: chunk scans are pure overhead — run scalar
            # until a hit run resumes (the step itself is always exact)
            streak = 0
            while i < n and streak < 24:
                a = arrival[pg[i]]
                plain = a != _INF and a <= clock + cpa
                _step(i)
                i += 1
                streak = streak + 1 if plain else 0
                if _over():
                    break
            dense = 0
            chunk = 64
            continue

        k = min(chunk, n - i)
        seg = pg[i:i + k]
        incr = np.full(k, cpa)
        incr[0] = clock + cpa
        clocks = np.cumsum(incr)           # exact: same fp chain as +=
        arr_seg = arrival[seg]
        bad = (arr_seg == _INF) | (arr_seg > clocks)
        fl = int(np.argmax(bad)) if bad.any() else k
        cand = adapter.scan(i, clocks, seg, fl)
        event = fl if cand is None else cand

        if event > 0:                      # vector-apply the pure hits
            h = event
            hseg = seg[:h]
            hits += h
            if tenancy is not None:
                n1 = int((hseg >= bnd).sum())
                th[1] += n1
                th[0] += h - n1
            m = pfu[hseg]
            if m.any():
                # first hit on each prefetched-unused page consumes it
                uniq = np.unique(hseg[m])
                prefetch_used += int(uniq.size)
                pfu[uniq] = False
            if track_lru:
                np.maximum.at(stamp, hseg,
                              counter + np.arange(h, dtype=np.int64))
                if hotcold:
                    np.add.at(freq, hseg, 1)
            counter += h
            clock = float(clocks[h - 1])
            if sb is not None:
                # windows ending inside the pure-hit run close at the
                # exact cumsum clock of their last access — the same
                # fp value the legacy += chain produces there
                while sp < sb.size and sb[sp] <= i + h:
                    step_clocks[sp] = float(clocks[sb[sp] - 1 - i])
                    sp += 1
            i += h
            dense = 0
        if event < k and i < n:
            _step(i)
            i += 1
            if event == 0:
                dense += 1
            chunk = max(32, min(2 * max(event, 1), 65536))
        else:
            chunk = min(chunk * 2, 65536)

    # drain: all outstanding stalls resolve
    while outstanding:
        clock = max(clock, heapq.heappop(outstanding))

    return UVMStats(
        name=trace.name,
        prefetcher=prefetcher.name,
        n_accesses=n,
        n_instructions=trace.n_instructions,
        cycles=clock,
        hits=hits,
        late=late,
        faults=faults,
        prefetch_issued=prefetch_issued,
        prefetch_used=prefetch_used,
        pages_migrated=pages_migrated,
        pages_evicted=pages_evicted,
        pcie_bytes=pcie_bytes,
        zero_copy_bytes=0.0,
        timeline=np.asarray(timeline) if record else None,
        eviction=cfg.eviction,
        step_clocks=step_clocks,
        tenant_hits=(th[0], th[1]) if tenancy is not None else None,
        tenant_accesses=_tenant_accesses(pages, tenancy),
    )


def run_legacy(request: ReplayRequest) -> UVMStats:
    """Replay one request on the reference per-access loop."""
    return UVMSimulator(request.config, request.record_timeline).run(
        request.trace, request.prefetcher,
        step_bounds=request.step_bounds)
