"""Evaluation metrics (paper §7.6) + serving SLO percentiles."""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np


def unity(accuracy: float, coverage: float, hit_rate: float) -> float:
    """Unity := cbrt(Accuracy * Coverage * Page_hit_rate); 1.0 is perfect."""
    return float(np.cbrt(accuracy * coverage * hit_rate))


def geomean(xs: Iterable[float]) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))


def sorted_percentiles(sorted_samples: np.ndarray,
                       qs: Sequence[float]) -> np.ndarray:
    """Percentiles of an *already sorted* 1-D float64 array.

    Bit-identical to ``np.percentile(a, q)`` (the default ``linear``
    method, including its symmetric lerp: ``a + (b-a)*t`` below the
    midpoint, ``b - (b-a)*(1-t)`` at or above it) but shares one sort
    across every requested percentile instead of re-partitioning the
    samples per call — the serve lanes ask for six percentiles over the
    same clock deltas on every row."""
    a = np.asarray(sorted_samples, dtype=np.float64)
    if a.ndim != 1:
        raise ValueError(f"expected a 1-D sample vector, got shape {a.shape}")
    if a.size == 0:
        raise ValueError("cannot take percentiles of an empty sample set")
    if not np.isfinite(a).all():
        # np.sort parks NaN at the tail, so a NaN-poisoned clock stream
        # would flow straight into the high percentiles (and p99 ordering
        # checks pass vacuously: NaN comparisons are all False) — reject
        # loudly instead of laundering a broken replay into SLO columns
        raise ValueError(
            f"non-finite latency samples "
            f"({int((~np.isfinite(a)).sum())} of {a.size}): percentiles "
            "over NaN/inf would silently corrupt the SLO columns")
    q = np.asarray(qs, dtype=np.float64)
    if q.size and (q.min() < 0.0 or q.max() > 100.0):
        raise ValueError("percentiles must lie in [0, 100]")
    virt = q / 100.0 * (a.size - 1)
    lo = np.floor(virt).astype(np.int64)
    hi = np.minimum(lo + 1, a.size - 1)
    t = virt - lo
    x, y = a[lo], a[hi]
    diff = y - x
    return np.where(t < 0.5, x + diff * t, y - diff * (1.0 - t))


def slo_percentiles(samples: Sequence[float], prefix: str,
                    qs: Tuple[int, ...] = (50, 95, 99)
                    ) -> Dict[str, Optional[float]]:
    """Latency samples -> SLO percentile columns
    (``{"<prefix>_p50_us": ..., "<prefix>_p95_us": ..., ...}``); an empty
    sample set yields None values so result rows stay schema-stable.
    One shared sort feeds every percentile (:func:`sorted_percentiles`)."""
    arr = np.asarray(samples, dtype=np.float64)
    if not arr.size:
        return {f"{prefix}_p{q}_us": None for q in qs}
    vals = sorted_percentiles(np.sort(arr), qs)
    return {f"{prefix}_p{q}_us": float(v) for q, v in zip(qs, vals)}


def pcie_gbs_timeline(timeline: np.ndarray, core_mhz: float,
                      window_cycles: float = 10_000.0) -> np.ndarray:
    """(cycle, bytes) transfer events -> (window_center_cycle, GB/s) rows.

    Events may arrive in any order (binning is order-independent), but
    every cycle stamp must be finite and non-negative: a negative stamp
    floor-divides to a negative window index, which ``np.add.at`` wraps
    to the *tail* window — the bandwidth spike lands on the wrong end of
    the plot with no error.  Reject instead of mis-binning."""
    if timeline is None or len(timeline) == 0:
        return np.zeros((0, 2))
    if window_cycles <= 0:
        raise ValueError(f"window_cycles must be positive: {window_cycles}")
    t = timeline[:, 0]
    b = timeline[:, 1]
    if not np.isfinite(t).all() or (t < 0).any():
        bad = int(((~np.isfinite(t)) | (t < 0)).sum())
        raise ValueError(
            f"invalid PCIe timeline: {bad} of {t.size} cycle stamps are "
            "negative or non-finite (negative stamps would wrap into the "
            "tail window)")
    n_win = int(t.max() // window_cycles) + 1
    idx = (t // window_cycles).astype(np.int64)
    acc = np.zeros(n_win)
    np.add.at(acc, idx, b)
    secs = window_cycles / (core_mhz * 1e6)
    centers = (np.arange(n_win) + 0.5) * window_cycles
    return np.stack([centers, acc / secs / 1e9], axis=1)
