"""Evaluation metrics (paper §7.6) + serving SLO percentiles."""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np


def unity(accuracy: float, coverage: float, hit_rate: float) -> float:
    """Unity := cbrt(Accuracy * Coverage * Page_hit_rate); 1.0 is perfect."""
    return float(np.cbrt(accuracy * coverage * hit_rate))


def geomean(xs: Iterable[float]) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))


def slo_percentiles(samples: Sequence[float], prefix: str,
                    qs: Tuple[int, ...] = (50, 95, 99)
                    ) -> Dict[str, Optional[float]]:
    """Latency samples -> SLO percentile columns
    (``{"<prefix>_p50_us": ..., "<prefix>_p95_us": ..., ...}``); an empty
    sample set yields None values so result rows stay schema-stable."""
    arr = np.asarray(samples, dtype=np.float64)
    return {f"{prefix}_p{q}_us":
            (float(np.percentile(arr, q)) if arr.size else None)
            for q in qs}


def pcie_gbs_timeline(timeline: np.ndarray, core_mhz: float,
                      window_cycles: float = 10_000.0) -> np.ndarray:
    """(cycle, bytes) transfer events -> (window_center_cycle, GB/s) rows."""
    if timeline is None or len(timeline) == 0:
        return np.zeros((0, 2))
    t = timeline[:, 0]
    b = timeline[:, 1]
    n_win = int(t.max() // window_cycles) + 1
    idx = (t // window_cycles).astype(np.int64)
    acc = np.zeros(n_win)
    np.add.at(acc, idx, b)
    secs = window_cycles / (core_mhz * 1e6)
    centers = (np.arange(n_win) + 0.5) * window_cycles
    return np.stack([centers, acc / secs / 1e9], axis=1)
