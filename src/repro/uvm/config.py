"""Simulator configuration — constants from the paper's Table 9."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class UVMConfig:
    """GPGPU-Sim UVMSmart configuration (paper Table 9), GTX 1080 Ti-like."""

    core_mhz: float = 1481.0
    n_sms: int = 28
    page_size: int = 4096

    # latencies (GPU core cycles unless noted)
    page_table_walk_cycles: int = 100
    dram_cycles: int = 100
    zero_copy_cycles: int = 200
    pcie_latency_cycles: int = 100
    far_fault_us: float = 45.0            # host-side fault service

    # PCI-e 3.0 x16: 8 GT/s per lane per direction, 128b/130b -> ~15.75 GB/s
    pcie_gb_s: float = 15.75

    # device memory capacity in pages; None = never oversubscribed
    device_pages: int | None = None

    # per-tenant hard quotas (pages) for multi-tenant interleaved traces
    # (repro.traces.interleave): a (q0, q1) tuple partitions device_pages
    # into per-tenant capacity, with device_pages - q0 - q1 left as a
    # shared spill pool either tenant may borrow while the other is under
    # its quota.  None (default) = shared capacity: tenants contend for
    # the whole device exactly like the single-tenant model.  Requires
    # device_pages and a multi-tenant trace; see repro.uvm.eviction
    # .resolve_tenancy for validation and the spill arithmetic.
    tenant_pages: tuple | None = None

    # eviction policy under oversubscription: "lru" (default, the
    # historical behavior), "random" (counter-based deterministic PRNG
    # replacement), or "hotcold" (access-frequency cold-first, arXiv
    # 2204.02974).  See repro.uvm.eviction.
    eviction: str = "lru"

    # far-fault MSHR entries: outstanding faults the GPU can hide behind
    # fine-grained multithreading before the SMs fully stall
    mshr_entries: int = 64

    # aggregate instruction issue throughput (inst / core cycle) used for the
    # IPC proxy.  28 SMs x 128 cores, but memory-intensive kernels sustain a
    # small fraction of peak; this constant cancels in normalized IPC.
    issue_ipc: float = 512.0

    # fixed cost per coalesced GMMU request beyond walk+DRAM (queueing,
    # multi-warp round trips).  Calibrated so the GMMU request rate is a
    # few/us — fast enough that bulk-DMA prefetch batches (the tree
    # prefetcher's granularity) are frequently still in flight when their
    # pages are demanded, and that a 1 us-per-prediction model keeps up
    # with most requests while a 10 us one cannot (paper Fig 10).
    access_overhead_cycles: float = 1200.0

    # driver-initiated prefetch overhead (scheduling a migration without a
    # GPU fault: no 45us fault service, just runtime work + doorbell)
    prefetch_overhead_cycles: float = 600.0

    # learned-predictor inference overhead per prediction, microseconds
    prediction_overhead_us: float = 1.0

    @property
    def cycles_per_us(self) -> float:
        return self.core_mhz  # 1481 MHz -> 1481 cycles / us

    def us_from_cycles(self, cycles):
        """GPU core cycles -> microseconds (scalar or ndarray) — the
        conversion behind the serving SLO latency columns
        (``repro.offload.serve_trace.serve_latency_columns``)."""
        return cycles / self.cycles_per_us

    @property
    def far_fault_cycles(self) -> float:
        return self.far_fault_us * self.cycles_per_us

    @property
    def pcie_bytes_per_cycle(self) -> float:
        return self.pcie_gb_s * 1e9 / (self.core_mhz * 1e6)

    @property
    def page_transfer_cycles(self) -> float:
        return self.page_size / self.pcie_bytes_per_cycle

    @property
    def prediction_overhead_cycles(self) -> float:
        return self.prediction_overhead_us * self.cycles_per_us
