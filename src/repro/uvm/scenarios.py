"""Declarative oversubscription scenario matrix for the UVM sweep.

The paper's headline numbers are measured under device-memory
oversubscription, where the eviction policy interacts with prefetch
aggressiveness (arXiv 2204.02974), and UVMBench (arXiv 2007.09822) argues
UVM results only generalize when swept across a full benchmark suite.
This module turns that into a first-class, validated registry of named
**scenarios**: each one expands to a (benchmark × oversubscription ratio ×
eviction policy × prefetcher) grid of :class:`~repro.uvm.sweep.SweepCell`
cells, every cell stamped with the scenario name so result rows are
self-describing and resumable per scenario.

Built-ins:

* ``oversub-full`` — all 11 paper benchmarks × capacity ratios
  (1.5/1.0/0.75/0.5 × working set) × all eviction policies
  (lru/random/hotcold) × all five prefetcher families.  The full matrix
  behind ``python -m repro.uvm.sweep --scenario oversub-full``.
* ``oversub-smoke`` — 2 small benchmarks × 2 oversubscribed ratios × all
  policies × (none, tree), at scale 0.25 (< 100k total accesses): the CI
  smoke that replays the whole matrix through the pallas lanes in
  interpret mode (``scripts/ci_check.sh``).
* ``serve-full`` / ``serve-smoke`` — the serving-traffic family:
  PagedKVStore fault streams (continuous-batching decode, multi-tenant
  mixes, bursty open-loop arrivals at several request rates; see
  ``repro.offload.serve_trace``) replayed as first-class traces, with
  p50/p95/p99 decode-latency and TTFT columns on every row.  Serve
  scenarios pin ``window=None`` — validation enforces it.
* ``mt-full`` / ``mt-smoke`` — the multi-tenant interference family:
  two benchmarks interleaved into ONE access stream
  (``repro.traces.interleave``) contending for a single device, swept
  across capacity splits (shared pool vs. hard per-tenant quotas with
  an optional spill pool); rows carry per-tenant hit rates and the
  interference slowdown vs. each tenant's solo replay.
* ``chaos-smoke`` — an 8-cell grid sized for the chaos convergence
  harness (``python -m repro.uvm.faults``): the CI check replays it
  fault-free and under a bounded kill+corrupt+raise fault plan and
  requires byte-identical rows.
* ``transformer-smoke`` — 4 learned cells across the ``simplified`` and
  reference ``transformer`` predictor families under ``adaptive``
  eviction: the CI check that rows record ``model_family`` and a
  concretely resolved ``eviction`` (never the ``adaptive`` literal).

Scenarios may also sweep the ``model_families`` axis
(``repro.core.families.MODEL_FAMILIES``) and request the ``adaptive``
eviction pseudo-policy (``repro.uvm.adaptive``), which the sweep
resolves per cell at prepare time.

Usage::

    from repro.uvm.scenarios import expand_scenario
    from repro.uvm.sweep import run_sweep
    cells = expand_scenario("oversub-full", backend="pallas")
    rows = run_sweep(cells, out_dir="results/oversub", workers=8)

Scenarios are plain frozen dataclasses: :meth:`Scenario.to_dict` /
:func:`scenario_from_dict` round-trip them through JSON so grids can be
shipped to other hosts, and :meth:`Scenario.validate` pins every axis
value against the live registries (benchmark generators, eviction
policies, prefetcher vocabulary) so a typo fails at registration, not
mid-sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.families import MODEL_FAMILIES  # jax-free config layer
from repro.uvm.adaptive import ADAPTIVE_POLICY
from repro.uvm.eviction import EVICTION_POLICIES
from repro.uvm.sweep import PREFETCHERS, SweepCell

#: the paper's full benchmark suite (Table 10) — kept in sync with
#: ``repro.traces.generators.BENCHMARKS`` by :meth:`Scenario.validate`
PAPER_BENCHMARKS = (
    "AddVectors", "ATAX", "Backprop", "BICG", "Hotspot", "MVT", "NW",
    "Pathfinder", "Srad-v2", "StreamTriad", "2DCONV",
)

#: capacity ratios (device memory / working set) of the full matrix:
#: 1.5 = comfortably undersubscribed control, 1.0 = exact fit, 0.75/0.5 =
#: the oversubscription regimes of arXiv 2204.02974
DEFAULT_RATIOS = (1.5, 1.0, 0.75, 0.5)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named (benchmark × ratio × eviction × prefetcher) matrix."""

    name: str
    description: str
    benches: Tuple[str, ...]
    ratios: Tuple[float, ...]                 # device_frac per cell
    evictions: Tuple[str, ...] = EVICTION_POLICIES
    prefetchers: Tuple[str, ...] = PREFETCHERS
    scale: float = 1.0
    window: Optional[float] = 0.6
    seeds: Tuple[int, ...] = (0,)
    prediction_us: float = 1.0
    service_steps: int = 150
    # predictor families for the learned prefetcher cells; non-learned
    # cells still expand per family (the axis is part of the cell key)
    # so keep this ("simplified",) unless the scenario compares families
    model_families: Tuple[str, ...] = ("simplified",)
    # multi-tenant capacity splits ("shared" | "f0/f1" quota fractions of
    # device_pages, see repro.uvm.sweep.parse_capacity_split); quota
    # splits require every bench to be an interleaved pair ("A+B")
    capacity_splits: Tuple[Optional[str], ...] = (None,)

    # ------------------------------------------------------------------
    def validate(self) -> "Scenario":
        """Check every axis against the live registries; returns self."""
        from repro.offload.serve_trace import is_serve_bench
        from repro.traces.generators import BENCHMARKS
        from repro.traces.interleave import is_mt_bench
        from repro.uvm.sweep import parse_capacity_split

        if not self.name or "/" in self.name:
            raise ValueError(f"bad scenario name {self.name!r}")
        if not self.benches:
            raise ValueError(f"scenario {self.name!r}: empty benches")
        bad = [b for b in self.benches
               if b not in BENCHMARKS and not is_serve_bench(b)
               and not is_mt_bench(b)]
        if bad:
            raise ValueError(
                f"scenario {self.name!r}: unknown benches {bad}; choose "
                f"from {sorted(BENCHMARKS)}, multi-tenant pairs like "
                "'ATAX+Pathfinder', or serve workloads (see "
                "repro.offload.serve_trace.SERVE_WORKLOADS, rate variants "
                "like 'ServeBursty@r128' accepted)")
        if not self.capacity_splits:
            raise ValueError(
                f"scenario {self.name!r}: empty capacity_splits")
        quota_splits = []
        for split in self.capacity_splits:
            try:
                if parse_capacity_split(split) is not None:
                    quota_splits.append(split)
            except ValueError as e:
                raise ValueError(f"scenario {self.name!r}: {e}") from None
        single = [b for b in self.benches if not is_mt_bench(b)]
        if quota_splits and single:
            raise ValueError(
                f"scenario {self.name!r}: capacity splits {quota_splits} "
                f"need multi-tenant benches, but {single} are "
                "single-tenant")
        serve = [b for b in self.benches if is_serve_bench(b)]
        if serve and self.window is not None:
            raise ValueError(
                f"scenario {self.name!r}: serve benches {serve} must use "
                "window=None (a window split would desynchronize the "
                "decode-step bounds the latency columns derive from)")
        for field, values, vocab in (
                ("evictions", self.evictions,
                 set(EVICTION_POLICIES) | {ADAPTIVE_POLICY}),
                ("prefetchers", self.prefetchers, set(PREFETCHERS)),
                ("model_families", self.model_families,
                 set(MODEL_FAMILIES))):
            if not values:
                raise ValueError(f"scenario {self.name!r}: empty {field}")
            bad = [v for v in values if v not in vocab]
            if bad:
                raise ValueError(
                    f"scenario {self.name!r}: unknown {field} {bad}; "
                    f"choose from {sorted(vocab)}")
        if not self.ratios or any(r <= 0 for r in self.ratios):
            raise ValueError(
                f"scenario {self.name!r}: ratios must be positive, "
                f"got {self.ratios}")
        if self.scale <= 0:
            raise ValueError(f"scenario {self.name!r}: scale must be > 0")
        return self

    # ------------------------------------------------------------------
    def cells(self, *, engine: str = "auto",
              backend: str = "auto") -> List[SweepCell]:
        """Expand the matrix in deterministic order, each cell stamped
        with the scenario name (the sweep's resume store keys on it)."""
        out = []
        for bench in self.benches:
            for seed in self.seeds:
                for ratio in self.ratios:
                    for eviction in self.evictions:
                        for split in self.capacity_splits:
                            for pf in self.prefetchers:
                                for fam in self.model_families:
                                    out.append(SweepCell(
                                        bench=bench, prefetcher=pf,
                                        scale=self.scale, seed=seed,
                                        window=self.window,
                                        prediction_us=self.prediction_us,
                                        device_frac=ratio,
                                        eviction=eviction,
                                        capacity_split=split,
                                        scenario=self.name, engine=engine,
                                        backend=backend,
                                        service_steps=self.service_steps,
                                        model_family=fam))
        return out

    def n_cells(self) -> int:
        return (len(self.benches) * len(self.seeds) * len(self.ratios)
                * len(self.evictions) * len(self.prefetchers)
                * len(self.model_families) * len(self.capacity_splits))

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def scenario_from_dict(doc: Dict) -> Scenario:
    """JSON round-trip: lists come back as the dataclass's tuples."""
    kwargs = dict(doc)
    for field in ("benches", "ratios", "evictions", "prefetchers", "seeds",
                  "model_families", "capacity_splits"):
        if field in kwargs and kwargs[field] is not None:
            kwargs[field] = tuple(kwargs[field])
    return Scenario(**kwargs).validate()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario, *,
                      replace: bool = False) -> Scenario:
    scenario.validate()
    if scenario.name in _SCENARIOS and not replace:
        raise ValueError(f"scenario {scenario.name!r} already registered "
                         "(pass replace=True to override)")
    _SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {available_scenarios()}") from None


def available_scenarios() -> List[str]:
    return sorted(_SCENARIOS)


def expand_scenario(name: str, *, engine: str = "auto",
                    backend: str = "auto") -> List[SweepCell]:
    """Expand a registered scenario into sweep cells (the CLI entry:
    ``python -m repro.uvm.sweep --scenario <name>``)."""
    return get_scenario(name).cells(engine=engine, backend=backend)


# ---------------------------------------------------------------------------
# built-ins
# ---------------------------------------------------------------------------

register_scenario(Scenario(
    name="oversub-full",
    description=(
        "Full oversubscription matrix: all 11 paper benchmarks x "
        "capacity ratios (1.5/1.0/0.75/0.5 x working set) x all "
        "eviction policies x all five prefetcher families"),
    benches=PAPER_BENCHMARKS,
    ratios=DEFAULT_RATIOS,
))

#: the serving scenario family: PagedKVStore-derived fault streams
#: (repro.offload.serve_trace) replayed as first-class traces — serve
#: scenarios always use window=None so decode-step bounds stay aligned
SERVE_BENCHES = ("ServeDecode", "ServeTenantMix", "ServeBursty")

register_scenario(Scenario(
    name="serve-full",
    description=(
        "Serving-traffic matrix: continuous-batching decode, multi-tenant "
        "mix, and bursty open-loop arrivals (three request rates) x "
        "capacity ratios x all eviction policies x all five prefetcher "
        "families; rows carry p50/p95/p99 decode latency and TTFT"),
    benches=SERVE_BENCHES + ("ServeBursty@r32", "ServeBursty@r256"),
    ratios=DEFAULT_RATIOS,
    window=None,
))

register_scenario(Scenario(
    name="serve-smoke",
    description=(
        "CI smoke for the serving family: 2 serve workloads x 2 "
        "oversubscribed ratios x all eviction policies x the demand-family "
        "prefetchers (none, block) at scale 0.25 — small enough that the "
        "pallas interpret-mode lanes replay every cell, and every row must "
        "record its backend, policy, and latency percentiles "
        "(scripts/ci_check.sh)"),
    benches=("ServeDecode", "ServeBursty"),
    ratios=(0.75, 0.5),
    prefetchers=("none", "block"),
    scale=0.25,
    window=None,
))

register_scenario(Scenario(
    name="chaos-smoke",
    description=(
        "CI smoke for the crash-safety plane: 2 small benchmarks x 1 "
        "oversubscribed ratio x 2 eviction policies x (none, tree) at "
        "scale 0.25 — 8 cells, sized so the chaos convergence harness "
        "(python -m repro.uvm.faults) can run it fault-free and under "
        "the bounded kill+corrupt+raise plan, with driver restarts, in "
        "well under a minute (scripts/ci_check.sh)"),
    benches=("ATAX", "Pathfinder"),
    ratios=(0.75,),
    evictions=("lru", "hotcold"),
    prefetchers=("none", "tree"),
    scale=0.25,
))

register_scenario(Scenario(
    name="transformer-smoke",
    description=(
        "CI smoke for the predictor-family axis: 2 small benchmarks x 1 "
        "oversubscribed ratio x adaptive eviction x the learned "
        "prefetcher, across the simplified AND reference-Transformer "
        "families at scale 0.25 with short training — 4 cells proving "
        "rows record their model_family and a concretely resolved "
        "eviction policy through the pallas interpret-mode lanes "
        "(scripts/ci_check.sh)"),
    benches=("ATAX", "Pathfinder"),
    ratios=(0.75,),
    evictions=(ADAPTIVE_POLICY,),
    prefetchers=("learned",),
    model_families=("simplified", "transformer"),
    scale=0.25,
    service_steps=40,
))

#: multi-tenant bench pairs of the full interference matrix: diverse
#: pairings (streaming x wavefront, linear-algebra x stencil, ...) per
#: the shared-virtual-memory interference argument of arXiv 2405.06811
MT_BENCHES = ("ATAX+Pathfinder", "BICG+Hotspot", "MVT+StreamTriad",
              "Backprop+NW")

register_scenario(Scenario(
    name="mt-full",
    description=(
        "Multi-tenant interference matrix: 4 diverse benchmark pairs "
        "interleaved into one access stream x oversubscribed capacity "
        "ratios x capacity splits (shared contention, a hard 50/50 "
        "partition, and a 40/40 split leaving a 20% spill pool) x all "
        "eviction policies x all five prefetcher families; every row "
        "carries per-tenant hit rates and the interference slowdown vs. "
        "each tenant's solo replay"),
    benches=MT_BENCHES,
    ratios=(0.75, 0.5),
    capacity_splits=("shared", "0.5/0.5", "0.4/0.4"),
))

register_scenario(Scenario(
    name="mt-smoke",
    description=(
        "CI smoke for the multi-tenant plane: 1 interleaved pair x 2 "
        "oversubscribed ratios x 3 capacity splits (shared / hard 50-50 "
        "/ 40-40 + spill) x all eviction policies x (none, tree) at "
        "scale 0.25 — 36 cells on ONE shared trace, replayed through "
        "the pallas interpret-mode lanes; every row must record "
        "tenants, its capacity split, both per-tenant hit rates, and "
        "the interference slowdown (scripts/ci_check.sh)"),
    benches=("ATAX+Pathfinder",),
    ratios=(0.75, 0.5),
    capacity_splits=("shared", "0.5/0.5", "0.4/0.4"),
    prefetchers=("none", "tree"),
    scale=0.25,
))

register_scenario(Scenario(
    name="oversub-smoke",
    description=(
        "CI smoke: 2 small benchmarks x 2 oversubscribed ratios x all "
        "eviction policies x (none, tree) at scale 0.25 — the whole "
        "matrix stays under 100k accesses so the pallas interpret-mode "
        "lanes replay it in seconds"),
    benches=("ATAX", "Pathfinder"),
    ratios=(0.75, 0.5),
    prefetchers=("none", "tree"),
    scale=0.25,
))
