"""Prefetching policies for the UVM simulator.

TreePrefetcher implements the CUDA-driver tree-based neighborhood scheme
uncovered by Ganguly et al. (ISCA'19) and used by the UVMSmart runtime — the
paper's baseline.  LearnedPrefetcher implements the paper's solution: on a
far-fault, migrate the 64 KB basic block of the faulting page plus the top-1
page predicted by the deep-learning model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.traces.trace import BASIC_BLOCK_PAGES, ROOT_PAGES


class Prefetcher:
    """Base interface.

    ``on_fault`` returns the pages to migrate *in addition to* the faulting
    page (the simulator always migrates the demand page first on the bus).
    ``extra_latency_cycles`` is added to the prefetched pages' availability
    (e.g. model inference overhead).
    """

    name = "none"
    extra_latency_cycles: float = 0.0

    def reset(self) -> None:  # pragma: no cover - trivial
        pass

    def on_fault(self, index: int, page: int, resident) -> List[int]:
        raise NotImplementedError

    def on_access(self, index: int, page: int, resident,
                  clock: float = 0.0) -> List[int]:
        """Called for *every* GMMU request (hit or fault) — continuous
        prefetching.  Returns additional pages to schedule."""
        return []

    def on_migrate(self, pages: List[int]) -> None:
        """Observe every page that became resident (demand or prefetch)."""

    def on_evict(self, page: int) -> None:
        """Observe evictions (tree node occupancy must shrink)."""


class NoPrefetcher(Prefetcher):
    """Pure on-demand paging (first-touch migration only)."""

    name = "on-demand"

    def on_fault(self, index: int, page: int, resident) -> List[int]:
        return []


def _block_of(page: int) -> int:
    return page // BASIC_BLOCK_PAGES


class BlockPrefetcher(Prefetcher):
    """Migrate the whole 64 KB basic block of the faulting page."""

    name = "block"

    def on_fault(self, index: int, page: int, resident) -> List[int]:
        base = _block_of(page) * BASIC_BLOCK_PAGES
        return [p for p in range(base, base + BASIC_BLOCK_PAGES)
                if p != page and p not in resident]


class TreePrefetcher(Prefetcher):
    """CUDA-driver tree-based neighborhood prefetcher (UVMSmart baseline).

    Each 2 MB chunk of an allocation is a full binary tree over 64 KB basic
    blocks (leaves).  A far-fault migrates its 64 KB block; whenever a
    non-leaf node becomes more than half resident, the *remaining* pages of
    that node are scheduled too — cascading up to the whole 2 MB chunk.
    """

    name = "tree"
    LEVELS = 5  # 64KB -> 128 -> 256 -> 512 -> 1MB -> 2MB (32 leaves)

    def __init__(self) -> None:
        # resident page count per (level, node); node id at level L covers
        # BASIC_BLOCK_PAGES * 2^L pages.
        self.counts: Dict[tuple, int] = {}

    def reset(self) -> None:
        self.counts.clear()

    def _node(self, level: int, page: int) -> tuple:
        span = BASIC_BLOCK_PAGES << level
        return (level, page // span)

    def on_migrate(self, pages: List[int]) -> None:
        for page in pages:
            for lv in range(self.LEVELS + 1):
                key = self._node(lv, page)
                self.counts[key] = self.counts.get(key, 0) + 1

    def on_evict(self, page: int) -> None:
        for lv in range(self.LEVELS + 1):
            key = self._node(lv, page)
            cnt = self.counts.get(key)
            if cnt is not None:
                if cnt <= 1:
                    # pop at zero: on churny oversubscribed runs the dict
                    # otherwise grows monotonically with every node ever
                    # touched (a zero-count node reads the same as a missing
                    # one, so behavior is unchanged)
                    del self.counts[key]
                else:
                    self.counts[key] = cnt - 1

    def on_fault(self, index: int, page: int, resident) -> List[int]:
        # 1) the faulting basic block
        base = _block_of(page) * BASIC_BLOCK_PAGES
        out = [p for p in range(base, base + BASIC_BLOCK_PAGES)
               if p != page and p not in resident]
        # 2) >50% escalation: walk up; count the about-to-arrive pages too.
        pending = set(out) | {page}
        for lv in range(1, self.LEVELS + 1):
            span = BASIC_BLOCK_PAGES << lv
            node_base = (page // span) * span
            key = (lv, page // span)
            cnt = self.counts.get(key, 0) + len(
                [p for p in pending if node_base <= p < node_base + span])
            if cnt * 2 > span:
                extra = [p for p in range(node_base, node_base + span)
                         if p not in resident and p not in pending and p != page]
                out.extend(extra)
                pending.update(extra)
            else:
                break
        return out


class LearnedPrefetcher(Prefetcher):
    """The paper's solution (§4, §7.3): the predictor sits at the UVM backend
    and makes a prediction for *every* GMMU read-request; the top-1 predicted
    page is scheduled for migration if absent.  On a far-fault the faulting
    64 KB basic block is migrated as well (max 15 + 1 = 16 pages per fault).

    Predictions are precomputed per trace index by the predictor service
    (``repro.core.service``): ``predicted_pages[i]`` is the model's top-1
    future page given the access history of this access's cluster up to and
    including index ``i`` (at the configured prediction distance).
    ``extra_latency_cycles`` models inference overhead (Fig 10 sensitivity).
    """

    name = "learned"

    def __init__(self, predicted_pages: np.ndarray,
                 extra_latency_cycles: float = 0.0,
                 prefetch_block: bool = True) -> None:
        self.predicted_pages = predicted_pages
        self.extra_latency_cycles = float(extra_latency_cycles)
        self.prefetch_block = prefetch_block
        self._next_free = 0.0

    def reset(self) -> None:
        self._next_free = 0.0

    def on_fault(self, index: int, page: int, resident) -> List[int]:
        if not self.prefetch_block:
            return []
        base = _block_of(page) * BASIC_BLOCK_PAGES
        return [p for p in range(base, base + BASIC_BLOCK_PAGES)
                if p != page and p not in resident]

    def on_access(self, index: int, page: int, resident,
                  clock: float = 0.0) -> List[int]:
        # The predictor is a serialized inference server: one prediction per
        # ``extra_latency_cycles``.  Requests arriving while it is busy get
        # no prediction — this is exactly why the paper's Fig 10 shows gains
        # vanishing as per-prediction overhead grows: the predictor can no
        # longer keep up with the GMMU request rate.
        if clock < self._next_free:
            return []
        self._next_free = clock + self.extra_latency_cycles
        pred = int(self.predicted_pages[index])
        if pred >= 0 and pred != page and pred not in resident:
            return [pred]
        return []


class OraclePrefetcher(Prefetcher):
    """Ideal-prefetcher upper bound: streams pages in first-touch order a
    fixed distance ahead of the demand frontier (perfect accuracy, perfect
    coverage; hit rate limited only by bus bandwidth)."""

    name = "oracle"

    def __init__(self, future_pages: np.ndarray, lookahead: int = 96) -> None:
        self.lookahead = lookahead
        # first-touch order of pages + the access index of each first touch
        pages = np.asarray(future_pages)
        _, first_idx = np.unique(pages, return_index=True)
        order = np.sort(first_idx)
        self.ft_pages = pages[order]
        self.ft_index = order
        self.pos = 0

    def reset(self) -> None:
        self.pos = 0

    def on_fault(self, index: int, page: int, resident) -> List[int]:
        return self.on_access(index, page, resident)

    def on_access(self, index: int, page: int, resident,
                  clock: float = 0.0) -> List[int]:
        while (self.pos < len(self.ft_index)
               and self.ft_index[self.pos] <= index):
            self.pos += 1
        out = []
        for j in range(self.pos, min(self.pos + self.lookahead, len(self.ft_pages))):
            p = int(self.ft_pages[j])
            if p not in resident:
                out.append(p)
            if len(out) >= 16:
                break
        return out
