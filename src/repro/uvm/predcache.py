"""Content-addressed cache of learned-prefetcher prediction arrays.

The learned sweep cells are the expensive ones: training the jax predictor
service dominates a (trace × prediction_us × device_frac) grid if every cell
retrains from scratch, even though the ``predict_trace`` output depends only
on the trace content and the predictor configuration — not on the replay
knobs (``prediction_us``, capacity) the grid actually varies.

This module gives those cells train-once semantics:

* Keys are **content-addressed**: sha256 over the trace's access records +
  instruction count plus every :class:`~repro.core.service.PredictorService`
  field that influences the predictions (cluster key, prediction distance,
  min-prob gate, sequence length, training steps, batch size, quantization,
  bypass threshold, seed) and a cache-format version.  Two callers holding
  bit-identical traces and configs always agree on the key, no matter how
  the trace was produced (generator, npz cache, in-process fixture).
* Values are plain ``.npy`` arrays written via **atomic write-rename**
  (``os.replace`` of a same-directory tempfile), so concurrent ``--workers``
  processes can never observe a torn file: they either see the complete
  array or nothing.
* A best-effort **training lock** (`O_CREAT|O_EXCL` lockfile) makes
  concurrent misses on the same key wait for the first trainer's result
  instead of training N times; if the lock holder dies, waiters time out
  and train themselves (correctness never depends on the lock).
* A per-process memo keeps the same array shared in-process even with no
  ``cache_dir`` (serial sweeps train once per (trace, model) pair too).

Set ``REPRO_PREDCACHE=0`` to disable all caching (the retrain-per-cell
baseline, used by the regression test in ``tests/test_sweep.py``).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from typing import Dict, Optional

import numpy as np

#: bump on any change to the key schema, the stored array semantics, or the
#: prediction pipeline itself — stale arrays must never be served
PREDCACHE_VERSION = 1

#: conventional subdirectory name under a sweep's trace cache
DEFAULT_SUBDIR = "pred_cache"

#: PredictorService fields that determine the predictions array
SERVICE_KEY_FIELDS = ("cluster_key", "distance", "min_prob", "seq_len",
                      "steps", "batch_size", "quantize", "bypass_threshold",
                      "seed")

_MEMO: Dict[str, np.ndarray] = {}


def clear_memo() -> None:
    """Drop the in-process memo (tests)."""
    _MEMO.clear()


def enabled() -> bool:
    return os.environ.get("REPRO_PREDCACHE", "1") != "0"


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------

def trace_content_key(trace) -> str:
    """Identity of a trace as the predictor sees it: the raw access records
    plus the instruction count (which scales the timing model, not the
    predictions, but keeps the key an honest trace fingerprint).  The hash
    is memoized on the trace instance — a grid calls this once per cell,
    and the access array is multi-MB at full scale."""
    key = getattr(trace, "_predcache_content_key", None)
    if key is not None:
        return key
    acc = np.ascontiguousarray(trace.accesses)
    h = hashlib.sha256()
    h.update(str(acc.dtype).encode())
    h.update(str(acc.shape).encode())
    h.update(acc.tobytes())
    h.update(str(int(trace.n_instructions)).encode())
    key = h.hexdigest()[:24]
    try:
        trace._predcache_content_key = key
    except AttributeError:               # slots/frozen trace: just recompute
        pass
    return key


def predictions_key(trace, **service_fields) -> str:
    """Cache key for one (trace content, predictor config) pair."""
    blob = json.dumps({"_v": PREDCACHE_VERSION,
                       "trace": trace_content_key(trace),
                       **service_fields}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


# ---------------------------------------------------------------------------
# storage (atomic)
# ---------------------------------------------------------------------------

def _path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"preds_{key}.npy")


def load(cache_dir: str, key: str) -> Optional[np.ndarray]:
    """Load a cached predictions array, or None.  A torn/invalid file reads
    as a miss (the atomic rename makes that unreachable for writers using
    :func:`store`, but a miss is always safe)."""
    try:
        arr = np.load(_path(cache_dir, key), allow_pickle=False)
    except (FileNotFoundError, NotADirectoryError, ValueError, EOFError,
            OSError):
        return None
    arr.flags.writeable = False
    return arr


def store(cache_dir: str, key: str, preds: np.ndarray) -> str:
    """Atomically persist a predictions array: write to a same-directory
    tempfile, then ``os.replace`` onto the final name.  Concurrent writers
    race benignly — last rename wins, readers never see a partial file."""
    os.makedirs(cache_dir, exist_ok=True)
    path = _path(cache_dir, key)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, prefix=f".{key}.",
                               suffix=".tmp.npy")
    try:
        with os.fdopen(fd, "wb") as f:
            np.save(f, np.ascontiguousarray(preds))
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


# ---------------------------------------------------------------------------
# training lock (best effort)
# ---------------------------------------------------------------------------

def _try_lock(lock_path: str) -> bool:
    try:
        fd = os.open(lock_path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        return False
    with os.fdopen(fd, "w") as f:
        f.write(str(os.getpid()))
    return True


def _unlock(lock_path: str) -> None:
    try:
        os.unlink(lock_path)
    except OSError:
        pass


# ---------------------------------------------------------------------------
# the train-once entry point
# ---------------------------------------------------------------------------

def get_or_train(trace, *, steps: int = 150, seed: int = 0,
                 cache_dir: Optional[str] = None,
                 service_kwargs: Optional[Dict] = None,
                 lock_poll_s: float = 0.25,
                 lock_patience_s: float = 900.0) -> np.ndarray:
    """Return the ``predict_trace`` array for (trace, predictor config),
    training at most once per key across the memo, the disk cache, and —
    via the lock — concurrent worker processes."""
    # lazy import: keys and storage must work without pulling in jax
    from repro.core import PredictorService

    def _fresh_service() -> "PredictorService":
        return PredictorService(steps=steps, seed=seed,
                                **(service_kwargs or {}))

    def _train() -> np.ndarray:
        svc = _fresh_service()
        svc.fit(trace)
        preds = np.ascontiguousarray(svc.predict_trace(), dtype=np.int64)
        preds.flags.writeable = False
        return preds

    if not enabled():
        return _train()

    probe = _fresh_service()
    fields = {f: getattr(probe, f) for f in SERVICE_KEY_FIELDS}
    key = predictions_key(trace, **fields)
    preds = _MEMO.get(key)
    if preds is not None:
        return preds

    if cache_dir is None:
        preds = _train()
        _MEMO[key] = preds
        return preds

    preds = load(cache_dir, key)
    if preds is None:
        os.makedirs(cache_dir, exist_ok=True)
        lock = _path(cache_dir, key) + ".lock"
        got = _try_lock(lock)
        if not got:
            # another process is training this key: wait for its array
            deadline = time.monotonic() + lock_patience_s
            while time.monotonic() < deadline:
                preds = load(cache_dir, key)
                if preds is not None:
                    break
                if _try_lock(lock):      # holder released without a result
                    got = True
                    break
                time.sleep(lock_poll_s)
            if preds is None and not got:
                # patience exhausted: the lock holder is dead or wedged.
                # Steal the lock so it cannot poison this key for every
                # future cold-cache process; a benign duplicate training
                # run (deterministic, atomic rename) is the worst case.
                _unlock(lock)
                got = _try_lock(lock)
        if preds is None:
            try:
                preds = load(cache_dir, key)   # double-check under the lock
                if preds is None:
                    preds = _train()
                    store(cache_dir, key, preds)
            finally:
                if got:
                    _unlock(lock)
    _MEMO[key] = preds
    return preds
