"""Content-addressed cache of learned-prefetcher prediction arrays.

The learned sweep cells are the expensive ones: training the jax predictor
service dominates a (trace × prediction_us × device_frac) grid if every cell
retrains from scratch, even though the ``predict_trace`` output depends only
on the trace content and the predictor configuration — not on the replay
knobs (``prediction_us``, capacity) the grid actually varies.

This module gives those cells train-once semantics:

* Keys are **content-addressed**: sha256 over the trace's access records +
  instruction count plus every :class:`~repro.core.service.PredictorService`
  field that influences the predictions (cluster key, prediction distance,
  min-prob gate, sequence length, training steps, batch size, quantization,
  bypass threshold, seed, and the model identity: the ``model_family``
  name plus the architecture digest of its resolved
  :class:`~repro.core.families.PredictorConfig`) and a cache-format
  version.  Two callers holding bit-identical traces and configs always
  agree on the key, no matter how the trace was produced (generator, npz
  cache, in-process fixture) — and two model families on the same trace
  can never cross-serve one cached array.  The trace fingerprint is
  memoized on the trace instance *and the access array is frozen*
  (``writeable=False``) at memo time, so a later in-place mutation raises
  instead of silently reusing a stale fingerprint.
* Values are single-file ``.npz`` archives carrying the predictions array
  **plus its sha256** (over dtype+shape+bytes), written via **atomic
  write-rename** (``os.replace`` of a same-directory tempfile), so
  concurrent ``--workers`` processes can never observe a torn file, and
  out-of-band corruption (truncation, bit flips) is *detected* on read:
  a failing entry is quarantined to ``<entry>.corrupt`` with a warning
  and the key retrains — corrupt bytes are never served as predictions.
* A best-effort **training lock** — a crash-reclaimable lease file from
  :mod:`repro.distributed.fault_tolerance` — makes concurrent misses on
  the same key wait for the first trainer's result instead of training N
  times.  A lock whose owner pid is dead (SIGKILLed trainer on this
  host) or whose TTL expired is stolen immediately; a holder that
  finished but wrote a *corrupt* entry is detected by the waiters'
  checksummed polls (quarantine + immediate steal + retrain — no
  patience burned on an array that can never appear); a live-but-wedged
  holder is waited out for ``lock_patience_s`` and then overridden
  (correctness never depends on the lock).
* A per-process memo keeps the same array shared in-process even with no
  ``cache_dir`` (serial sweeps train once per (trace, model) pair too).

Set ``REPRO_PREDCACHE=0`` to disable all caching (the retrain-per-cell
baseline, used by the regression test in ``tests/test_sweep.py``).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
import warnings
import zipfile
from typing import Dict, Optional

import numpy as np

from repro.distributed import fault_tolerance as ft
from repro.uvm import faults

#: bump on any change to the key schema, the stored array semantics, or the
#: prediction pipeline itself — stale arrays must never be served
#: (2: checksummed .npz entries with an embedded sha256;
#:  3: model identity in the key — ``model_family`` + resolved
#:  PredictorConfig digest, so no two architectures share an entry)
PREDCACHE_VERSION = 3

#: conventional subdirectory name under a sweep's trace cache
DEFAULT_SUBDIR = "pred_cache"

#: PredictorService fields that determine the predictions array.
#: ``model_config`` is the service's architecture-digest property
#: (repro.core.families.config_digest of the resolved family config):
#: without it, two families — or two revisions of one family's block —
#: on the same trace would collide on one cached array.
SERVICE_KEY_FIELDS = ("cluster_key", "distance", "min_prob", "seq_len",
                      "steps", "batch_size", "quantize", "bypass_threshold",
                      "seed", "model_family", "model_config")

_MEMO: Dict[str, np.ndarray] = {}


def clear_memo() -> None:
    """Drop the in-process memo (tests)."""
    _MEMO.clear()


def enabled() -> bool:
    return os.environ.get("REPRO_PREDCACHE", "1") != "0"


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------

def trace_content_key(trace) -> str:
    """Identity of a trace as the predictor sees it: the raw access records
    plus the instruction count (which scales the timing model, not the
    predictions, but keeps the key an honest trace fingerprint).  The hash
    is memoized on the trace instance — a grid calls this once per cell,
    and the access array is multi-MB at full scale.  Memoizing is only
    sound if the hashed bytes cannot change afterwards, so the access
    array is frozen (``writeable=False``) at memo time: an in-place
    mutation after keying then raises at the mutation site instead of
    silently serving another trace's predictions."""
    key = getattr(trace, "_predcache_content_key", None)
    if key is not None:
        return key
    acc = np.ascontiguousarray(trace.accesses)
    h = hashlib.sha256()
    h.update(str(acc.dtype).encode())
    h.update(str(acc.shape).encode())
    h.update(acc.tobytes())
    h.update(str(int(trace.n_instructions)).encode())
    key = h.hexdigest()[:24]
    try:
        trace.accesses.flags.writeable = False
        trace._predcache_content_key = key
    except (AttributeError, ValueError):
        # slots/frozen trace, or an accesses view we cannot freeze: skip
        # the memo and recompute per call — correct, just slower
        pass
    return key


def predictions_key(trace, **service_fields) -> str:
    """Cache key for one (trace content, predictor config) pair."""
    blob = json.dumps({"_v": PREDCACHE_VERSION,
                       "trace": trace_content_key(trace),
                       **service_fields}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


# ---------------------------------------------------------------------------
# storage (atomic)
# ---------------------------------------------------------------------------

def _path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"preds_{key}.npz")


def _preds_digest(preds: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(preds.dtype).encode())
    h.update(str(preds.shape).encode())
    h.update(np.ascontiguousarray(preds).tobytes())
    return h.hexdigest()


def _quarantine(path: str, reason: str) -> None:
    warnings.warn(f"{reason}: quarantining {path} -> {path}.corrupt and "
                  "retraining", RuntimeWarning)
    try:
        os.replace(path, path + ".corrupt")
    except OSError:
        pass


def load_checked(cache_dir: str, key: str
                 ) -> "tuple[Optional[np.ndarray], bool]":
    """Load a cached predictions array; returns ``(array_or_None,
    corrupt)``.  The embedded sha256 is verified against the array bytes:
    an unreadable or checksum-failing entry (truncation, bit flips —
    anything the atomic rename cannot rule out) is quarantined to
    ``<entry>.corrupt`` and reads as a miss with ``corrupt=True``, so
    corruption triggers a retrain instead of silently skewing every
    downstream hit-rate.  The corrupt flag matters to lock *waiters*: a
    corrupt entry proves the holder already finished (and failed) its
    write, so waiting out its lease cannot produce a good array."""
    path = _path(cache_dir, key)
    try:
        with np.load(path, allow_pickle=False) as z:
            preds = np.ascontiguousarray(z["preds"])
            sha = str(z["sha"])
    except (FileNotFoundError, NotADirectoryError):
        return None, False
    except (ValueError, EOFError, OSError, KeyError, zipfile.BadZipFile):
        _quarantine(path, "unreadable prediction cache entry")
        return None, True
    if sha != _preds_digest(preds):
        _quarantine(path, "prediction cache checksum mismatch")
        return None, True
    preds.flags.writeable = False
    return preds, False


def load(cache_dir: str, key: str) -> Optional[np.ndarray]:
    """:func:`load_checked` without the corrupt flag."""
    return load_checked(cache_dir, key)[0]


def store(cache_dir: str, key: str, preds: np.ndarray) -> str:
    """Atomically persist a predictions array with its checksum: write a
    single ``.npz`` (array + sha256) to a same-directory tempfile, then
    ``os.replace`` onto the final name.  Concurrent writers race benignly
    — last rename wins, readers never see a partial file — and keeping
    array and checksum in one file means no writer interleaving can pair
    an array with another writer's checksum."""
    os.makedirs(cache_dir, exist_ok=True)
    path = _path(cache_dir, key)
    arr = np.ascontiguousarray(preds)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, prefix=f".{key}.",
                               suffix=".tmp.npz")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, preds=arr, sha=np.array(_preds_digest(arr)))
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    faults.corrupt("pred.artifact", path, key)
    return path


# ---------------------------------------------------------------------------
# training lock (best effort, crash-reclaimable)
# ---------------------------------------------------------------------------

def _try_lock(lock_path: str, ttl_s: float) -> bool:
    """Claim the training lock for a key.  The lock is a lease file
    ({pid, host, ts}): a holder that was SIGKILLed on this host is stolen
    immediately via the dead-pid check, a holder elsewhere is presumed
    dead once its TTL expires — so one crashed trainer can never make
    every future cold-cache process serve its full ``lock_patience_s``.
    Legacy bare-pid lockfiles parse as TTL-less records and read as
    stale."""
    return ft.try_acquire_lease(lock_path, ttl_s,
                                extra={"role": "predcache-train"})


def _unlock(lock_path: str) -> None:
    ft.release_lease(lock_path)


# ---------------------------------------------------------------------------
# the train-once entry point
# ---------------------------------------------------------------------------

def get_or_train(trace, *, steps: int = 150, seed: int = 0,
                 cache_dir: Optional[str] = None,
                 service_kwargs: Optional[Dict] = None,
                 lock_poll_s: float = 0.25,
                 lock_patience_s: float = 900.0) -> np.ndarray:
    """Return the ``predict_trace`` array for (trace, predictor config),
    training at most once per key across the memo, the disk cache, and —
    via the lock — concurrent worker processes."""
    # lazy import: keys and storage must work without pulling in jax
    from repro.core import PredictorService

    def _fresh_service() -> "PredictorService":
        return PredictorService(steps=steps, seed=seed,
                                **(service_kwargs or {}))

    def _train() -> np.ndarray:
        svc = _fresh_service()
        svc.fit(trace)
        preds = np.ascontiguousarray(svc.predict_trace(), dtype=np.int64)
        preds.flags.writeable = False
        return preds

    if not enabled():
        return _train()

    probe = _fresh_service()
    fields = {f: getattr(probe, f) for f in SERVICE_KEY_FIELDS}
    key = predictions_key(trace, **fields)
    preds = _MEMO.get(key)
    if preds is not None:
        return preds

    if cache_dir is None:
        preds = _train()
        _MEMO[key] = preds
        return preds

    preds, corrupt = load_checked(cache_dir, key)
    if preds is None:
        os.makedirs(cache_dir, exist_ok=True)
        lock = _path(cache_dir, key) + ".lock"
        got = _try_lock(lock, lock_patience_s)
        if not got and corrupt:
            # A corrupt entry under someone else's live lock means its
            # holder already trained, stored, and failed (the entry is
            # quarantined): waiting out the lease can never produce a
            # good array, so steal it and retrain now.  If the entry was
            # a *previous* crash's debris and the current holder is
            # healthy, the steal costs one benign duplicate training run
            # (deterministic, atomic rename — last writer wins).
            _unlock(lock)
            got = _try_lock(lock, lock_patience_s)
        if not got:
            # another *live* process is training this key: wait for its
            # array.  Each poll re-probes the lease, so a holder that
            # dies mid-training is reclaimed at the next poll instead of
            # costing the full patience window.
            deadline = time.monotonic() + lock_patience_s
            while time.monotonic() < deadline:
                preds, corrupt = load_checked(cache_dir, key)
                if preds is not None:
                    break
                if corrupt:
                    # The holder already wrote its entry and the bytes
                    # are bad (now quarantined): it trained, stored, and
                    # failed — whether it is still alive, waiting out
                    # its lease can never yield a good array.  Steal the
                    # lock and retrain now instead of burning the full
                    # patience window.
                    _unlock(lock)
                    got = _try_lock(lock, lock_patience_s)
                    break
                if _try_lock(lock, lock_patience_s):
                    got = True           # holder released, died, or TTL'd
                    break
                time.sleep(lock_poll_s)
            if preds is None and not got:
                # patience exhausted: the lock holder is alive but wedged.
                # Steal the lock so it cannot poison this key for every
                # future cold-cache process; a benign duplicate training
                # run (deterministic, atomic rename) is the worst case.
                _unlock(lock)
                got = _try_lock(lock, lock_patience_s)
        if preds is None:
            try:
                preds = load(cache_dir, key)   # double-check under the lock
                if preds is None:
                    preds = _train()
                    store(cache_dir, key, preds)
            finally:
                if got:
                    _unlock(lock)
    _MEMO[key] = preds
    return preds
