"""Batched UVM sweep orchestrator + backend scheduler.

Runs (trace × prefetcher × config) grids through the backend-pluggable
replay core: cached trace generation, optional process fan-out, structured
JSON/CSV results, and resumability (each completed cell is persisted, so an
interrupted sweep picks up where it stopped).

Programmatic use::

    from repro.uvm.sweep import expand_grid, run_sweep
    cells = expand_grid(["ATAX", "BICG"], ["none", "tree", "oracle"],
                        device_fracs=[None, 0.5])
    rows = run_sweep(cells, out_dir="results/", workers=8)

CLI::

    PYTHONPATH=src python -m repro.uvm.sweep \
        --benches ATAX,BICG,Pathfinder,Hotspot \
        --prefetchers none,tree,learned,oracle \
        --evictions lru,random,hotcold \
        --backend pallas --out results/ --workers 8

    # the full oversubscription scenario matrix (11 benchmarks x ratio x
    # eviction policy x prefetcher, see repro.uvm.scenarios), resumable:
    PYTHONPATH=src python -m repro.uvm.sweep --scenario oversub-full \
        --out results/oversub/ --workers 8

Backend scheduling
------------------

Each cell names a replay backend (``--backend {numpy,pallas,auto}``; also
the ``REPRO_SWEEP_BACKEND`` env var).  The scheduler groups pending
pallas-eligible cells — every paper-facing prefetcher
(none/block/tree/learned/oracle) whose page span fits a lane — into
multi-lane batches bucketed by *prefetcher family* in addition to
span/length (a lane batch is always family-homogeneous: demand, tree,
learned, and oracle lanes are different kernels with different per-lane
state) and replays each batch in ONE ``jax_pallas`` kernel launch (one
lane per cell, padded to the longest trace; see
``repro.uvm.backends.pallas_backend``).  Everything unpackable falls back
*per cell* down the ``pallas → numpy → legacy`` chain, and every result
row records the backend that actually ran in its ``backend`` column, so
fallbacks are visible instead of silently reading as covered.  ``auto`` resolves to the pallas lanes only when jax is
already up on a platform the lanes compile natively for (TPU, or
``REPRO_PALLAS_COMPILE=1`` on other accelerators); everywhere else —
including CPU hosts, where the lanes would run in interpret mode — it is
the NumPy engine.

Train-once learned cells
------------------------

The ``learned`` prefetcher needs the paper's predictor service (jax;
expensive to train), but its predictions depend only on the *trace content*
and the *predictor config* — not on the replay knobs (``prediction_us``,
``device_frac``/``device_pages``, engine, backend) a sensitivity grid
varies.
:func:`make_prefetcher` therefore routes predictions through
``repro.uvm.predcache``: a grid trains **once per (trace, model) pair** and
every other learned cell of the grid reuses the cached array, in-process
(memo) and across runs (content-addressed ``.npy`` files under
``<trace cache>/pred_cache/``, written with atomic rename).

With ``--workers N`` the cache is shared through the filesystem: the first
worker to miss a key takes a lockfile and trains; workers hitting the same
key wait for the array instead of training again, and workers on different
keys train in parallel — a (trace × prediction_us × device_frac) grid costs
one training run per trace no matter how many variants ride on it or how
the pool schedules them.  ``REPRO_PREDCACHE=0`` restores the
retrain-per-cell behavior.

A prebuilt predictions array can still be supplied per bench via
:func:`simulate_cell`'s ``prefetcher`` override.

Workers are deterministic: a cell's row is a pure function of the cell, so
serial and parallel sweeps produce identical results (modulo the ``seconds``
timing column).

Crash safety (leases, retries, quarantine)
------------------------------------------

With an ``out_dir``, the sweep is fault-tolerant end to end (the full
protocol is documented in ``repro/uvm/backends/README.md``, "Fault
model"):

* Every persisted artifact — ``cells/<key>.json`` rows, cached trace
  ``.npz`` files, prediction-cache entries — is **checksummed** and
  written with atomic rename.  A torn or corrupted file detected on read
  is quarantined (renamed ``*.corrupt``) with a warning and the work is
  redone, so resume never mixes damaged state into results.  Cell files
  also embed ``SWEEP_VERSION``; a version mismatch requeues the cell
  instead of mixing rows across timing-model versions.
* Per-cell execution takes an expiring **lease**
  (``cells/<key>.lease``, via ``repro.distributed.fault_tolerance``):
  a SIGKILLed worker's lease is reclaimed immediately through the
  owner-pid liveness check (TTL expiry covers remote/multi-host owners),
  so crashed workers never wedge the grid.  Leases are advisory — cells
  are deterministic and their writes atomic, so the benign steal race
  can only duplicate work, not corrupt results.
* A failing cell **retries with capped exponential backoff**
  (``REPRO_SWEEP_BACKOFF``); after ``max_attempts`` lease claims
  (``REPRO_SWEEP_MAX_ATTEMPTS``) it lands in the **quarantine manifest**
  (``out_dir/quarantine.json`` + a stub row with ``quarantined=True``)
  instead of aborting the grid — visible, never silent.
* With ``--workers N`` the fan-out is a pool of lease workers supervised
  by a :class:`~repro.distributed.fault_tolerance.HeartbeatMonitor`:
  dead workers are restarted, silent-but-alive workers are terminated so
  their leases free up, and any worker can pick up any unleased cell.
* The ``repro.uvm.faults`` plane (``REPRO_FAULT_PLAN``) injects
  deterministic chaos — kills, artifact corruption, transient backend
  raises — at the sites marked throughout this module; the chaos harness
  (``python -m repro.uvm.faults``) proves a sweep under such a plan
  converges byte-identically to a fault-free run.
"""
from __future__ import annotations

import argparse
import collections
import csv
import dataclasses
import functools
import hashlib
import json
import multiprocessing
import os
import sys
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.families import MODEL_FAMILIES  # jax-free config layer
from repro.distributed import fault_tolerance as ft
from repro.traces.trace import ACCESS_DTYPE, Trace
from repro.uvm import adaptive, faults
from repro.uvm.replay_core import TransientBackendFault
from repro.uvm.config import UVMConfig
from repro.uvm.engine import simulate
from repro.uvm.eviction import EVICTION_POLICIES
from repro.uvm.prefetchers import (BlockPrefetcher, LearnedPrefetcher,
                                   NoPrefetcher, OraclePrefetcher,
                                   Prefetcher, TreePrefetcher)
from repro.uvm.replay_core import (ReplayRequest, backend_chain,
                                   dispatch as replay_dispatch, get_backend)
from repro.uvm.simulator import UVMStats

#: cell-spec prefetcher names to concrete types — the single source the
#: CLI vocabulary (PREFETCHERS), :func:`make_prefetcher`, and the lane
#: scheduler's packability/family maps all derive from, so a new
#: prefetcher added here flows everywhere at once
_PREFETCHER_TYPES = {"none": NoPrefetcher, "block": BlockPrefetcher,
                     "tree": TreePrefetcher, "learned": LearnedPrefetcher,
                     "oracle": OraclePrefetcher}
PREFETCHERS = tuple(_PREFETCHER_TYPES)
BACKENDS = ("auto", "numpy", "pallas")

#: bump on any intentional change to the timing model, trace generators,
#: prediction pipeline, or row schema — invalidates persisted sweep cells
#: and cached traces so a resumed sweep never mixes pre- and post-change
#: numbers (v7: serve rows carry ``slo_source`` — ``kernel`` when the
#: replay that ran the cell emitted its step clocks in-band, including
#: the pallas lanes' in-kernel capture; ``side-pass`` when a separate
#: NumPy replay recovered them; v8: learned cells carry a
#: ``model_family`` column — simplified vs the reference Transformer
#: variants — and the ``adaptive`` pseudo-policy resolves to a concrete
#: policy at prepare time, recorded honestly in ``eviction``;
#: v9: multi-tenant interleaved rows (``repro.traces.interleave``) carry
#: ``tenants`` / ``capacity_split`` / per-tenant hit rates and the
#: interference-slowdown columns, and the adaptive probe is keyed by the
#: cell's prefetcher family instead of demand-paging only)
SWEEP_VERSION = 9

#: serving SLO columns (``repro.offload.serve_trace``): per-decode-step
#: latency and time-to-first-token percentiles, None on non-serve rows
SERVE_LATENCY_FIELDS = (
    "decode_lat_p50_us", "decode_lat_p95_us", "decode_lat_p99_us",
    "ttft_p50_us", "ttft_p95_us", "ttft_p99_us",
)

#: multi-tenant columns (``repro.traces.interleave``): tenant count,
#: the capacity split the cell replayed under (``"shared"`` or
#: ``"f0/f1"`` quota fractions), per-tenant hit rates, and the
#: interference slowdown — each tenant's completion cycles in the mix
#: over its *solo* replay (the tenant's accesses extracted and replayed
#: alone at the capacity its quota grants, or the full device when
#: shared).  None on single-tenant rows.
MT_FIELDS = (
    "tenants", "capacity_split", "hit_rate_t0", "hit_rate_t1",
    "slowdown_t0", "slowdown_t1", "interference_slowdown",
)

#: columns of the structured results, in CSV order (``engine`` is the
#: requested replay style, ``backend`` the implementation that actually
#: ran the cell: legacy / numpy / pallas; ``eviction`` the policy the
#: cell replayed under, ``scenario`` the scenario-registry entry the
#: cell expanded from — None for ad-hoc grids)
ROW_FIELDS = [
    "bench", "prefetcher", "scale", "seed", "window", "prediction_us",
    "device_pages", "device_frac", "eviction", "model_family", "scenario",
    "engine", "backend", "n_accesses", "n_instructions",
    "cycles", "ipc", "hits", "late", "faults", "hit_rate", "prefetch_issued",
    "prefetch_used", "accuracy", "coverage", "unity", "pages_migrated",
    "pages_evicted", "pcie_bytes", *SERVE_LATENCY_FIELDS, "slo_source",
    *MT_FIELDS, "retries", "quarantined", "seconds",
]


def parse_capacity_split(split: Optional[str]) -> Optional[Tuple[float,
                                                                 float]]:
    """Validate/parse a ``capacity_split`` spec.

    ``None`` or ``"shared"`` -> None (tenants contend for the whole
    device); ``"f0/f1"`` -> the two per-tenant quota *fractions* of
    ``device_pages`` (``f0 + f1 <= 1``; the remainder is the shared
    spill pool, see ``UVMConfig.tenant_pages``).  Raises ``ValueError``
    on anything else — scenario validation and cell preparation share
    this single parser.
    """
    if split is None or split == "shared":
        return None
    try:
        f0, f1 = (float(x) for x in str(split).split("/"))
    except ValueError:
        raise ValueError(
            f"bad capacity_split {split!r}: expected 'shared' or two "
            "quota fractions like '0.5/0.5'") from None
    if f0 < 0 or f1 < 0 or f0 + f1 > 1.0 + 1e-9:
        raise ValueError(
            f"bad capacity_split {split!r}: fractions must be "
            "non-negative and sum to at most 1")
    return f0, f1


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One point of a sweep grid (hashable, JSON-serializable)."""

    bench: str
    prefetcher: str
    scale: float = 1.0
    seed: int = 0
    window: Optional[float] = 0.6       # leading trace fraction (paper eval)
    prediction_us: float = 1.0          # learned-model inference overhead
    device_pages: Optional[int] = None  # absolute capacity, or ...
    device_frac: Optional[float] = None  # ... fraction of the working set
    eviction: str = "lru"               # lru | random | hotcold | adaptive
    capacity_split: Optional[str] = None  # mt cells: "shared" | "f0/f1"
    scenario: Optional[str] = None      # scenario-registry entry (if any)
    engine: str = "auto"
    backend: str = "auto"               # numpy | pallas | auto
    service_steps: int = 150            # learned-predictor training steps
    model_family: str = "simplified"    # predictor family for learned cells
                                        # (repro.core.families.MODEL_FAMILIES)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def key(self) -> str:
        blob = json.dumps({"_v": SWEEP_VERSION, **self.to_dict()},
                          sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


def expand_grid(benches: Sequence[str], prefetchers: Sequence[str], *,
                scales: Sequence[float] = (1.0,),
                seeds: Sequence[int] = (0,),
                windows: Sequence[Optional[float]] = (0.6,),
                prediction_us: Sequence[float] = (1.0,),
                device_fracs: Sequence[Optional[float]] = (None,),
                evictions: Sequence[str] = ("lru",),
                model_families: Sequence[str] = ("simplified",),
                capacity_splits: Sequence[Optional[str]] = (None,),
                scenario: Optional[str] = None,
                engine: str = "auto",
                backend: str = "auto",
                service_steps: int = 150) -> List[SweepCell]:
    """Cartesian product of the sweep axes, in deterministic order."""
    cells = []
    for bench in benches:
        for pf in prefetchers:
            for scale in scales:
                for seed in seeds:
                    for window in windows:
                        for us in prediction_us:
                            for frac in device_fracs:
                                for ev in evictions:
                                    for split in capacity_splits:
                                        for fam in model_families:
                                            cells.append(SweepCell(
                                                bench=bench, prefetcher=pf,
                                                scale=scale, seed=seed,
                                                window=window,
                                                prediction_us=us,
                                                device_frac=frac,
                                                eviction=ev,
                                                capacity_split=split,
                                                scenario=scenario,
                                                engine=engine,
                                                backend=backend,
                                                service_steps=service_steps,
                                                model_family=fam))
    return cells


# ---------------------------------------------------------------------------
# cached trace generation
# ---------------------------------------------------------------------------

def _trace_cache_path(cache_dir: str, bench: str, scale: float,
                      seed: int) -> str:
    tag = hashlib.sha256(
        json.dumps([SWEEP_VERSION, bench, scale, seed]).encode()
    ).hexdigest()[:16]
    return os.path.join(cache_dir, f"trace_{bench}_{tag}.npz")


def _trace_digest(accesses: np.ndarray, meta_json: str) -> str:
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(accesses).tobytes())
    h.update(meta_json.encode())
    return h.hexdigest()


def quarantine_artifact(path: str, reason: str) -> None:
    """Move a damaged persisted artifact aside (``<path>.corrupt``) with a
    warning, so the caller regenerates instead of crashing — and the
    evidence survives for inspection instead of being overwritten."""
    warnings.warn(f"{reason}: quarantining {path} -> {path}.corrupt and "
                  "regenerating", RuntimeWarning)
    try:
        os.replace(path, path + ".corrupt")
    except OSError:                   # already gone: a racer quarantined it
        pass


class _TraceMemo:
    """Bounded in-process LRU over deserialized (and checksum-verified)
    traces, keyed by the full trace identity (bench, scale, seed, window,
    cache_dir).

    Co-scheduled cells sharing a trace — 24 serve-smoke cells ride on 4
    distinct traces — hit the memo instead of re-opening and re-hashing
    the npz cache file per cell: the checksum is verified **once per
    (path, sha)** within a process, and the PR 7 quarantine path is
    untouched for cold reads (a fresh process reading a corrupted file
    still quarantines + regenerates).  Thread-safe: the lane scheduler's
    prepare stage runs in a thread pool.  ``REPRO_TRACE_MEMO`` overrides
    the entry bound (0 disables the memo entirely).
    """

    def __init__(self, maxsize: int = 32):
        self.maxsize = maxsize
        self._lock = threading.Lock()
        self._data: "collections.OrderedDict[Tuple, Trace]" = \
            collections.OrderedDict()

    def _bound(self) -> int:
        try:
            return int(os.environ.get("REPRO_TRACE_MEMO", self.maxsize))
        except ValueError:
            return self.maxsize

    def get(self, key: Tuple) -> Optional[Trace]:
        if self._bound() <= 0:
            return None
        with self._lock:
            trace = self._data.pop(key, None)
            if trace is not None:
                self._data[key] = trace       # refresh LRU position
            return trace

    def put(self, key: Tuple, trace: Trace) -> None:
        bound = self._bound()
        if bound <= 0:
            return
        with self._lock:
            self._data[key] = trace
            self._data.move_to_end(key)
            while len(self._data) > bound:
                self._data.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()


#: process-wide trace memo (worker processes each build their own)
_trace_memo = _TraceMemo()

#: single-flight guard: concurrent prepare-stage threads asking for the
#: same trace must resolve to ONE generate/deserialize/checksum, with the
#: others blocking on the winner's memo write instead of racing on the
#: cache file
_trace_flight_guard = threading.Lock()
_trace_flights: Dict[Tuple, threading.Lock] = {}


def _trace_flight(key: Tuple) -> threading.Lock:
    with _trace_flight_guard:
        return _trace_flights.setdefault(key, threading.Lock())


def load_trace(bench: str, scale: float = 1.0, seed: int = 0,
               window: Optional[float] = 0.6,
               cache_dir: Optional[str] = None) -> Trace:
    """Generate (or load from the npz disk cache) one benchmark trace and
    cut the leading evaluation window.

    Cached traces embed a content checksum; a truncated or corrupted
    cache file (killed writer on a non-atomic filesystem, disk rot, an
    injected ``trace.artifact`` fault) is quarantined with a warning and
    the trace is regenerated deterministically — never replayed from
    damaged bytes.

    Serve bench names (``repro.offload.serve_trace.SERVE_WORKLOADS``,
    including ``@r<rate>`` variants) route through the serving load
    generator instead of the GPU model; serve traces are never
    window-split (the split would desynchronize the decode-step bounds
    their latency columns derive from).

    Loads are memoized in-process (:class:`_TraceMemo`): cells sharing a
    trace deserialize and checksum it once, not once per cell, and
    concurrent prepare-stage threads single-flight on the key instead of
    generating the same trace twice.
    """
    memo_key = (bench, scale, seed, window, cache_dir)
    memoized = _trace_memo.get(memo_key)
    if memoized is not None:
        return memoized
    with _trace_flight(memo_key):
        memoized = _trace_memo.get(memo_key)    # the winner filled it
        if memoized is not None:
            return memoized
        trace = _load_trace_uncached(bench, scale, seed, window, cache_dir)
        _trace_memo.put(memo_key, trace)
        return trace


def _load_trace_uncached(bench: str, scale: float, seed: int,
                         window: Optional[float],
                         cache_dir: Optional[str]) -> Trace:
    trace = None
    path = None
    if cache_dir:
        path = _trace_cache_path(cache_dir, bench, scale, seed)
        if os.path.exists(path):
            try:
                with np.load(path, allow_pickle=False) as z:
                    meta_json = str(z["meta"])
                    accesses = z["accesses"].astype(ACCESS_DTYPE,
                                                    copy=False)
                    stored_sha = str(z["sha"])
                if stored_sha != _trace_digest(accesses, meta_json):
                    raise ValueError("trace cache checksum mismatch")
                meta = json.loads(meta_json)
                trace = Trace(
                    name=meta["name"],
                    accesses=accesses,
                    array_bases=meta["array_bases"],
                    array_pages=meta["array_pages"],
                    n_instructions=meta["n_instructions"],
                    meta=meta.get("meta", {}),
                )
            except Exception as e:
                quarantine_artifact(
                    path, f"invalid cached trace for {bench} ({e!r})")
                trace = None
    if trace is None:
        from repro.offload.serve_trace import build_serve_trace, \
            is_serve_bench
        from repro.traces.interleave import build_mt_trace, is_mt_bench
        if is_serve_bench(bench):
            trace = build_serve_trace(bench, scale=scale, seed=seed)
        elif is_mt_bench(bench):
            trace = build_mt_trace(bench, scale=scale, seed=seed)
        else:
            from repro.traces import GPUModel, generate_benchmark
            from repro.traces.gpu_model import GPUModelConfig
            spec = generate_benchmark(bench, scale=scale, seed=seed)
            trace = GPUModel(GPUModelConfig(seed=seed)).run(spec)
        if path:
            os.makedirs(cache_dir, exist_ok=True)
            meta = json.dumps({
                "name": trace.name,
                "array_bases": trace.array_bases,
                "array_pages": trace.array_pages,
                "n_instructions": trace.n_instructions,
                "meta": trace.meta,
            })
            tmp = path + f".{os.getpid()}.{threading.get_ident()}.tmp.npz"
            np.savez(tmp, accesses=trace.accesses, meta=np.array(meta),
                     sha=np.array(_trace_digest(trace.accesses, meta)))
            os.replace(tmp, path)
            faults.corrupt("trace.artifact", path, os.path.basename(path))
    if window is not None and not (trace.meta and "serve" in trace.meta):
        trace, _ = trace.split(window)
    return trace


# ---------------------------------------------------------------------------
# per-cell simulation
# ---------------------------------------------------------------------------

def make_prefetcher(cell: SweepCell, trace: Trace, config: UVMConfig,
                    cache_dir: Optional[str] = None) -> Prefetcher:
    if cell.prefetcher == "oracle":
        return OraclePrefetcher(np.asarray(trace.pages))
    if cell.prefetcher == "learned":
        # train-once: predictions come from the content-addressed cache —
        # one training run per (trace, model) pair, shared across every
        # prediction_us / capacity variant, process, and (with cache_dir)
        # run.  See repro.uvm.predcache.
        from repro.uvm import predcache
        pred_dir = (os.path.join(cache_dir, predcache.DEFAULT_SUBDIR)
                    if cache_dir else None)
        preds = predcache.get_or_train(
            trace, steps=cell.service_steps, cache_dir=pred_dir,
            service_kwargs={"model_family": cell.model_family})
        return LearnedPrefetcher(
            preds,
            extra_latency_cycles=cell.prediction_us * config.cycles_per_us)
    cls = _PREFETCHER_TYPES.get(cell.prefetcher)
    if cls is None:
        raise ValueError(f"unknown prefetcher {cell.prefetcher!r}")
    return cls()


def prepare_cell(cell: SweepCell, *, cache_dir: Optional[str] = None,
                 trace: Optional[Trace] = None,
                 prefetcher: Optional[Prefetcher] = None):
    """Materialize one cell's (trace, config, prefetcher, device_pages).

    Shared by the per-cell path (:func:`simulate_cell`) and the lane-batch
    scheduler, so a cell resolves to the same replay inputs no matter which
    backend ends up running it.
    """
    if trace is None:
        trace = load_trace(cell.bench, cell.scale, cell.seed, cell.window,
                           cache_dir=cache_dir)
    device_pages = cell.device_pages
    if device_pages is None and cell.device_frac is not None:
        device_pages = int(trace.working_set_pages * cell.device_frac)
    # the adaptive pseudo-policy resolves to a concrete one here, before
    # the replay config exists: lane batches stay policy-homogeneous and
    # the row's eviction column (from stats.eviction) records what ran
    eviction = adaptive.resolve_eviction(cell.eviction, cell.bench,
                                         trace=trace,
                                         device_pages=device_pages,
                                         prefetcher=cell.prefetcher)
    fracs = parse_capacity_split(cell.capacity_split)
    tenant_pages = None
    if fracs is not None:
        if device_pages is None:
            raise ValueError(
                f"cell {cell.bench}/{cell.prefetcher}: capacity_split="
                f"{cell.capacity_split!r} needs a device capacity "
                "(device_pages or device_frac)")
        tenant_pages = (int(fracs[0] * device_pages),
                        int(fracs[1] * device_pages))
    config = UVMConfig(prediction_overhead_us=cell.prediction_us,
                       device_pages=device_pages, eviction=eviction,
                       tenant_pages=tenant_pages)
    if prefetcher is None:
        prefetcher = make_prefetcher(cell, trace, config,
                                     cache_dir=cache_dir)
    return trace, config, prefetcher, device_pages


def _finish_row(cell: SweepCell, stats: UVMStats,
                device_pages: Optional[int], seconds: float,
                record_timeline: bool = False) -> Dict:
    row = cell.to_dict()
    row.pop("service_steps", None)
    row.update(
        device_pages=device_pages,
        backend=stats.backend,
        eviction=stats.eviction,
        n_accesses=stats.n_accesses,
        n_instructions=stats.n_instructions,
        cycles=stats.cycles,
        ipc=stats.ipc,
        hits=stats.hits,
        late=stats.late,
        faults=stats.faults,
        hit_rate=stats.hit_rate,
        prefetch_issued=stats.prefetch_issued,
        prefetch_used=stats.prefetch_used,
        accuracy=stats.accuracy,
        coverage=stats.coverage,
        unity=stats.unity,
        pages_migrated=stats.pages_migrated,
        pages_evicted=stats.pages_evicted,
        pcie_bytes=stats.pcie_bytes,
        retries=0,                 # lease attempts beyond the first; the
        quarantined=False,         # retry layer overwrites on retried cells
        seconds=seconds,
    )
    for f in SERVE_LATENCY_FIELDS:
        row.setdefault(f, None)      # filled on serve rows, None otherwise
    row.setdefault("slo_source", None)
    for f in MT_FIELDS:
        row.setdefault(f, None)      # filled on multi-tenant rows
    if record_timeline and stats.timeline is not None:
        row["timeline"] = stats.timeline.tolist()
    return row


def _serve_step_bounds(trace: Trace) -> Optional[np.ndarray]:
    """Decode-step bounds of a serve trace, None for benchmark traces."""
    if trace.meta and "serve" in trace.meta:
        from repro.offload.serve_trace import trace_step_bounds
        return trace_step_bounds(trace)
    return None


def _mt_step_bounds(trace: Trace) -> Optional[np.ndarray]:
    """Step bounds marking each tenant's *last access* in an interleaved
    trace (None for single-tenant traces): the replay's step clocks at
    these bounds are the per-tenant completion cycles behind the
    interference-slowdown columns — reusing the serve-row step-clock
    machinery, in-kernel on the pallas lanes included."""
    from repro.traces.interleave import tenant_last_index
    last = tenant_last_index(trace)
    if last is None:
        return None
    bounds = sorted({i + 1 for i in last if i >= 0})
    return np.asarray(bounds, dtype=np.int64)


def _step_bounds(trace: Trace) -> Optional[np.ndarray]:
    """The step bounds a cell's replay should clock: serve decode steps,
    multi-tenant completion bounds, or None."""
    bounds = _serve_step_bounds(trace)
    return bounds if bounds is not None else _mt_step_bounds(trace)


def _serve_side_pass(cell: SweepCell, trace: Trace, config: UVMConfig,
                     stats: UVMStats, bounds: np.ndarray,
                     cache_dir: Optional[str]) -> np.ndarray:
    """NumPy side-pass replay recovering a serve row's step clocks, with
    a built-in differential check: its integer counters must match the
    primary row exactly, whatever backend produced it."""
    pf = make_prefetcher(cell, trace, config, cache_dir=cache_dir)
    req = ReplayRequest(trace, pf, config, step_bounds=bounds)
    check = get_backend("numpy").replay([req])[0]
    for f in ("hits", "late", "faults", "prefetch_issued",
              "prefetch_used", "pages_migrated", "pages_evicted"):
        if getattr(check, f) != getattr(stats, f):
            raise AssertionError(
                f"serve step-clock side pass disagrees with the "
                f"{stats.backend} row on {f}: {getattr(check, f)} != "
                f"{getattr(stats, f)} "
                f"({cell.bench}/{cell.prefetcher}/{cell.eviction})")
    return check.step_clocks


def _serve_latency_row(cell: SweepCell, trace: Trace, config: UVMConfig,
                       stats: UVMStats,
                       cache_dir: Optional[str]) -> Dict:
    """The serving SLO columns for one serve-trace row.

    Every backend now records ``step_clocks`` in-band (legacy/numpy
    host-side, the pallas lanes in-kernel), so the normal path is pure
    percentile math over the clocks the primary replay already produced
    — ``slo_source="kernel"``.  The NumPy side pass of PR 6 survives in
    two demoted roles: a fallback when a row somehow arrives without
    clocks (``slo_source="side-pass"``), and an opt-in differential
    check (``REPRO_SERVE_CHECK=1``) that re-replays the cell host-side
    and requires counters AND clocks to match bit-for-bit.
    """
    from repro.offload.serve_trace import (serve_latency_columns,
                                           trace_step_bounds)

    bounds = trace_step_bounds(trace)
    clocks = stats.step_clocks
    source = "kernel"
    if clocks is None or len(clocks) != len(bounds):
        clocks = _serve_side_pass(cell, trace, config, stats, bounds,
                                  cache_dir)
        source = "side-pass"
    elif os.environ.get("REPRO_SERVE_CHECK", "0") == "1":
        check = _serve_side_pass(cell, trace, config, stats, bounds,
                                 cache_dir)
        if not np.array_equal(np.asarray(clocks), np.asarray(check)):
            raise AssertionError(
                f"in-band step clocks of the {stats.backend} row diverge "
                f"from the NumPy side pass "
                f"({cell.bench}/{cell.prefetcher}/{cell.eviction})")
    row = serve_latency_columns(trace, clocks, config)
    row["slo_source"] = source
    return row


#: solo-replay cycles memo for the interference-slowdown columns: cells
#: of one grid share solo baselines across capacity splits and backends
#: (key: trace identity + tenant + solo capacity + replay knobs)
_solo_memo: Dict[Tuple, int] = {}
_solo_lock = threading.Lock()


def _mt_solo_cycles(cell: SweepCell, trace: Trace, tenant: int,
                    capacity: Optional[int], eviction: str,
                    cache_dir: Optional[str]) -> int:
    """Cycles of one tenant's *solo* replay: its accesses extracted from
    the interleaved trace (``mt_component_trace``) and replayed alone on
    the NumPy engine at ``capacity`` — the tenant's quota on split rows,
    the full device on shared rows.  Memoized: every cell of a grid that
    shares (trace, tenant, capacity, prefetcher, policy) reuses one
    baseline replay."""
    from repro.traces.interleave import mt_component_trace

    key = (cell.bench, cell.scale, cell.seed, cell.window, tenant,
           capacity, cell.prefetcher, eviction, cell.prediction_us,
           cell.model_family)
    with _solo_lock:
        hit = _solo_memo.get(key)
    if hit is not None:
        return hit
    solo = mt_component_trace(trace, tenant)
    cfg = UVMConfig(prediction_overhead_us=cell.prediction_us,
                    device_pages=capacity, eviction=eviction)
    pf = make_prefetcher(cell, solo, cfg, cache_dir=cache_dir)
    stats = get_backend("numpy").replay([ReplayRequest(solo, pf, cfg)])[0]
    cycles = int(stats.cycles)
    with _solo_lock:
        _solo_memo.setdefault(key, cycles)
    return cycles


def _mt_row(cell: SweepCell, trace: Trace, config: UVMConfig,
            stats: UVMStats, device_pages: Optional[int],
            cache_dir: Optional[str]) -> Dict:
    """The multi-tenant columns for one interleaved-trace row: tenant
    count, the capacity split that ran, per-tenant hit rates, and the
    interference slowdown (per-tenant completion cycles in the mix over
    the tenant's solo replay)."""
    from repro.traces.interleave import N_TENANTS, tenant_last_index

    row: Dict = {"tenants": N_TENANTS,
                 "capacity_split": cell.capacity_split or "shared"}
    th, ta = stats.tenant_hits, stats.tenant_accesses
    for t in range(N_TENANTS):
        row[f"hit_rate_t{t}"] = (th[t] / ta[t]) if ta and ta[t] else None

    last = tenant_last_index(trace)
    bounds = sorted({i + 1 for i in last if i >= 0})
    clocks = stats.step_clocks
    if clocks is None or len(clocks) != len(bounds):
        # a row without in-band clocks (or with desynchronized bounds)
        # recovers them from the NumPy side pass, counter-checked
        # against the primary replay like the serve rows
        clocks = _serve_side_pass(cell, trace, config, stats,
                                  np.asarray(bounds, dtype=np.int64),
                                  cache_dir)
    cyc_at = {b: float(c) for b, c in zip(bounds, np.asarray(clocks))}
    slowdowns = []
    for t in range(N_TENANTS):
        if last[t] < 0:
            row[f"slowdown_t{t}"] = None
            continue
        capacity = (config.tenant_pages[t] if config.tenant_pages
                    else device_pages)
        solo = _mt_solo_cycles(cell, trace, t, capacity, config.eviction,
                               cache_dir)
        sd = cyc_at[last[t] + 1] / solo if solo > 0 else None
        row[f"slowdown_t{t}"] = sd
        if sd is not None:
            slowdowns.append(sd)
    row["interference_slowdown"] = max(slowdowns) if slowdowns else None
    return row


def _is_mt_trace(trace: Trace) -> bool:
    from repro.traces.interleave import tenant_boundary
    return tenant_boundary(trace) is not None


def simulate_cell(cell: SweepCell, *, cache_dir: Optional[str] = None,
                  trace: Optional[Trace] = None,
                  prefetcher: Optional[Prefetcher] = None,
                  record_timeline: bool = False) -> Dict:
    """Run one cell and return its structured row.  ``trace`` /
    ``prefetcher`` overrides let callers inject pre-built objects (e.g. a
    LearnedPrefetcher sharing one trained service across cells)."""
    t0 = time.time()
    trace, config, prefetcher, device_pages = prepare_cell(
        cell, cache_dir=cache_dir, trace=trace, prefetcher=prefetcher)
    # serve traces carry decode-step bounds into the replay so the row
    # gets per-step clocks in one pass, whichever backend runs it (the
    # pallas lanes capture them in-kernel); multi-tenant traces reuse the
    # same machinery for per-tenant completion cycles
    serve_bounds = _serve_step_bounds(trace)
    step_bounds = serve_bounds if serve_bounds is not None \
        else _mt_step_bounds(trace)
    stats = simulate(trace, prefetcher, config, engine=cell.engine,
                     backend=cell.backend, record_timeline=record_timeline,
                     step_bounds=step_bounds)
    row = _finish_row(cell, stats, device_pages, time.time() - t0,
                      record_timeline)
    if serve_bounds is not None:
        row.update(_serve_latency_row(cell, trace, config, stats,
                                      cache_dir))
    elif _is_mt_trace(trace):
        row.update(_mt_row(cell, trace, config, stats, device_pages,
                           cache_dir))
    return row


def _worker(args) -> Dict:
    cell, cache_dir = args
    return simulate_cell(cell, cache_dir=cache_dir)


def _init_worker(path: List[str]) -> None:
    """spawn-context initializer: children need the parent's sys.path (the
    repo uses a src layout without installation)."""
    for p in reversed(path):
        if p not in sys.path:
            sys.path.insert(0, p)


# ---------------------------------------------------------------------------
# crash-safe cell store: checksummed envelopes, leases, attempts, quarantine
# ---------------------------------------------------------------------------

def _cell_path(out_dir: str, cell: SweepCell) -> str:
    return os.path.join(out_dir, "cells", f"{cell.key()}.json")


def write_cell_row(path: str, row: Dict) -> None:
    """Persist one result row as a checksummed, versioned envelope
    (``{_v, sha256, row}``) with atomic write-rename.  Readers verify the
    checksum and version, so a resumed sweep can never load a torn,
    corrupted, or cross-version row as if it were a completed cell."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = json.dumps(row, sort_keys=True)
    doc = {"_v": SWEEP_VERSION,
           "sha256": hashlib.sha256(payload.encode()).hexdigest(),
           "row": row}
    key = os.path.basename(path)
    tmp = path + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, sort_keys=True)
    faults.fire("cell.result.write", key)    # kill here = torn write
    os.replace(tmp, path)
    faults.corrupt("cell.result.artifact", path, key)


def load_cell_row(path: str) -> Tuple[Optional[Dict], str]:
    """Load a persisted cell row.  Returns ``(row, "ok")`` or ``(None,
    reason)`` with reason one of ``missing`` / ``corrupt`` (torn JSON,
    checksum mismatch, truncated file) / ``version`` (written by a
    different ``SWEEP_VERSION``, including pre-envelope flat rows)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        return None, "missing"
    except (ValueError, OSError, UnicodeDecodeError):
        return None, "corrupt"
    if not isinstance(doc, dict):
        return None, "corrupt"
    if doc.get("_v") != SWEEP_VERSION:
        return None, "version"
    row = doc.get("row")
    if not isinstance(row, dict):
        return None, "corrupt"
    payload = json.dumps(row, sort_keys=True)
    if hashlib.sha256(payload.encode()).hexdigest() != doc.get("sha256"):
        return None, "corrupt"
    return row, "ok"


def _read_json(path: str) -> Optional[Dict]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    return doc if isinstance(doc, dict) else None


def _write_json_atomic(path: str, doc: Dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, sort_keys=True)
    os.replace(tmp, path)


# -- retry / lease policy ----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ExecPolicy:
    """Knobs of the leased execution layer (env-overridable)."""

    max_attempts: int        # lease claims per cell before quarantine
    lease_ttl_s: float       # lease expiry for remote/unkillable owners
    backoff_base_s: float    # exponential backoff base between retries
    backoff_cap_s: float
    hb_timeout_s: float      # silent-worker termination threshold
    max_worker_restarts: int


def _env_num(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, default))
    except ValueError:
        return default


def _exec_policy(max_attempts: Optional[int] = None,
                 lease_ttl_s: Optional[float] = None) -> _ExecPolicy:
    return _ExecPolicy(
        max_attempts=int(max_attempts if max_attempts is not None
                         else _env_num("REPRO_SWEEP_MAX_ATTEMPTS", 4)),
        lease_ttl_s=float(lease_ttl_s if lease_ttl_s is not None
                          else _env_num("REPRO_SWEEP_LEASE_TTL", 300.0)),
        backoff_base_s=_env_num("REPRO_SWEEP_BACKOFF", 0.25),
        backoff_cap_s=30.0,
        # must exceed the slowest single cell (learned training included):
        # a heartbeat is written per cell attempt, not mid-cell
        hb_timeout_s=_env_num("REPRO_SWEEP_HB_TIMEOUT", 900.0),
        max_worker_restarts=int(_env_num("REPRO_SWEEP_MAX_RESTARTS", 16)),
    )


def _backoff_s(pol: _ExecPolicy, attempt: int) -> float:
    return min(pol.backoff_cap_s,
               pol.backoff_base_s * (2 ** max(attempt - 1, 0)))


# -- attempts ledger + quarantine -------------------------------------------

def _bump_attempts(path: str, error: Optional[str] = None) -> int:
    """Record one more lease claim (or a failure message) for a cell.
    Only ever called while holding the cell's lease, so the
    read-modify-write is single-writer; the write itself is atomic."""
    apath = path + ".attempts"
    doc = _read_json(apath) or {}
    doc["attempts"] = int(doc.get("attempts", 0)) + (0 if error else 1)
    errors = doc.get("errors")
    doc["errors"] = list(errors) if isinstance(errors, list) else []
    if error:
        doc["errors"].append(error)
    _write_json_atomic(apath, doc)
    return doc["attempts"]


def _quarantine_stub(cell: SweepCell, qdoc: Dict) -> Dict:
    """The placeholder row a quarantined cell contributes: the cell's
    identity columns, every stat None, and ``quarantined=True`` — the
    grid completes, but a quarantined cell can never read as covered."""
    row = cell.to_dict()
    row.pop("service_steps", None)
    for f in ROW_FIELDS:
        row.setdefault(f, None)
    row["retries"] = max(int(qdoc.get("attempts", 0)) - 1, 0)
    row["quarantined"] = True
    return row


def _attempt_cell(cell: SweepCell, out_dir: str,
                  cache_dir: Optional[str],
                  pol: _ExecPolicy) -> Tuple[str, Optional[Dict]]:
    """One non-blocking leased attempt at a cell.

    Returns ``(status, payload)``: ``("done", row)`` (computed now or
    found persisted), ``("quarantined", stub_row)``, ``("busy", None)``
    (a live owner holds the lease), or ``("retry", attempt_no)`` after a
    failure this process should back off from.  Crash-safe at every
    point: a SIGKILL leaves at most a stale lease (reclaimed via the
    dead-pid check) and a counted attempt."""
    path = _cell_path(out_dir, cell)
    row, reason = load_cell_row(path)
    if row is not None:
        return "done", row
    if reason in ("corrupt", "version"):
        quarantine_artifact(path, f"invalid persisted cell "
                            f"{cell.bench}/{cell.prefetcher} ({reason})")
    qdoc = _read_json(path + ".quarantine")
    if qdoc is not None:
        return "quarantined", _quarantine_stub(cell, qdoc)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    lease = path + ".lease"
    if not ft.try_acquire_lease(lease, pol.lease_ttl_s,
                                extra={"cell": cell.key()}):
        return "busy", None
    att = 0
    try:
        spent = int((_read_json(path + ".attempts") or {})
                    .get("attempts", 0))
        if spent >= pol.max_attempts:
            qdoc = _read_json(path + ".attempts") or {}
            qdoc.update(key=cell.key(), cell=cell.to_dict())
            _write_json_atomic(path + ".quarantine", qdoc)
            warnings.warn(
                f"cell {cell.bench}/{cell.prefetcher} "
                f"(eviction={cell.eviction}, frac={cell.device_frac}) "
                f"quarantined after {spent} attempts: "
                f"{qdoc.get('errors') or 'worker crashes'}",
                RuntimeWarning)
            return "quarantined", _quarantine_stub(cell, qdoc)
        att = _bump_attempts(path)
        faults.fire("cell.start", cell.key())
        row = simulate_cell(cell, cache_dir=cache_dir)
        row["retries"] = att - 1
        write_cell_row(path, row)
        return "done", row
    except Exception as e:
        _bump_attempts(path, error=repr(e))
        return "retry", att
    finally:
        ft.release_lease(lease)


def _run_cell_leased(i: int, cell: SweepCell, out_dir: str,
                     cache_dir: Optional[str],
                     pol: _ExecPolicy) -> Tuple[str, Dict]:
    """Drive one cell to resolution (result or quarantine), blocking
    through retries/backoff and foreign leases."""
    while True:
        status, payload = _attempt_cell(cell, out_dir, cache_dir, pol)
        if status in ("done", "quarantined"):
            return status, payload
        if status == "retry":
            time.sleep(_backoff_s(pol, payload))
        else:                                  # busy: foreign live owner
            time.sleep(min(0.2, max(pol.lease_ttl_s / 10, 0.01)))


# -- the lease worker pool ---------------------------------------------------

def _heartbeat(hb_dir: str, wid: int, done_n: int) -> None:
    try:
        _write_json_atomic(os.path.join(hb_dir, f"w{wid}.json"),
                           {"ts": time.time(), "pid": os.getpid(),
                            "done": done_n})
    except OSError:  # pragma: no cover - hb dir vanished
        pass


def _lease_worker_main(sys_path: List[str], cells: List[SweepCell],
                       out_dir: str, cache_dir: Optional[str],
                       pol: _ExecPolicy, wid: int, hb_dir: str) -> None:
    """A lease worker: loops over the whole grid claiming unleased,
    unfinished cells until every cell is resolved.  Any worker can run
    any cell, so crashed or slow peers never strand work; the rotated
    start offset keeps workers from contending on the same cells."""
    _init_worker(sys_path)
    n = len(cells)
    done = [False] * n
    rot = wid % max(n, 1)
    order = list(range(rot, n)) + list(range(rot))
    while not all(done):
        progressed = False
        for j in order:
            if done[j]:
                continue
            faults.fire("worker.loop", f"w{wid}")
            status, payload = _attempt_cell(cells[j], out_dir, cache_dir,
                                            pol)
            if status in ("done", "quarantined"):
                done[j] = True
                progressed = True
            elif status == "retry":
                progressed = True
                time.sleep(_backoff_s(pol, payload))
            _heartbeat(hb_dir, wid, sum(done))
        if not progressed:
            time.sleep(0.05)


def _mp_context():
    """fork is the cheap default, but forking a jax/XLA-initialized
    parent (e.g. benchmarks.run after training suites) inherits its
    thread/mutex state and can deadlock — use spawn in that case, unless
    __main__ is not re-importable (stdin/-c scripts), which spawn cannot
    handle.  Cells are pure functions of their spec, so results match
    the serial path either way."""
    main_file = getattr(sys.modules.get("__main__"), "__file__", None)
    spawn_ok = main_file is None or os.path.exists(main_file)
    method = "spawn" if ("jax" in sys.modules and spawn_ok) else "fork"
    try:
        return multiprocessing.get_context(method)
    except ValueError:  # pragma: no cover - non-POSIX
        return multiprocessing.get_context("spawn")


def _lease_pool(cells: Sequence[SweepCell], pending: List[int],
                out_dir: str, cache_dir: Optional[str], workers: int,
                pol: _ExecPolicy, record, verbose: bool) -> None:
    """Supervise a pool of lease workers over the pending cells.

    The parent never computes; it collects finished cell files into
    ``record`` and runs the failure-detection loop: a
    :class:`~repro.distributed.fault_tolerance.HeartbeatMonitor` tracks
    per-worker heartbeats — dead workers (SIGKILL, crash) are restarted
    up to a budget, silent-but-alive workers are terminated so their
    leases free up via the dead-pid reclaim.  If every worker exhausts
    its restart budget, the parent finishes the remainder serially
    (attempts are bounded, so that terminates — in quarantine at worst).
    """
    sub = [cells[i] for i in pending]
    ctx = _mp_context()
    hb_dir = os.path.join(out_dir, "hb")
    os.makedirs(hb_dir, exist_ok=True)
    monitor = ft.HeartbeatMonitor(timeout_s=pol.hb_timeout_s)
    n_workers = min(workers, len(sub))

    def _spawn(wid: int):
        p = ctx.Process(target=_lease_worker_main,
                        args=(list(sys.path), sub, out_dir, cache_dir,
                              pol, wid, hb_dir),
                        daemon=True)
        p.start()
        # grace window until the first beat; heartbeat files carry
        # time.time() stamps, so the monitor must live in wall-clock time
        monitor.beat(wid, 0.0, now=time.time())
        return p

    procs = {wid: _spawn(wid) for wid in range(n_workers)}
    restarts = {wid: 0 for wid in procs}
    last_hb: Dict[int, float] = {}
    unresolved = set(pending)
    try:
        while unresolved:
            for i in sorted(unresolved):
                path = _cell_path(out_dir, cells[i])
                row, _reason = load_cell_row(path)
                if row is not None:
                    record(i, row, persist=False)
                    unresolved.discard(i)
                    continue
                qdoc = _read_json(path + ".quarantine")
                if qdoc is not None:
                    record(i, _quarantine_stub(cells[i], qdoc),
                           persist=False)
                    unresolved.discard(i)
            if not unresolved:
                break
            now = time.time()
            for wid, p in procs.items():
                hb = _read_json(os.path.join(hb_dir, f"w{wid}.json"))
                if hb and isinstance(hb.get("ts"), (int, float)):
                    ts = float(hb["ts"])
                    if last_hb.get(wid) != ts:
                        monitor.beat(wid, ts - last_hb.get(wid, ts),
                                     now=ts)
                        last_hb[wid] = ts
                if p.is_alive() and wid in monitor.dead_hosts(now=now):
                    if verbose:
                        print(f"[sweep] worker {wid} silent for "
                              f">{pol.hb_timeout_s}s; terminating so its "
                              "lease frees up", flush=True)
                    p.terminate()
                    p.join(timeout=5)
                if not p.is_alive() and restarts[wid] \
                        < pol.max_worker_restarts:
                    restarts[wid] += 1
                    if verbose:
                        print(f"[sweep] worker {wid} died; restart "
                              f"{restarts[wid]}/{pol.max_worker_restarts}",
                              flush=True)
                    procs[wid] = _spawn(wid)
            if all(not p.is_alive() for p in procs.values()):
                for i in sorted(unresolved):
                    status, row = _run_cell_leased(
                        i, cells[i], out_dir, cache_dir, pol)
                    record(i, row, persist=False)
                unresolved.clear()
                break
            time.sleep(0.05)
    finally:
        for p in procs.values():
            if p.is_alive():
                p.terminate()
            p.join(timeout=10)


# ---------------------------------------------------------------------------
# orchestration: lane-batch scheduling, fan-out, persistence, resume
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _packable_prefetcher_names() -> Tuple[str, ...]:
    """Cheap pre-filter vocabulary for the lane scheduler, derived from
    the pallas backend's own packable-prefetcher set so extending the
    backend with new families automatically widens the filter."""
    from repro.uvm.backends.pallas_backend import PACKABLE_PREFETCHERS
    return tuple(n for n, t in _PREFETCHER_TYPES.items()
                 if t in PACKABLE_PREFETCHERS)


@functools.lru_cache(maxsize=1)
def _family_of_prefetcher_name() -> Dict[str, str]:
    """Lane-family kind per cell-spec prefetcher name, derived from the
    pallas backend's own type map so a new packable family automatically
    gets grouped by the scheduler (lane batches are family-homogeneous:
    processing cells family-by-family packs full batches instead of
    flushing a half-filled one at every family change)."""
    from repro.uvm.backends.pallas_backend import FAMILY_BY_TYPE
    return {n: FAMILY_BY_TYPE[t] for n, t in _PREFETCHER_TYPES.items()
            if t in FAMILY_BY_TYPE}


def _wants_lanes(cell: SweepCell) -> bool:
    """True when this cell's backend chain starts at the pallas lanes (an
    explicit ``backend="pallas"`` or ``auto`` on an accelerator host) and
    its prefetcher can be packed at all — anything else skips
    trace/prefetcher preparation and goes straight to the per-cell path."""
    return (cell.engine in ("auto", "vectorized")
            and cell.prefetcher in _packable_prefetcher_names()
            and backend_chain(cell.backend)[0] == "pallas")


def _run_lane_batches(cells: Sequence[SweepCell],
                      cache_dir: Optional[str],
                      verbose: bool = False) -> Dict[int, Dict]:
    """Replay the pallas-eligible subset of ``cells`` as multi-lane batches.

    Returns ``{position: row}`` for every cell that was packed into a
    lane.  Cells are visited family-by-family (lane batches must be
    family-homogeneous — ``fits_batch`` refuses to co-bucket two
    prefetcher families, so interleaved families would flush half-empty
    batches).

    Execution is a **pipeline** of overlapping stages (diagrammed in
    ``repro/uvm/backends/README.md``, "Sweep pipeline"):

    * *prepare* — trace generation/deserialization and predcache
      inference run in a small thread pool a bounded lookahead window
      ahead of the batcher (``REPRO_SWEEP_PREP_THREADS`` /
      ``REPRO_SWEEP_PREP_WINDOW``); the trace memo means co-scheduled
      cells sharing a trace resolve to one deserialize + one checksum.
    * *pack* — the main thread consumes prepared cells **in scheduler
      order** (results stay deterministic) and packs lanes under
      ``fits_batch``'s budgets, exactly as before.
    * *flush* — each full batch replays on a small flush pool while the
      main thread packs the next one.  At most ``REPRO_SWEEP_FLUSH_THREADS``
      batches (default 2 — independent policy/family batches parallelize
      across cores, XLA releases the GIL) are in flight plus one being
      packed, so batch residency stays O(1) and the whole grid is never
      materialized — the bounded-memory property of the serial scheduler
      survives (set the knob to 1 for strict one-in-flight residency),
      shrunk further by the trace memo sharing Trace objects across
      lanes.

    Serve cells carry their decode-step bounds into the lane request, so
    the kernel emits per-step clocks in-band and the row's SLO columns
    are pure percentile math (``slo_source="kernel"``) — no NumPy
    side-pass replay unless ``REPRO_SERVE_CHECK=1`` asks for the
    differential check.

    Cells the backend declines (span too large, empty trace, ...) are
    left out of the result and flow back to the per-cell pool path,
    which keeps the ``--workers`` fan-out for them.  A runtime failure
    of a lane batch (experimental-backend lowering faults) degrades its
    cells to the NumPy path inline, with a warning; their rows record
    the backend that actually ran.  A ``TransientBackendFault``
    propagates out of the flush future and aborts the scheduler — the
    PR 7 contract (crash the driver, retry on the same backend after
    resume) is preserved across the thread boundary.
    """
    from repro.uvm.backends.pallas_backend import _lane_shape

    backend = get_backend("pallas")
    rows: Dict[int, Dict] = {}
    batch: List[int] = []
    requests: List[ReplayRequest] = []
    caps: List[Optional[int]] = []
    # (family, policy, length, span) per queued lane — the family/policy
    # elements make fits_batch refuse to co-bucket families or policies
    shapes: List[Tuple[str, str, int, int]] = []

    def _replay_batch_rows(b: List[int], reqs: List[ReplayRequest],
                           cps: List[Optional[int]]) -> Dict[int, Dict]:
        """Flush-stage body (runs on the flush thread): replay one packed
        batch and assemble its rows."""
        t0 = time.time()
        try:
            stats = backend.replay(list(reqs))
        except TransientBackendFault:
            # retryable by contract: degrading would permanently change
            # the rows' backend column, so let the driver crash and the
            # resumed run replay these cells on the same backend
            raise
        except Exception as e:  # pragma: no cover - backend runtime faults
            warnings.warn(f"pallas lane batch failed at runtime ({e!r}); "
                          "replaying the affected cells on the NumPy path",
                          RuntimeWarning)
            stats = [replay_dispatch(r, "numpy") for r in reqs]
        per_cell = (time.time() - t0) / len(b)
        out: Dict[int, Dict] = {}
        for i, st, cap, req in zip(b, stats, cps, reqs):
            row = _finish_row(cells[i], st, cap, per_cell)
            if req.trace.meta and "serve" in req.trace.meta:
                row.update(_serve_latency_row(cells[i], req.trace,
                                              req.config, st, cache_dir))
            elif _is_mt_trace(req.trace):
                row.update(_mt_row(cells[i], req.trace, req.config, st,
                                   cap, cache_dir))
            out[i] = row
        return out

    n_flush = max(1, int(_env_num("REPRO_SWEEP_FLUSH_THREADS", 2)))
    flush_pool = ThreadPoolExecutor(max_workers=n_flush)
    inflight: collections.deque = collections.deque()   # FIFO of futures

    def _await_inflight(room: int = 0) -> None:
        """Drain flush futures (oldest first) until at most ``room`` are
        still in flight; re-raises their failures in the main thread."""
        while len(inflight) > room:
            rows.update(inflight.popleft().result())

    def _flush() -> None:
        if not batch:
            return
        if verbose:
            print(f"[sweep] pallas lanes: replaying {len(batch)} cells "
                  "in one batch", flush=True)
        faults.fire("lane.flush", f"{len(batch)}:{cells[batch[0]].key()}")
        _await_inflight(room=n_flush - 1)    # bounded batches in flight
        inflight.append(flush_pool.submit(
            _replay_batch_rows, list(batch), list(requests), list(caps)))
        batch.clear()
        requests.clear()
        caps.clear()
        shapes.clear()

    families = _family_of_prefetcher_name()
    # family- AND policy-major order: lane batches are homogeneous in
    # both, so interleaved cells would flush half-filled batches
    order = sorted(range(len(cells)),
                   key=lambda i: (families.get(cells[i].prefetcher, "~"),
                                  cells[i].eviction, i))

    n_prep = max(1, int(_env_num("REPRO_SWEEP_PREP_THREADS", 4)))
    prep_window = max(1, int(_env_num("REPRO_SWEEP_PREP_WINDOW", 32)))
    prep_pool = ThreadPoolExecutor(max_workers=n_prep)
    pending = collections.deque()            # (i, future) in scheduler order
    feed = iter(order)

    def _top_up() -> None:
        while len(pending) < prep_window:
            try:
                i = next(feed)
            except StopIteration:
                return
            pending.append((i, prep_pool.submit(
                prepare_cell, cells[i], cache_dir=cache_dir)))

    try:
        _top_up()
        while pending:
            i, fut = pending.popleft()
            trace, config, prefetcher, pages = fut.result()
            _top_up()                        # keep the lookahead full
            req = ReplayRequest(trace, prefetcher, config,
                                step_bounds=_step_bounds(trace))
            if not backend.can_replay(req):
                continue                     # back to the per-cell pool path
            shape = _lane_shape(req)
            if not backend.fits_batch(shapes, shape):
                _flush()
            batch.append(i)
            requests.append(req)
            caps.append(pages)
            shapes.append(shape)
        _flush()
        _await_inflight(room=0)
    finally:
        for _, fut in pending:
            fut.cancel()
        prep_pool.shutdown(wait=True)
        flush_pool.shutdown(wait=True)
    return rows


def run_sweep(cells: Sequence[SweepCell], *, out_dir: Optional[str] = None,
              workers: int = 1, resume: bool = True,
              cache_dir: Optional[str] = None,
              verbose: bool = False,
              write_aggregate: bool = True,
              max_attempts: Optional[int] = None,
              lease_ttl_s: Optional[float] = None) -> List[Dict]:
    """Run a grid of cells; returns rows in the order of ``cells``.

    With ``out_dir``, each completed cell is persisted under
    ``out_dir/cells/<key>.json`` as a checksummed envelope (and skipped on
    resume; a truncated/corrupt/cross-version cell file is quarantined to
    ``<key>.json.corrupt`` with a warning and the cell requeued), cells
    execute under crash-reclaimable leases with bounded retries (cells
    still failing after ``max_attempts`` lease claims land in
    ``out_dir/quarantine.json`` and contribute a ``quarantined=True`` stub
    row instead of aborting the grid), and aggregate ``results.json`` /
    ``results.csv`` are (re)written at the end.  Callers sharing one
    ``out_dir`` across several grids should pass ``write_aggregate=False``
    so the aggregate files never reflect a partial grid.
    """
    if cache_dir is None and out_dir is not None:
        cache_dir = os.path.join(out_dir, "trace_cache")
    pol = _exec_policy(max_attempts, lease_ttl_s)
    rows: Dict[int, Dict] = {}
    pending: List[int] = []
    for i, cell in enumerate(cells):
        if out_dir:
            path = _cell_path(out_dir, cell)
            if resume:
                row, reason = load_cell_row(path)
                if row is not None:
                    rows[i] = row
                    continue
                if reason in ("corrupt", "version"):
                    quarantine_artifact(
                        path, f"resume: invalid cell file for "
                        f"{cell.bench}/{cell.prefetcher} ({reason}); "
                        "requeueing")
                qdoc = _read_json(path + ".quarantine")
                if qdoc is not None:
                    rows[i] = _quarantine_stub(cell, qdoc)
                    continue
            else:
                # a fresh (non-resumed) run must not inherit results,
                # attempt counts, or quarantine verdicts from earlier
                # runs — the leased executor would short-circuit on them
                for suffix in ("", ".quarantine", ".attempts"):
                    try:
                        os.unlink(path + suffix)
                    except OSError:
                        pass
        pending.append(i)

    def _record(i: int, row: Dict, persist: bool = True) -> None:
        rows[i] = row
        if out_dir and persist:
            write_cell_row(_cell_path(out_dir, cells[i]), row)
        if verbose:
            if row.get("quarantined"):
                print(f"[sweep] {row['bench']}/{row['prefetcher']}"
                      f" frac={row.get('device_frac')} QUARANTINED"
                      f" after {row.get('retries')} retries", flush=True)
            else:
                print(f"[sweep] {row['bench']}/{row['prefetcher']}"
                      f" frac={row.get('device_frac')}"
                      f" backend={row.get('backend')}"
                      f" hit={row['hit_rate']:.3f} ipc={row['ipc']:.2f}"
                      f" ({row['seconds']:.2f}s)", flush=True)

    # lane-batch scheduler: pack pallas-bound cells into multi-lane kernel
    # launches in the parent process (they are already batched — worker
    # fan-out would only serialize them again); whatever the backend
    # declines falls back to the per-cell path below
    lane_pending = [i for i in pending if _wants_lanes(cells[i])]
    if lane_pending:
        lane_rows = _run_lane_batches([cells[i] for i in lane_pending],
                                      cache_dir, verbose=verbose)
        for j, row in lane_rows.items():
            _record(lane_pending[j], row)
        handled = {lane_pending[j] for j in lane_rows}
        pending = [i for i in pending if i not in handled]

    if pending and out_dir:
        # leased execution: every cell resolves to a persisted result or
        # a quarantine verdict, whatever crashes along the way
        if workers > 1:
            _lease_pool(cells, pending, out_dir, cache_dir, workers, pol,
                        _record, verbose)
        else:
            for i in pending:
                status, row = _run_cell_leased(i, cells[i], out_dir,
                                               cache_dir, pol)
                _record(i, row, persist=False)
    elif pending and workers > 1:
        ctx = _mp_context()
        with ctx.Pool(min(workers, len(pending)), initializer=_init_worker,
                      initargs=(list(sys.path),)) as pool:
            args = [(cells[i], cache_dir) for i in pending]
            for i, row in zip(pending, pool.imap(_worker, args)):
                _record(i, row)
    else:
        for i in pending:
            _record(i, simulate_cell(cells[i], cache_dir=cache_dir))

    out = [rows[i] for i in range(len(cells))]
    if out_dir and write_aggregate:
        write_results(out, out_dir)
        _write_json_atomic(
            os.path.join(out_dir, "quarantine.json"),
            {"cells": [q for q in
                       (_read_json(_cell_path(out_dir, c) + ".quarantine")
                        for c in cells) if q is not None]})
    return out


# ---------------------------------------------------------------------------
# structured results
# ---------------------------------------------------------------------------

def write_results(rows: List[Dict], out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "results.json"), "w") as f:
        json.dump({"rows": rows}, f, indent=1, sort_keys=True)
        f.write("\n")
    with open(os.path.join(out_dir, "results.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=ROW_FIELDS, extrasaction="ignore")
        w.writeheader()
        for row in rows:
            w.writerow(row)


def read_results(out_dir: str) -> List[Dict]:
    """Read the aggregate rows.  A missing or corrupt aggregate falls
    back to scanning the per-cell store (checksum-valid, current-version
    cells only) with a warning, so one torn ``results.json`` never loses
    a finished grid."""
    try:
        with open(os.path.join(out_dir, "results.json")) as f:
            doc = json.load(f)
        rows = doc["rows"]
        if not isinstance(rows, list):
            raise ValueError("aggregate rows is not a list")
        return rows
    except (OSError, ValueError, KeyError, TypeError) as e:
        cell_dir = os.path.join(out_dir, "cells")
        if not os.path.isdir(cell_dir):
            raise
        warnings.warn(f"aggregate results.json unreadable ({e!r}); "
                      "rebuilding from the per-cell store", RuntimeWarning)
        rows = []
        for fname in sorted(os.listdir(cell_dir)):
            if not fname.endswith(".json"):
                continue
            row, reason = load_cell_row(os.path.join(cell_dir, fname))
            if row is not None:
                rows.append(row)
        return rows


def read_results_csv(path: str) -> List[Dict]:
    """CSV round-trip: numeric columns come back as numbers."""
    out = []
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            parsed: Dict = {}
            for k, v in row.items():
                if v == "" or v == "None":
                    parsed[k] = None
                    continue
                if v in ("True", "False"):
                    parsed[k] = v == "True"
                    continue
                try:
                    fv = float(v)
                    parsed[k] = int(fv) if fv.is_integer() and "." not in v \
                        else fv
                except ValueError:
                    parsed[k] = v
            out.append(parsed)
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Batched UVM sweep: (trace x prefetcher x config) grid")
    ap.add_argument("--benches", default="ATAX,BICG,Pathfinder,Hotspot")
    ap.add_argument("--prefetchers", default="none,tree,oracle",
                    help=f"comma list from {','.join(PREFETCHERS)}")
    ap.add_argument("--scales", default="1.0")
    ap.add_argument("--windows", default="0.6")
    ap.add_argument("--prediction-us", default="1.0")
    ap.add_argument("--device-fracs", default="",
                    help="e.g. '0.5,0.75' (empty = no oversubscription)")
    ap.add_argument("--capacity-splits", default="",
                    help="multi-tenant capacity splits for '<A>+<B>' "
                         "benches, e.g. 'shared,0.5/0.5,0.4/0.4' "
                         "(empty = shared capacity)")
    ap.add_argument("--evictions", default="lru",
                    help="eviction policies under oversubscription, comma "
                         f"list from {','.join(EVICTION_POLICIES)} or "
                         f"'{adaptive.ADAPTIVE_POLICY}' (resolved per cell "
                         "at prepare time; rows record the concrete policy)")
    ap.add_argument("--model-families", default="simplified",
                    help="predictor families for learned cells, comma list "
                         f"from {','.join(MODEL_FAMILIES)}")
    ap.add_argument("--scenario", default=None,
                    help="expand a named scenario from "
                         "repro.uvm.scenarios (e.g. 'oversub-full': the "
                         "full 11-benchmark x ratio x eviction-policy x "
                         "prefetcher matrix) instead of the grid flags; "
                         "--engine/--backend/--out/--workers still apply "
                         "and completed cells resume as usual")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "vectorized", "legacy"])
    ap.add_argument("--backend", default=None, choices=list(BACKENDS),
                    help="replay backend: numpy, pallas (multi-lane "
                         "kernel batches), or auto (pallas only where "
                         "the lanes compile natively — TPU, or "
                         "REPRO_PALLAS_COMPILE=1 on other accelerators; "
                         "numpy otherwise); defaults to "
                         "$REPRO_SWEEP_BACKEND or auto")
    ap.add_argument("--out", default=None, help="results directory")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args(argv)

    from repro.traces.generators import BENCHMARKS
    backend = args.backend or os.environ.get("REPRO_SWEEP_BACKEND", "auto")
    if backend not in BACKENDS:
        ap.error(f"unknown backend {backend!r}; "
                 f"choose from {','.join(BACKENDS)}")
    if args.scenario:
        from repro.uvm.scenarios import available_scenarios, expand_scenario
        try:
            cells = expand_scenario(args.scenario, engine=args.engine,
                                    backend=backend)
        except KeyError:
            ap.error(f"unknown scenario {args.scenario!r}; choose from "
                     f"{','.join(available_scenarios())}")
        print(f"[sweep] scenario {args.scenario!r}: {len(cells)} cells")
    else:
        benches = args.benches.split(",")
        pfs = args.prefetchers.split(",")
        bad = [p for p in pfs if p not in PREFETCHERS]
        if bad:
            ap.error(f"unknown prefetcher(s) {','.join(bad)}; "
                     f"choose from {','.join(PREFETCHERS)}")
        from repro.offload.serve_trace import SERVE_WORKLOADS, is_serve_bench
        from repro.traces.interleave import is_mt_bench
        bad = [b for b in benches
               if b not in BENCHMARKS and not is_serve_bench(b)
               and not is_mt_bench(b)]
        if bad:
            ap.error(f"unknown benchmark(s) {','.join(bad)}; "
                     f"choose from {','.join(sorted(BENCHMARKS))}, "
                     "multi-tenant pairs like ATAX+Pathfinder, or serve "
                     f"workloads {','.join(sorted(SERVE_WORKLOADS))} "
                     "(rate variants like ServeBursty@r128 accepted)")
        splits: List[Optional[str]] = [None]
        if args.capacity_splits:
            splits = list(args.capacity_splits.split(","))
            for s in splits:
                try:
                    parse_capacity_split(s)
                except ValueError as e:
                    ap.error(str(e))
            mt_less = [b for b in benches if not is_mt_bench(b)]
            if mt_less and any(parse_capacity_split(s) for s in splits):
                ap.error(f"--capacity-splits needs multi-tenant benches; "
                         f"{','.join(mt_less)} are single-tenant")
        evictions = args.evictions.split(",")
        ev_vocab = EVICTION_POLICIES + (adaptive.ADAPTIVE_POLICY,)
        bad = [e for e in evictions if e not in ev_vocab]
        if bad:
            ap.error(f"unknown eviction policy(ies) {','.join(bad)}; "
                     f"choose from {','.join(ev_vocab)}")
        model_families = args.model_families.split(",")
        bad = [m for m in model_families if m not in MODEL_FAMILIES]
        if bad:
            ap.error(f"unknown model family(ies) {','.join(bad)}; "
                     f"choose from {','.join(MODEL_FAMILIES)}")
        fracs: List[Optional[float]] = [None]
        if args.device_fracs:
            fracs += [float(x) for x in args.device_fracs.split(",")]
        cells = expand_grid(
            benches, pfs,
            scales=[float(x) for x in args.scales.split(",")],
            windows=[None if x == "full" else float(x)
                     for x in args.windows.split(",")],
            prediction_us=[float(x) for x in args.prediction_us.split(",")],
            device_fracs=fracs, evictions=evictions,
            model_families=model_families, capacity_splits=splits,
            engine=args.engine, backend=backend)
    t0 = time.time()
    rows = run_sweep(cells, out_dir=args.out, workers=args.workers,
                     resume=not args.no_resume, verbose=True)
    dt = time.time() - t0
    n_quar = sum(1 for r in rows if r.get("quarantined"))
    print(f"\n{len(rows)} cells in {dt:.1f}s "
          f"({sum(r['n_accesses'] or 0 for r in rows) / max(dt, 1e-9) / 1e6:.2f}"
          " M accesses/s aggregate)"
          + (f" [{n_quar} QUARANTINED - see quarantine.json]"
             if n_quar else ""))
    cols = ["bench", "prefetcher", "device_frac", "eviction", "backend",
            "hit_rate", "ipc", "unity"]
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4f}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))


if __name__ == "__main__":
    main()
