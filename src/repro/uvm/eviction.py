"""Pluggable eviction policies for the UVM replay stack.

The paper's oversubscription results hinge on how the device frees pages
when capacity runs out; "An Intelligent Framework for Oversubscription
Management in CPU-GPU Unified Memory" (arXiv 2204.02974) shows the choice
of policy (LRU vs. random vs. access-pattern-aware) swings oversubscribed
performance by double digits.  This module defines the policy vocabulary
shared by every replay backend:

* ``lru`` — least-recently-used, the historical behavior (the legacy
  ``OrderedDict`` order / the monotone touch-stamp argmin).  Default;
  golden fixtures recorded before the policy axis replay bit-identically.
* ``random`` — counter-based deterministic pseudo-random replacement: a
  page draws a 32-bit priority from :func:`eviction_scores` **at insertion
  time** (the draw is the monotone insert/touch counter, so re-insertions
  draw fresh priorities), and the victim is the resident page with the
  smallest ``(priority, page)``.  Deterministic, seedless, and identical
  across backends: the legacy loop hashes Python ints, the NumPy engine
  hashes ``uint32`` arrays, and the pallas kernel replays the same mixer
  in ``jnp.uint32`` — all three wrap mod 2**32 by construction.
* ``hotcold`` — access-frequency (cold-first) replacement per 2204.02974:
  each resident page counts its touches since migration; the victim is
  the resident page with the smallest ``(frequency, LRU-stamp)`` — the
  coldest page, ties broken least-recently-used.  Prefetched-but-unused
  pages (frequency 0) are evicted first, which is exactly the
  access-pattern-aware intuition.

Every backend must agree on the *victim sequence* (pinned by the golden
and differential suites): the policy semantics here — including the
in-flight-victim rule (a selected victim that has not arrived yet is
spared, retouched at MRU, and the eviction round stops) and the event
counter (one tick per page insert and per resident touch, shared with the
LRU stamps) — are the single source of truth.

The scalar/array scorer below is the reference for the ``random`` mixer;
``pallas_backend`` re-implements the identical operation chain in jnp and
the test suites pin the equality.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

import numpy as np

#: policy vocabulary, in CLI/registry order (``lru`` is the default and
#: must stay first: code that predates the policy axis assumes it)
EVICTION_POLICIES = ("lru", "random", "hotcold")

_MASK32 = 0xFFFFFFFF
#: mixer constants (32-bit finalizer, low-bias): the jnp re-implementation
#: in ``pallas_backend._rand_score`` must use the exact same chain
SCORE_SEED_MULT = 0x9E3779B9
SCORE_MULT_1 = 0x21F0AAAD
SCORE_MULT_2 = 0x735A2D97


def validate_policy(name: str) -> str:
    if name not in EVICTION_POLICIES:
        raise ValueError(f"unknown eviction policy {name!r}; "
                         f"choose from {', '.join(EVICTION_POLICIES)}")
    return name


def eviction_scores(pages, draw) -> np.ndarray:
    """uint32 priority per page for the ``random`` policy.

    ``pages`` are absolute page ids (truncated mod 2**32); ``draw`` is the
    per-page insert-event counter value (scalar or array).  All arithmetic
    wraps mod 2**32 — NumPy array ops wrap silently, and the seeds are
    pre-masked Python ints so no scalar-overflow warnings fire.
    """
    x = (np.asarray(pages, dtype=np.int64) & _MASK32).astype(np.uint32)
    # at-least-1d operands: NumPy *array* integer ops wrap silently, but
    # scalar ops would raise overflow RuntimeWarnings
    d = np.atleast_1d(
        (np.asarray(draw, dtype=np.int64) & _MASK32)).astype(np.uint32)
    x = np.atleast_1d(x) ^ (d * np.uint32(SCORE_SEED_MULT))
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(SCORE_MULT_1)
    x = x ^ (x >> np.uint32(15))
    x = x * np.uint32(SCORE_MULT_2)
    x = x ^ (x >> np.uint32(15))
    return x


def eviction_score(page: int, draw: int) -> int:
    """Scalar :func:`eviction_scores` as a pure-int mixer — this sits in
    the per-insert hot path of both the legacy loop and the NumPy
    engine's ``random`` policy, where one-element array round trips cost
    more than the hash itself.  ``tests/test_scenarios.py`` pins it equal
    to the array version."""
    x = (int(page) & _MASK32) ^ ((int(draw) * SCORE_SEED_MULT) & _MASK32)
    x ^= x >> 16
    x = (x * SCORE_MULT_1) & _MASK32
    x ^= x >> 15
    x = (x * SCORE_MULT_2) & _MASK32
    x ^= x >> 15
    return x


# ---------------------------------------------------------------------------
# reference policy objects (the legacy per-access loop drives these; the
# NumPy and pallas engines replay the same semantics vectorized)
# ---------------------------------------------------------------------------

class EvictionPolicy:
    """Victim-selection strategy for the legacy simulator.

    The simulator calls, only when ``device_pages`` is set:

    * ``on_insert(page)`` — page became resident (demand fault or
      prefetch).  Idempotent for already-resident pages (matches the
      engines, which never re-draw state for an overwrite).
    * ``on_touch(page)`` — resident page touched (hit/late access, or an
      in-flight victim spared by the eviction loop and retouched at MRU).
    * ``on_evict(page)`` — page left residency.
    * ``select_victim(resident)`` — the next victim among the keys of
      ``resident`` (the simulator's page → arrival ``OrderedDict``, kept
      in exact LRU order by the access loop).

    The event counter (one tick per insert and per touch) is shared
    vocabulary with the vectorized engines' LRU touch stamps — policies
    that consume it (random draws, hotcold tie-breaks) stay identical
    across backends because every backend ticks it on the same events.
    """

    name = "abstract"

    def reset(self) -> None:
        pass

    def on_insert(self, page: int) -> None:
        pass

    def on_touch(self, page: int) -> None:
        pass

    def on_evict(self, page: int) -> None:
        pass

    def select_victim(self, resident) -> int:
        raise NotImplementedError


class LRUEviction(EvictionPolicy):
    """Least-recently-used: the simulator's ``resident`` OrderedDict *is*
    the LRU order (every touch moves to end), so the victim is simply its
    first key — exactly the historical ``popitem(last=False)``."""

    name = "lru"

    def select_victim(self, resident) -> int:
        return next(iter(resident))


class RandomEviction(EvictionPolicy):
    """Counter-based deterministic pseudo-random replacement.

    Each page draws ``eviction_score(page, counter)`` when it becomes
    resident (so re-insertions re-draw), and the victim is the resident
    page minimizing ``(priority, page)``.  Priorities are static while
    resident, so selection is a lazy min-heap: stale entries (evicted or
    re-drawn pages) self-heal at pop time.
    """

    name = "random"

    def reset(self) -> None:
        self.counter = 0
        self.prio: Dict[int, int] = {}
        self.heap: List[Tuple[int, int]] = []

    def on_insert(self, page: int) -> None:
        if page in self.prio:
            return
        pr = eviction_score(page, self.counter)
        self.prio[page] = pr
        heapq.heappush(self.heap, (pr, page))
        self.counter += 1

    def on_touch(self, page: int) -> None:
        self.counter += 1

    def on_evict(self, page: int) -> None:
        del self.prio[page]

    def select_victim(self, resident) -> int:
        while True:
            pr, page = self.heap[0]
            if self.prio.get(page) != pr:
                heapq.heappop(self.heap)     # evicted or re-drawn: stale
                continue
            return page


class HotColdEviction(EvictionPolicy):
    """Access-frequency (cold-first) replacement per arXiv 2204.02974.

    ``freq[page]`` counts touches since the page migrated (0 at insert:
    prefetched-but-unused pages are the coldest); the victim minimizes
    ``(freq, stamp)`` — stamps are the shared monotone touch counter, so
    frequency ties resolve least-recently-used.  Lazy min-heap: keys only
    grow while resident, so stale entries re-push and self-heal.
    """

    name = "hotcold"

    def reset(self) -> None:
        self.counter = 0
        self.freq: Dict[int, int] = {}
        self.stamp: Dict[int, int] = {}
        self.heap: List[Tuple[int, int, int]] = []

    def on_insert(self, page: int) -> None:
        if page in self.stamp:
            return
        self.freq[page] = 0
        self.stamp[page] = self.counter
        heapq.heappush(self.heap, (0, self.counter, page))
        self.counter += 1

    def on_touch(self, page: int) -> None:
        if page in self.stamp:
            self.freq[page] += 1
            self.stamp[page] = self.counter
        self.counter += 1

    def on_evict(self, page: int) -> None:
        del self.freq[page]
        del self.stamp[page]

    def select_victim(self, resident) -> int:
        while True:
            f, s, page = self.heap[0]
            cur = self.stamp.get(page)
            if cur is None:                  # evicted: drop the entry
                heapq.heappop(self.heap)
                continue
            if (self.freq[page], cur) != (f, s):
                heapq.heapreplace(self.heap, (self.freq[page], cur, page))
                continue
            return page


def make_eviction_policy(name: str) -> EvictionPolicy:
    validate_policy(name)
    policy = {"lru": LRUEviction, "random": RandomEviction,
              "hotcold": HotColdEviction}[name]()
    policy.reset()
    return policy
