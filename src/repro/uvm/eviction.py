"""Pluggable eviction policies for the UVM replay stack.

The paper's oversubscription results hinge on how the device frees pages
when capacity runs out; "An Intelligent Framework for Oversubscription
Management in CPU-GPU Unified Memory" (arXiv 2204.02974) shows the choice
of policy (LRU vs. random vs. access-pattern-aware) swings oversubscribed
performance by double digits.  This module defines the policy vocabulary
shared by every replay backend:

* ``lru`` — least-recently-used, the historical behavior (the legacy
  ``OrderedDict`` order / the monotone touch-stamp argmin).  Default;
  golden fixtures recorded before the policy axis replay bit-identically.
* ``random`` — counter-based deterministic pseudo-random replacement: a
  page draws a 32-bit priority from :func:`eviction_scores` **at insertion
  time** (the draw is the monotone insert/touch counter, so re-insertions
  draw fresh priorities), and the victim is the resident page with the
  smallest ``(priority, page)``.  Deterministic, seedless, and identical
  across backends: the legacy loop hashes Python ints, the NumPy engine
  hashes ``uint32`` arrays, and the pallas kernel replays the same mixer
  in ``jnp.uint32`` — all three wrap mod 2**32 by construction.
* ``hotcold`` — access-frequency (cold-first) replacement per 2204.02974:
  each resident page counts its touches since migration; the victim is
  the resident page with the smallest ``(frequency, LRU-stamp)`` — the
  coldest page, ties broken least-recently-used.  Prefetched-but-unused
  pages (frequency 0) are evicted first, which is exactly the
  access-pattern-aware intuition.

Every backend must agree on the *victim sequence* (pinned by the golden
and differential suites): the policy semantics here — including the
in-flight-victim rule (a selected victim that has not arrived yet is
spared, retouched at MRU, and the eviction round stops) and the event
counter (one tick per page insert and per resident touch, shared with the
LRU stamps) — are the single source of truth.

The scalar/array scorer below is the reference for the ``random`` mixer;
``pallas_backend`` re-implements the identical operation chain in jnp and
the test suites pin the equality.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

#: policy vocabulary, in CLI/registry order (``lru`` is the default and
#: must stay first: code that predates the policy axis assumes it)
EVICTION_POLICIES = ("lru", "random", "hotcold")

_MASK32 = 0xFFFFFFFF
#: mixer constants (32-bit finalizer, low-bias): the jnp re-implementation
#: in ``pallas_backend._rand_score`` must use the exact same chain
SCORE_SEED_MULT = 0x9E3779B9
SCORE_MULT_1 = 0x21F0AAAD
SCORE_MULT_2 = 0x735A2D97


def validate_policy(name: str) -> str:
    if name not in EVICTION_POLICIES:
        raise ValueError(f"unknown eviction policy {name!r}; "
                         f"choose from {', '.join(EVICTION_POLICIES)}")
    return name


def eviction_scores(pages, draw) -> np.ndarray:
    """uint32 priority per page for the ``random`` policy.

    ``pages`` are absolute page ids (truncated mod 2**32); ``draw`` is the
    per-page insert-event counter value (scalar or array).  All arithmetic
    wraps mod 2**32 — NumPy array ops wrap silently, and the seeds are
    pre-masked Python ints so no scalar-overflow warnings fire.
    """
    x = (np.asarray(pages, dtype=np.int64) & _MASK32).astype(np.uint32)
    # at-least-1d operands: NumPy *array* integer ops wrap silently, but
    # scalar ops would raise overflow RuntimeWarnings
    d = np.atleast_1d(
        (np.asarray(draw, dtype=np.int64) & _MASK32)).astype(np.uint32)
    x = np.atleast_1d(x) ^ (d * np.uint32(SCORE_SEED_MULT))
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(SCORE_MULT_1)
    x = x ^ (x >> np.uint32(15))
    x = x * np.uint32(SCORE_MULT_2)
    x = x ^ (x >> np.uint32(15))
    return x


def eviction_score(page: int, draw: int) -> int:
    """Scalar :func:`eviction_scores` as a pure-int mixer — this sits in
    the per-insert hot path of both the legacy loop and the NumPy
    engine's ``random`` policy, where one-element array round trips cost
    more than the hash itself.  ``tests/test_scenarios.py`` pins it equal
    to the array version."""
    x = (int(page) & _MASK32) ^ ((int(draw) * SCORE_SEED_MULT) & _MASK32)
    x ^= x >> 16
    x = (x * SCORE_MULT_1) & _MASK32
    x ^= x >> 15
    x = (x * SCORE_MULT_2) & _MASK32
    x ^= x >> 15
    return x


# ---------------------------------------------------------------------------
# tenancy: per-tenant capacity partitioning for multi-tenant traces
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Tenancy:
    """Resolved tenancy of one replay: the page-region boundary of a
    multi-tenant trace (``repro.traces.interleave``) plus the optional
    hard quotas partitioning device capacity.

    ``quotas=None`` is **shared mode**: both tenants contend for the whole
    device exactly like the single-tenant model (per-tenant stats are
    still recorded — it is the interference-allowed baseline the isolation
    property test contrasts against).  With quotas ``(q0, q1)``, tenant
    ``t`` owns ``q_t`` pages outright and may additionally borrow from the
    ``spill`` pool (``device_pages - q0 - q1``) whatever the co-tenant is
    not currently borrowing — so victim selection for tenant ``t`` is
    masked to tenant ``t``'s own resident pages, and a thrashing co-tenant
    can never evict a quota-protected tenant's pages.
    """

    boundary: int                          # first page of tenant 1's region
    quotas: Optional[Tuple[int, int]]      # hard per-tenant quotas, or None
    spill: int                             # shared pool beyond the quotas

    @property
    def split(self) -> bool:
        return self.quotas is not None

    def tenant_of(self, page: int) -> int:
        return 1 if page >= self.boundary else 0

    def allowed(self, rc0: int, rc1: int) -> Tuple[int, int]:
        """Per-tenant residency ceilings given current residencies: quota
        plus whatever spill the co-tenant has not borrowed.  The pallas
        kernel re-implements this arithmetic in int32; the differential
        suite pins the equality."""
        q0, q1 = self.quotas
        a0 = q0 + max(0, self.spill - max(0, rc1 - q1))
        a1 = q1 + max(0, self.spill - max(0, rc0 - q0))
        return a0, a1


def resolve_tenancy(trace, config) -> Optional[Tenancy]:
    """The single tenancy-validation chokepoint shared by all three
    backends: returns None for a plain single-tenant replay, a
    :class:`Tenancy` for a multi-tenant trace, and raises on inconsistent
    requests (quotas without a multi-tenant trace, quotas without a
    capacity, quotas exceeding the capacity)."""
    from repro.traces.interleave import tenant_boundary
    boundary = tenant_boundary(trace)
    tp = getattr(config, "tenant_pages", None)
    if tp is None:
        if boundary is None:
            return None
        return Tenancy(boundary=boundary, quotas=None, spill=0)
    if boundary is None:
        raise ValueError(
            f"config.tenant_pages={tp!r} but trace {trace.name!r} is not "
            "multi-tenant (no meta['mt'] sidecar; build it via "
            "repro.traces.interleave.build_mt_trace)")
    if config.device_pages is None:
        raise ValueError(
            "config.tenant_pages requires device_pages: quotas partition "
            "a finite device capacity")
    quotas = tuple(int(q) for q in tp)
    if len(quotas) != 2 or any(q < 0 for q in quotas):
        raise ValueError(f"tenant_pages must be two non-negative page "
                         f"counts, got {tp!r}")
    spill = int(config.device_pages) - sum(quotas)
    if spill < 0:
        raise ValueError(
            f"tenant_pages {quotas} exceed device_pages "
            f"{config.device_pages} (spill would be {spill})")
    return Tenancy(boundary=boundary, quotas=quotas, spill=spill)


# ---------------------------------------------------------------------------
# reference policy objects (the legacy per-access loop drives these; the
# NumPy and pallas engines replay the same semantics vectorized)
# ---------------------------------------------------------------------------

class EvictionPolicy:
    """Victim-selection strategy for the legacy simulator.

    The simulator calls, only when ``device_pages`` is set:

    * ``on_insert(page)`` — page became resident (demand fault or
      prefetch).  Idempotent for already-resident pages (matches the
      engines, which never re-draw state for an overwrite).
    * ``on_touch(page)`` — resident page touched (hit/late access, or an
      in-flight victim spared by the eviction loop and retouched at MRU).
    * ``on_evict(page)`` — page left residency.
    * ``select_victim(resident, tenant=None)`` — the next victim among
      the keys of ``resident`` (the simulator's page → arrival
      ``OrderedDict``, kept in exact LRU order by the access loop).
      With per-tenant quotas the simulator first calls
      :meth:`bind_tenancy` and then passes the over-quota tenant id, and
      selection is masked to that tenant's resident pages — the policy's
      internal ordering (LRU order, random priorities, hotcold keys) is
      unchanged; only the candidate set shrinks.

    The event counter (one tick per insert and per touch) is shared
    vocabulary with the vectorized engines' LRU touch stamps — policies
    that consume it (random draws, hotcold tie-breaks) stay identical
    across backends because every backend ticks it on the same events.
    """

    name = "abstract"

    #: page -> tenant id mapping when quota-split tenancy is bound
    #: (bind_tenancy); None = single-tenant / shared-capacity selection
    _tenant_of = None

    def bind_tenancy(self, tenant_of) -> None:
        """Install a ``page -> tenant`` mapping so victim selection can be
        masked per tenant.  Must be called before any ``on_insert`` (the
        heap-backed policies shard their heaps by tenant at insert time)."""
        self._tenant_of = tenant_of

    def reset(self) -> None:
        pass

    def on_insert(self, page: int) -> None:
        pass

    def on_touch(self, page: int) -> None:
        pass

    def on_evict(self, page: int) -> None:
        pass

    def select_victim(self, resident, tenant: Optional[int] = None) -> int:
        raise NotImplementedError


class LRUEviction(EvictionPolicy):
    """Least-recently-used: the simulator's ``resident`` OrderedDict *is*
    the LRU order (every touch moves to end), so the victim is simply its
    first key — exactly the historical ``popitem(last=False)``."""

    name = "lru"

    def select_victim(self, resident, tenant: Optional[int] = None) -> int:
        if tenant is None or self._tenant_of is None:
            return next(iter(resident))
        # masked LRU: the least-recently-used page OF THIS TENANT — the
        # OrderedDict is already in LRU order, so the first match is it
        return next(p for p in resident if self._tenant_of(p) == tenant)


class RandomEviction(EvictionPolicy):
    """Counter-based deterministic pseudo-random replacement.

    Each page draws ``eviction_score(page, counter)`` when it becomes
    resident (so re-insertions re-draw), and the victim is the resident
    page minimizing ``(priority, page)``.  Priorities are static while
    resident, so selection is a lazy min-heap: stale entries (evicted or
    re-drawn pages) self-heal at pop time.
    """

    name = "random"

    def reset(self) -> None:
        self.counter = 0
        self.prio: Dict[int, int] = {}
        # heaps sharded by tenant id (None = unmasked): priorities are
        # unchanged by tenancy, only which shard gets popped from
        self.heaps: Dict[Optional[int], List[Tuple[int, int]]] = {None: []}

    def _heap(self, page: int) -> List[Tuple[int, int]]:
        key = self._tenant_of(page) if self._tenant_of else None
        return self.heaps.setdefault(key, [])

    def on_insert(self, page: int) -> None:
        if page in self.prio:
            return
        pr = eviction_score(page, self.counter)
        self.prio[page] = pr
        heapq.heappush(self._heap(page), (pr, page))
        self.counter += 1

    def on_touch(self, page: int) -> None:
        self.counter += 1

    def on_evict(self, page: int) -> None:
        del self.prio[page]

    def select_victim(self, resident, tenant: Optional[int] = None) -> int:
        key = tenant if self._tenant_of else None
        heap = self.heaps[key]
        while True:
            pr, page = heap[0]
            if self.prio.get(page) != pr:
                heapq.heappop(heap)          # evicted or re-drawn: stale
                continue
            return page


class HotColdEviction(EvictionPolicy):
    """Access-frequency (cold-first) replacement per arXiv 2204.02974.

    ``freq[page]`` counts touches since the page migrated (0 at insert:
    prefetched-but-unused pages are the coldest); the victim minimizes
    ``(freq, stamp)`` — stamps are the shared monotone touch counter, so
    frequency ties resolve least-recently-used.  Lazy min-heap: keys only
    grow while resident, so stale entries re-push and self-heal.
    """

    name = "hotcold"

    def reset(self) -> None:
        self.counter = 0
        self.freq: Dict[int, int] = {}
        self.stamp: Dict[int, int] = {}
        self.heaps: Dict[Optional[int],
                         List[Tuple[int, int, int]]] = {None: []}

    def _heap(self, page: int) -> List[Tuple[int, int, int]]:
        key = self._tenant_of(page) if self._tenant_of else None
        return self.heaps.setdefault(key, [])

    def on_insert(self, page: int) -> None:
        if page in self.stamp:
            return
        self.freq[page] = 0
        self.stamp[page] = self.counter
        heapq.heappush(self._heap(page), (0, self.counter, page))
        self.counter += 1

    def on_touch(self, page: int) -> None:
        if page in self.stamp:
            self.freq[page] += 1
            self.stamp[page] = self.counter
        self.counter += 1

    def on_evict(self, page: int) -> None:
        del self.freq[page]
        del self.stamp[page]

    def select_victim(self, resident, tenant: Optional[int] = None) -> int:
        key = tenant if self._tenant_of else None
        heap = self.heaps[key]
        while True:
            f, s, page = heap[0]
            cur = self.stamp.get(page)
            if cur is None:                  # evicted: drop the entry
                heapq.heappop(heap)
                continue
            if (self.freq[page], cur) != (f, s):
                heapq.heapreplace(heap, (self.freq[page], cur, page))
                continue
            return page


def make_eviction_policy(name: str) -> EvictionPolicy:
    validate_policy(name)
    policy = {"lru": LRUEviction, "random": RandomEviction,
              "hotcold": HotColdEviction}[name]()
    policy.reset()
    return policy
