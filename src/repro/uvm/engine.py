"""Engine façade over the backend-pluggable replay core.

The replay state machine lives in ``repro.uvm.replay_core`` (pure array
program + the ``ReplayBackend`` interface); concrete backends live in
``repro.uvm.backends`` (``legacy`` / ``numpy`` / ``pallas``).  This module
keeps the historical entry points:

* :class:`VectorizedUVMSimulator` — drop-in replacement for
  ``UVMSimulator`` (same timing model, bit-identical stats): the ``numpy``
  backend with automatic legacy fallback for unknown prefetcher subclasses
  or unreasonable page spans.
* :func:`simulate` — one (trace, prefetcher) cell on a chosen engine and
  backend; the returned ``UVMStats.backend`` records what actually ran.

Whole-grid lane batching (many cells in one pallas kernel) is scheduled by
``repro.uvm.sweep``; this module dispatches single cells.
"""
from __future__ import annotations

from repro.traces.trace import Trace
from repro.uvm.config import UVMConfig
from repro.uvm.prefetchers import Prefetcher
from repro.uvm.replay_core import (  # noqa: F401  (compat re-exports)
    MAX_SPAN_PAGES, SUPPORTED_PREFETCHERS, ReplayRequest, _BlockAdapter,
    _LearnedAdapter, _OracleAdapter, _TreeAdapter, dispatch, get_backend,
    resolve_backend)
from repro.uvm.simulator import UVMSimulator, UVMStats

ENGINES = ("auto", "vectorized", "legacy")


class VectorizedUVMSimulator:
    """Drop-in replacement for :class:`UVMSimulator` (same timing model,
    bit-identical stats, NumPy-chunked replay via the ``numpy`` backend;
    unknown prefetcher subclasses and oversized page spans fall back to
    the legacy loop wholesale — exact by construction, no speedup)."""

    def __init__(self, config: UVMConfig | None = None,
                 record_timeline: bool = False,
                 strict_checks: bool = False,
                 max_span_pages: int = MAX_SPAN_PAGES) -> None:
        self.config = config or UVMConfig()
        self.record_timeline = record_timeline
        self.strict_checks = strict_checks
        self.max_span_pages = max_span_pages

    def run(self, trace: Trace, prefetcher: Prefetcher) -> UVMStats:
        request = ReplayRequest(
            trace=trace, prefetcher=prefetcher, config=self.config,
            record_timeline=self.record_timeline,
            strict_checks=self.strict_checks,
            max_span_pages=self.max_span_pages)
        return dispatch(request, backend="numpy")


def simulate(trace: Trace, prefetcher: Prefetcher,
             config: UVMConfig | None = None, *, engine: str = "auto",
             backend: str = "auto",
             record_timeline: bool = False,
             step_bounds=None) -> UVMStats:
    """Run one (trace, prefetcher) cell on the chosen engine/backend.

    ``engine`` picks the replay style: ``auto``/``vectorized`` use the
    backend-pluggable array core, ``legacy`` forces the original
    per-access loop.  ``backend`` picks the array implementation
    (``numpy``, ``pallas``, or ``auto``) with automatic per-cell fallback
    down the chain — the returned ``UVMStats.backend`` names the one that
    actually ran, so silent fallbacks are visible to callers.
    ``step_bounds`` requests per-window completion clocks
    (``UVMStats.step_clocks``; see ``ReplayRequest.step_bounds``) —
    every backend honors them bit-identically (the pallas lanes capture
    the clocks in-kernel).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    request = ReplayRequest(trace=trace, prefetcher=prefetcher,
                            config=config or UVMConfig(),
                            record_timeline=record_timeline,
                            step_bounds=step_bounds)
    if engine == "legacy":
        return dispatch(request, backend="legacy")
    return dispatch(request, backend=backend)
