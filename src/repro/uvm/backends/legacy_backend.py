"""The reference per-access loop as a replay backend.

``UVMSimulator`` *is* the timing model — every other backend is pinned
against it by the golden harness.  It accepts any prefetcher (including
unknown ``Prefetcher`` subclasses that may touch pages outside a dense
span) and any trace, so it terminates every backend fallback chain.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.uvm.replay_core import ReplayBackend, ReplayRequest, run_legacy
from repro.uvm.simulator import UVMStats


class LegacyReplayBackend(ReplayBackend):
    name = "legacy"

    def can_replay(self, request: ReplayRequest) -> bool:
        return True

    def replay(self, requests: Sequence[ReplayRequest]) -> List[UVMStats]:
        out = []
        for req in requests:
            stats = run_legacy(req)
            stats.backend = self.name
            out.append(stats)
        return out
