"""The NumPy-chunked replay core as a replay backend.

This is the original ``VectorizedUVMSimulator`` array program (moved into
``repro.uvm.replay_core.replay_chunked``) behind the ``ReplayBackend``
interface, unchanged: bit-identical to the legacy loop for every supported
prefetcher type, pinned by ``tests/test_uvm_golden.py``.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.uvm.eviction import EVICTION_POLICIES
from repro.uvm.replay_core import (SUPPORTED_PREFETCHERS, ReplayBackend,
                                   ReplayRequest, replay_chunked, span_ok)
from repro.uvm.simulator import UVMStats


class NumpyReplayBackend(ReplayBackend):
    name = "numpy"

    def can_replay(self, request: ReplayRequest) -> bool:
        return (type(request.prefetcher) in SUPPORTED_PREFETCHERS
                and request.config.eviction in EVICTION_POLICIES
                and span_ok(request))

    def replay(self, requests: Sequence[ReplayRequest]) -> List[UVMStats]:
        out = []
        for req in requests:
            stats = replay_chunked(req)
            stats.backend = self.name
            out.append(stats)
        return out
