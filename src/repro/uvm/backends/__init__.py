"""Replay backends — implementations of ``repro.uvm.replay_core.ReplayBackend``.

Importing this package registers the built-in backends:

* ``legacy`` — the reference per-access Python loop (accepts everything).
* ``numpy``  — the NumPy-chunked replay core (bit-identical to legacy for
  the supported prefetcher types and sane page spans).
* ``pallas`` — the jax_pallas multi-lane engine: many compatible cells
  packed into one lane-batched kernel (integer counters exact,
  cycles/pcie_bytes within the golden tolerance).

See ``README.md`` in this directory for the layer diagram, the backend
contract, and how to add a backend.
"""
from repro.uvm.replay_core import register_backend
from repro.uvm.backends.legacy_backend import LegacyReplayBackend
from repro.uvm.backends.numpy_backend import NumpyReplayBackend
from repro.uvm.backends.pallas_backend import PallasReplayBackend

LEGACY = register_backend(LegacyReplayBackend())
NUMPY = register_backend(NumpyReplayBackend())
PALLAS = register_backend(PallasReplayBackend())

__all__ = ["LegacyReplayBackend", "NumpyReplayBackend",
           "PallasReplayBackend", "LEGACY", "NUMPY", "PALLAS"]
