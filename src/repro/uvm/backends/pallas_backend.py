"""jax_pallas multi-lane replay backend: GPU-resident grid replay.

Packs many compatible sweep cells into ONE lane-batched ``pl.pallas_call``:
one lane per (trace, config) cell, traces padded to the longest lane, and
per-lane residency/arrival/LRU-stamp state held as device arrays.  The
kernel grid iterates over lanes, so on an accelerator every cell of a
sweep batch replays concurrently; on CPU hosts the kernel runs in
interpret mode (exact same jaxpr, executed through XLA-CPU), which is what
CI exercises under ``JAX_PLATFORMS=cpu``.

Packable cells
--------------
A lane replays the *full* legacy timing model — far-fault service windows,
PCIe queueing, batch-DMA block prefetches, MSHR stalls, and LRU eviction
under oversubscription with in-flight-victim reinsertion — for the
prefetchers whose per-access behavior is pure array arithmetic:
``NoPrefetcher`` (on-demand) and ``BlockPrefetcher`` (64 KB basic-block
batch DMA).  Stateful prefetchers (tree/learned/oracle) keep their exact
NumPy adapters; the scheduler in ``repro.uvm.sweep`` routes those cells to
the ``numpy`` backend per cell, and the result rows record which backend
actually ran.

Exactness
---------
Every float chain in the kernel replays the legacy loop's IEEE-754
operation order in float64 (the lane functions are traced under
``jax.experimental.enable_x64``), including a branch-free emulation of
CPython's float floor-division in the fault-service window computation.
Integer counters are therefore exact and cycles/pcie_bytes agree with the
legacy engine to well inside the golden 1e-6 relative tolerance (bit-equal
in practice); ``tests/test_uvm_golden.py`` pins this per golden cell and
``tests/test_backends.py`` property-tests random lane batches against
independent NumPy replays.

The per-lane state (arrival/stamp/pfu spans) is carried through a
``lax.fori_loop`` over trace positions — the functional-carry form keeps
the kernel identical between interpret mode and compiled execution.  A
device-native Mosaic/Triton lowering would move the span state into
scratch refs; the lane packing, parameter blocks, and stats layout here
are already shaped for that (see ``README.md``).
"""
from __future__ import annotations

import functools
import os
from typing import List, Sequence, Tuple

import numpy as np

from repro.traces.trace import BASIC_BLOCK_PAGES, ROOT_PAGES
from repro.uvm.prefetchers import BlockPrefetcher, NoPrefetcher
from repro.uvm.replay_core import (ReplayBackend, ReplayRequest,
                                   cycles_per_access, dense_bounds)
from repro.uvm.simulator import UVMStats

#: prefetchers a pallas lane can replay entirely in-kernel
PACKABLE_PREFETCHERS = (NoPrefetcher, BlockPrefetcher)

#: hard per-lane page-span ceiling (beyond it the dense lane state would
#: dwarf the batch; such cells fall back to the NumPy path per cell)
MAX_LANE_SPAN_PAGES = 1 << 20

#: lane-batch shape budgets: lanes per kernel launch, total padded state
#: (lanes x span pages) and total padded trace positions (lanes x t_max)
MAX_LANES_PER_BATCH = 32
MAX_BATCH_STATE_PAGES = 1 << 23
MAX_BATCH_ACCESSES = 1 << 24

#: per-lane trace-length ceiling.  Must stay well below int32 range /
#: the max per-access touch-counter growth (1 demand + 15 block extras =
#: 16, plus a retouch): the kernel's LRU stamps are int32, so a lane of
#: 2^24 accesses tops out near 2^28 touches — 8x headroom under 2^31.
MAX_LANE_ACCESSES = MAX_BATCH_ACCESSES

_N_FPARAMS = 8       # cpa, page_tx, far_fault, ptw, pcie_lat, pfo, extra, page_size
_N_IPARAMS = 4       # n_accesses, device_pages(-1=uncapped), mshr, has_block
STAT_FIELDS = ("cycles", "hits", "late", "faults", "prefetch_issued",
               "prefetch_used", "pages_migrated", "pages_evicted",
               "pcie_bytes")


def _bucket(n: int, floor: int) -> int:
    """Round up to the next power of two (>= floor) so repeated batches of
    similar shape reuse one compiled kernel."""
    b = max(floor, 1)
    while b < n:
        b <<= 1
    return b


@functools.lru_cache(maxsize=None)
def _lane_replay_fn(n_lanes: int, t_max: int, span: int, buf_len: int,
                    interpret: bool):
    """Build (and cache) the jitted multi-lane replay for one batch shape."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    blk_pages = BASIC_BLOCK_PAGES
    i32 = jnp.int32

    def kernel(pages_ref, fparams_ref, iparams_ref, out_ref):
        INF = jnp.float64(jnp.inf)
        IMAX = jnp.int32(np.iinfo(np.int32).max)
        pages = pages_ref[0]
        fp = fparams_ref[0]
        cpa, page_tx, ff, ptw, pcie_lat = fp[0], fp[1], fp[2], fp[3], fp[4]
        pfo, extra_lat, page_size = fp[5], fp[6], fp[7]
        n = iparams_ref[0, 0]
        cap = iparams_ref[0, 1]
        mshr = iparams_ref[0, 2]
        has_block = iparams_ref[0, 3] > 0
        track_lru = cap >= 0

        def step(t, carry):
            (arrival, stamp, pfu, buf, clock, pcie_free, counter, resident,
             nbuf, hits, late, faults, issued, used, migrated, evicted,
             wbacks) = carry

            p = pages[t]
            clock = clock + cpa
            a = arrival[p]
            is_res = a < INF
            is_hit = is_res & (a <= clock)
            is_late = is_res & ~is_hit
            is_fault = ~is_res
            hits = hits + is_hit.astype(i32)
            late = late + is_late.astype(i32)
            faults = faults + is_fault.astype(i32)

            # prefetched-but-unused consumption (False on faults by
            # construction: eviction clears the flag with the residency)
            was_pfu = pfu[p]
            used = used + was_pfu.astype(i32)
            pfu = pfu.at[p].set(False)

            # far-fault service window.  ``(clock // ff)`` in the legacy
            # loop is CPython float floor-division: fmod-based, so the
            # quotient is exact even when clock/ff rounds across an
            # integer — replay that algorithm branch-free (args positive).
            mod = jax.lax.rem(clock, ff)
            div = (clock - mod) / ff
            fd = jnp.floor(div)
            fd = jnp.where(div - fd > 0.5, fd + 1.0, fd)
            ready = (fd + 2.0) * ff + ptw
            start = jnp.maximum(ready, pcie_free)
            arr_v = start + pcie_lat + page_tx

            # demand insert (fault) / LRU retouch (hit, late): both stamp
            # the page at the current touch counter
            arrival = arrival.at[p].set(jnp.where(is_fault, arr_v, a))
            stamp = stamp.at[p].set(counter)
            counter = counter + 1
            resident = resident + is_fault.astype(i32)
            migrated = migrated + is_fault.astype(i32)
            pcie_free = jnp.where(is_fault, start + page_tx, pcie_free)

            # outstanding-stall push: a fault waits on its own migration,
            # a late access on the in-flight page's arrival (<=1 per step,
            # so the buffer never overflows mshr+1 before the trim below)
            push = is_fault | is_late
            push_val = jnp.where(is_fault, arr_v, a)
            slot = jnp.argmax(buf)               # some empty (+inf) slot
            buf = buf.at[slot].set(jnp.where(push, push_val, buf[slot]))
            nbuf = nbuf + push.astype(i32)

            # block prefetcher on_fault: batch-DMA the faulting 64 KB
            # basic block's non-resident pages (the demand page is already
            # in flight, so the window compare excludes it)
            blk = (p // blk_pages) * blk_pages
            win = jax.lax.dynamic_slice(arrival, (blk,), (blk_pages,))
            mask = (win == INF) & is_fault & has_block
            k = jnp.sum(mask, dtype=i32)
            kf = k.astype(jnp.float64)
            ex_ready = clock + pfo + extra_lat
            ex_start = jnp.maximum(pcie_free, ex_ready)
            end = ex_start + kf * page_tx
            ex_arr = end + pcie_lat              # batch completes as one DMA
            arrival = jax.lax.dynamic_update_slice(
                arrival, jnp.where(mask, ex_arr, win), (blk,))
            pwin = jax.lax.dynamic_slice(pfu, (blk,), (blk_pages,))
            pfu = jax.lax.dynamic_update_slice(pfu, pwin | mask, (blk,))
            swin = jax.lax.dynamic_slice(stamp, (blk,), (blk_pages,))
            rank = counter + jnp.cumsum(mask, dtype=i32) - 1
            stamp = jax.lax.dynamic_update_slice(
                stamp, jnp.where(mask, rank, swin), (blk,))
            counter = counter + k
            resident = resident + k
            migrated = migrated + k
            issued = issued + k
            pcie_free = jnp.where(k > 0, end, pcie_free)

            # MSHR pressure: beyond ``mshr`` outstanding stalls the clock
            # jumps to the oldest completion (single pop suffices: pushes
            # are <=1 per access and the buffer is trimmed every access)
            pop = nbuf > mshr
            mi = jnp.argmin(buf)
            clock = jnp.where(pop, jnp.maximum(clock, buf[mi]), clock)
            buf = buf.at[mi].set(jnp.where(pop, INF, buf[mi]))
            nbuf = nbuf - pop.astype(i32)

            # LRU eviction under oversubscription: pop the minimum touch
            # stamp among resident pages; an in-flight victim is reinserted
            # at MRU and stops the loop (exact OrderedDict order — stamps
            # are unique, so argmin is the heap pop)
            def econd(c):
                return c[0] & (c[5] > cap)

            def ebody(c):
                (_, arrival, stamp, pfu, counter, resident, evicted, wbacks,
                 pcie_free) = c
                vi = jnp.argmin(jnp.where(arrival < INF, stamp, IMAX))
                v_arr = arrival[vi]
                in_flight = v_arr > clock
                stamp = stamp.at[vi].set(
                    jnp.where(in_flight, counter, stamp[vi]))
                counter = counter + in_flight.astype(i32)
                arrival = arrival.at[vi].set(
                    jnp.where(in_flight, v_arr, INF))
                pfu = pfu.at[vi].set(jnp.where(in_flight, pfu[vi], False))
                ev = (~in_flight).astype(i32)
                resident = resident - ev
                evicted = evicted + ev
                # writeback traffic (half the evictions dirty)
                wb = (~in_flight) & (evicted % 2 == 0)
                wbacks = wbacks + wb.astype(i32)
                pcie_free = pcie_free + jnp.where(wb, page_tx, 0.0)
                return (~in_flight, arrival, stamp, pfu, counter, resident,
                        evicted, wbacks, pcie_free)

            (_, arrival, stamp, pfu, counter, resident, evicted, wbacks,
             pcie_free) = jax.lax.while_loop(
                econd, ebody,
                (track_lru, arrival, stamp, pfu, counter, resident, evicted,
                 wbacks, pcie_free))

            return (arrival, stamp, pfu, buf, clock, pcie_free, counter,
                    resident, nbuf, hits, late, faults, issued, used,
                    migrated, evicted, wbacks)

        zero = jnp.int32(0)
        init = (
            jnp.full((span,), jnp.inf, dtype=jnp.float64),   # arrival
            jnp.zeros((span,), dtype=i32),                   # LRU stamps
            jnp.zeros((span,), dtype=jnp.bool_),             # pfu flags
            jnp.full((buf_len,), jnp.inf, dtype=jnp.float64),  # MSHR buffer
            jnp.float64(0.0), jnp.float64(0.0),              # clock, pcie_free
            zero, zero, zero,                  # counter, resident, nbuf
            zero, zero, zero,                  # hits, late, faults
            zero, zero, zero, zero, zero,      # issued, used, migr, evic, wb
        )
        (arrival, stamp, pfu, buf, clock, pcie_free, counter, resident,
         nbuf, hits, late, faults, issued, used, migrated, evicted,
         wbacks) = jax.lax.fori_loop(0, n, step, init)

        # drain: every outstanding stall resolves (max over the buffer is
        # the max over any heap-pop order)
        tail = jnp.max(jnp.where(buf < jnp.inf, buf, -jnp.inf))
        clock = jnp.where(nbuf > 0, jnp.maximum(clock, tail), clock)

        out_ref[0, 0] = clock
        out_ref[0, 1] = hits.astype(jnp.float64)
        out_ref[0, 2] = late.astype(jnp.float64)
        out_ref[0, 3] = faults.astype(jnp.float64)
        out_ref[0, 4] = issued.astype(jnp.float64)
        out_ref[0, 5] = used.astype(jnp.float64)
        out_ref[0, 6] = migrated.astype(jnp.float64)
        out_ref[0, 7] = evicted.astype(jnp.float64)
        out_ref[0, 8] = ((migrated + wbacks).astype(jnp.float64) * page_size)

    call = pl.pallas_call(
        kernel,
        grid=(n_lanes,),
        in_specs=[
            pl.BlockSpec((1, t_max), lambda l: (l, 0)),
            pl.BlockSpec((1, _N_FPARAMS), lambda l: (l, 0)),
            pl.BlockSpec((1, _N_IPARAMS), lambda l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, len(STAT_FIELDS)), lambda l: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((n_lanes, len(STAT_FIELDS)),
                                       jnp.float64),
        interpret=interpret,
    )
    return jax.jit(call)


def _lane_shape(request: ReplayRequest) -> Tuple[int, int]:
    lo, hi = dense_bounds(request.trace, request.prefetcher)
    return len(request.trace.pages), hi - lo


class PallasReplayBackend(ReplayBackend):
    name = "pallas"
    experimental = True   # runtime failures degrade down the chain

    def is_native(self) -> bool:
        """Native only when jax is already up on an accelerator the lanes
        actually *compile* for (the same :func:`_interpret_mode` policy:
        TPU, or ``REPRO_PALLAS_COMPILE=1`` elsewhere): ``auto``
        resolution must not drag jax into NumPy-only sweep workers, and
        interpret-mode lanes lose to the NumPy engine on any host."""
        import sys
        jax = sys.modules.get("jax")
        if jax is None:
            return False
        try:
            if jax.default_backend() == "cpu":
                return False
        except Exception:  # pragma: no cover - uninitialized backends
            return False
        return not _interpret_mode()

    # ------------------------------------------------------------------
    def can_replay(self, request: ReplayRequest) -> bool:
        if type(request.prefetcher) not in PACKABLE_PREFETCHERS:
            return False
        if request.record_timeline:
            return False          # per-transfer timelines stay host-side
        n = len(request.trace.pages)
        if n == 0 or n > MAX_LANE_ACCESSES:
            return False          # int32 stamp/counter headroom (above)
        lo, hi = dense_bounds(request.trace, request.prefetcher)
        span = hi - lo
        return lo >= 0 and span <= min(request.max_span_pages,
                                       MAX_LANE_SPAN_PAGES)

    # ------------------------------------------------------------------
    @staticmethod
    def fits_batch(shapes: Sequence[Tuple[int, int]],
                   shape: Tuple[int, int]) -> bool:
        """True if a lane of ``shape`` = (length, span) fits a batch that
        already holds lanes of ``shapes`` under the lane-count, padded
        state, and padded access budgets.  The scheduler uses this to
        flush batches incrementally instead of materializing whole grids.
        """
        n = len(shapes) + 1
        t = max([shape[0]] + [s[0] for s in shapes])
        span = max([shape[1]] + [s[1] for s in shapes])
        return (n <= MAX_LANES_PER_BATCH
                and n * span <= MAX_BATCH_STATE_PAGES
                and n * t <= MAX_BATCH_ACCESSES)

    def pack_lanes(self, requests: Sequence[ReplayRequest]
                   ) -> List[List[int]]:
        """Group request indices into lane batches.

        Cells are sorted by (span, length) so lanes of one batch pad to
        similar shapes, then greedily packed under :meth:`fits_batch`'s
        budgets.  Deterministic in the request order.
        """
        order = sorted(range(len(requests)),
                       key=lambda i: _lane_shape(requests[i]), reverse=True)
        batches: List[List[int]] = []
        cur: List[int] = []
        cur_shapes: List[Tuple[int, int]] = []
        for i in order:
            shape = _lane_shape(requests[i])
            if cur and not self.fits_batch(cur_shapes, shape):
                batches.append(cur)
                cur, cur_shapes = [], []
            cur.append(i)
            cur_shapes.append(shape)
        if cur:
            batches.append(cur)
        return batches

    # ------------------------------------------------------------------
    def replay(self, requests: Sequence[ReplayRequest]) -> List[UVMStats]:
        for req in requests:
            if not self.can_replay(req):
                raise ValueError(
                    f"request not packable into pallas lanes "
                    f"({type(req.prefetcher).__name__}); route it through "
                    "the numpy backend")
        out: List[UVMStats] = [None] * len(requests)  # type: ignore
        for batch in self.pack_lanes(requests):
            for i, stats in zip(batch,
                                self._replay_batch([requests[i]
                                                    for i in batch])):
                out[i] = stats
        return out

    # ------------------------------------------------------------------
    def _replay_batch(self, requests: Sequence[ReplayRequest]
                      ) -> List[UVMStats]:
        """Replay one lane batch: pad, launch, unpack."""
        import jax  # noqa: F401  (jax must import before enable_x64)
        from jax.experimental import enable_x64

        lanes = len(requests)
        shapes = [_lane_shape(r) for r in requests]
        t_max = _bucket(max(t for t, _ in shapes), 64)
        span = _bucket(max(s for _, s in shapes), ROOT_PAGES)
        buf_len = max(int(r.config.mshr_entries) for r in requests) + 1
        n_lanes = _bucket(lanes, 1)

        pages = np.zeros((n_lanes, t_max), dtype=np.int32)
        fparams = np.zeros((n_lanes, _N_FPARAMS), dtype=np.float64)
        iparams = np.full((n_lanes, _N_IPARAMS), -1, dtype=np.int32)
        iparams[:, 0] = 0                      # padding lanes replay nothing
        for l, req in enumerate(requests):
            trace, cfg = req.trace, req.config
            req.prefetcher.reset()
            lo, _ = dense_bounds(trace, req.prefetcher)
            pages[l, :len(trace.pages)] = (
                np.asarray(trace.pages, dtype=np.int64) - lo)
            fparams[l] = (
                cycles_per_access(trace, cfg), cfg.page_transfer_cycles,
                cfg.far_fault_cycles, cfg.page_table_walk_cycles,
                cfg.pcie_latency_cycles, cfg.prefetch_overhead_cycles,
                req.prefetcher.extra_latency_cycles, cfg.page_size)
            iparams[l] = (
                len(trace.pages),
                -1 if cfg.device_pages is None else int(cfg.device_pages),
                int(cfg.mshr_entries),
                1 if isinstance(req.prefetcher, BlockPrefetcher) else 0)

        interpret = _interpret_mode()
        with enable_x64():
            fn = _lane_replay_fn(n_lanes, t_max, span, buf_len, interpret)
            raw = np.asarray(fn(pages, fparams, iparams))

        out = []
        for l, req in enumerate(requests):
            row = raw[l]
            stats = UVMStats(
                name=req.trace.name,
                prefetcher=req.prefetcher.name,
                n_accesses=len(req.trace.pages),
                n_instructions=req.trace.n_instructions,
                cycles=float(row[0]),
                hits=int(row[1]),
                late=int(row[2]),
                faults=int(row[3]),
                prefetch_issued=int(row[4]),
                prefetch_used=int(row[5]),
                pages_migrated=int(row[6]),
                pages_evicted=int(row[7]),
                pcie_bytes=float(row[8]),
                zero_copy_bytes=0.0,
                timeline=None,
            )
            stats.backend = self.name
            out.append(stats)
        return out


def _interpret_mode() -> bool:
    """Shared repo policy (``repro.kernels.ops.default_interpret``):
    interpret everywhere except on a real TPU.  ``REPRO_PALLAS_COMPILE=1``
    forces native compilation for experiments on other accelerators."""
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    from repro.kernels.ops import default_interpret
    return default_interpret()
