"""jax_pallas multi-lane replay backend: GPU-resident grid replay.

Packs many compatible sweep cells into ONE lane-batched ``pl.pallas_call``:
one lane per (trace, config) cell, traces padded to the longest lane, and
per-lane residency/arrival/LRU-stamp state held as device arrays.  The
kernel grid iterates over lanes, so on an accelerator every cell of a
sweep batch replays concurrently; on CPU hosts the kernel runs in
interpret mode (exact same jaxpr, executed through XLA-CPU), which is what
CI exercises under ``JAX_PLATFORMS=cpu``.

Packable cells and lane families
--------------------------------
Every paper-facing prefetcher replays *fully in-kernel* — far-fault
service windows, PCIe queueing, batch-DMA prefetches, MSHR stalls, and
LRU eviction under oversubscription with in-flight-victim reinsertion —
so ``none``/``block``/``tree``/``learned``/``oracle`` cells are all
pallas-eligible.  Cells are bucketed into **lane families** and a batch
is always family-homogeneous (each family is a different kernel with
different per-lane state and inputs):

* ``demand`` — ``NoPrefetcher`` / ``BlockPrefetcher``: the faulting 64 KB
  basic-block window is one 16-page slice compare (no extra lane state).
* ``tree`` — ``TreePrefetcher``: dense per-level node-occupancy count
  arrays (``span >> (4+lv)`` int32 per level, lv = 0..5, mirroring the
  NumPy ``_TreeAdapter``) ride in the lane carry; a fault classifies the
  2 MB root window and walks the >50% escalation levels in-kernel,
  emitting extras in the exact legacy order (per level, ascending page)
  so LRU stamps — and therefore eviction order — stay bit-equal.
* ``learned`` — ``LearnedPrefetcher``: the precomputed ``predict_trace``
  array (content-addressed by ``repro.uvm.predcache``) is fed into the
  lane as a per-access prefetch-decision input stream (page indices
  relative to the lane span, ``-1`` = no prediction), and the serialized
  inference-server gate (``clock >= next_free``) is one float64 carry.
* ``oracle`` — ``OraclePrefetcher``: the first-touch page stream and the
  per-access stream position (a pure function of the access index) are
  precomputed host-side; each access scans a ``lookahead``-wide window of
  the stream for up to 16 non-resident pages, twice on faults (batch DMA
  then continuous), exactly like the legacy object.  Lanes with different
  ``lookahead`` are different families (the window width is a static
  kernel shape).

Cells are additionally bucketed by **eviction policy**
(``UVMConfig.eviction``, see ``repro.uvm.eviction``): victim selection
and the extra per-lane carry (``random`` insert-time priority draws,
``hotcold`` touch-frequency counts) are static kernel structure, so a
batch is policy-homogeneous — ``_lane_shape`` is (family, policy,
length, span) and ``fits_batch`` refuses to co-bucket policies exactly
like families.

Stateful-prefetcher cells the backend still declines (oversized spans,
too-long traces, timeline recording) keep their exact NumPy adapters; the
scheduler in ``repro.uvm.sweep`` routes those cells to the ``numpy``
backend per cell, and the result rows record which backend actually ran.

Exactness
---------
Every float chain in the kernel replays the legacy loop's IEEE-754
operation order in float64 (the lane functions are traced under
``jax.experimental.enable_x64``), including a branch-free emulation of
CPython's float floor-division in the fault-service window computation
and the sequential ``t += page_tx`` arrival chain of non-batch (oracle
continuous) prefetches.  Integer counters are therefore exact and
cycles/pcie_bytes agree with the legacy engine to well inside the golden
1e-6 relative tolerance (bit-equal in practice);
``tests/test_uvm_golden.py`` pins this per golden cell for every family,
``tests/test_backends.py`` property-tests random lane batches against
independent NumPy replays, and ``tests/test_differential.py`` fuzzes all
registered backend pairs.

The per-lane state (arrival/stamp/pfu spans, tree counts) is carried
through a ``lax.fori_loop`` over trace positions — the functional-carry
form keeps the kernel identical between interpret mode and compiled
execution.  A device-native Mosaic/Triton lowering would move the span
state into scratch refs; the lane packing, parameter blocks, and stats
layout here are already shaped for that (see ``README.md``).
"""
from __future__ import annotations

import functools
import hashlib
import os
import pickle
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.traces.trace import BASIC_BLOCK_PAGES, ROOT_PAGES
from repro.uvm.eviction import (EVICTION_POLICIES, SCORE_MULT_1,
                                SCORE_MULT_2, SCORE_SEED_MULT,
                                resolve_tenancy)
from repro.uvm.prefetchers import (BlockPrefetcher, LearnedPrefetcher,
                                   NoPrefetcher, OraclePrefetcher,
                                   Prefetcher, TreePrefetcher)
from repro.uvm.replay_core import (ReplayBackend, ReplayRequest,
                                   cycles_per_access, dense_bounds)
from repro.uvm.simulator import UVMStats, _tenant_accesses

#: lane-family kind per exact prefetcher type — the single source of
#: truth the scheduler derives its name-level family map from (oracle
#: lanes additionally carry their lookahead in the full family id)
FAMILY_BY_TYPE = {
    NoPrefetcher: "demand",
    BlockPrefetcher: "demand",
    TreePrefetcher: "tree",
    LearnedPrefetcher: "learned",
    OraclePrefetcher: "oracle",
}

#: prefetchers a pallas lane can replay entirely in-kernel
PACKABLE_PREFETCHERS = tuple(FAMILY_BY_TYPE)

#: hard per-lane page-span ceiling (beyond it the dense lane state would
#: dwarf the batch; such cells fall back to the NumPy path per cell)
MAX_LANE_SPAN_PAGES = 1 << 20

#: lane-batch shape budgets: lanes per kernel launch, total padded state
#: (lanes x span pages) and total padded trace positions (lanes x t_max)
MAX_LANES_PER_BATCH = 32
MAX_BATCH_STATE_PAGES = 1 << 23
MAX_BATCH_ACCESSES = 1 << 24

#: per-lane trace-length ceiling.  Must stay well below int32 range /
#: the max per-access touch-counter growth: the kernel's LRU stamps are
#: int32.  Demand/learned/oracle lanes grow the counter by at most
#: 1 + 16 + 16 = 33 per access (2^24 * 33 ~ 2^29, 4x headroom under
#: 2^31); a tree fault can stamp a whole 2 MB root window (1 + 511 per
#: access worst case), so tree lanes cap at 2^21 (2^21 * 512 = 2^30).
MAX_LANE_ACCESSES = MAX_BATCH_ACCESSES
MAX_TREE_LANE_ACCESSES = 1 << 21

#: oracle lookahead is a static kernel shape (the per-access window scan
#: width); absurd lookaheads fall back rather than bloat the kernel
MAX_ORACLE_LOOKAHEAD = 512

#: the legacy OraclePrefetcher emits at most 16 extras per callback
ORACLE_MAX_EXTRAS = 16

#: per-lane step-clock window ceiling (``ReplayRequest.step_bounds``):
#: the per-step segment-max carry is ``steps_len + 1`` float64 per lane,
#: so absurd window counts fall back to the NumPy path instead of
#: bloating the batch (serve traces are bounded well below this by
#: ``repro.offload.serve_trace.MAX_SERVE_STEPS``)
MAX_LANE_STEPS = 1 << 16

_N_FPARAMS = 8       # cpa, page_tx, far_fault, ptw, pcie_lat, pfo, extra, page_size
_N_IPARAMS = 9       # n_accesses, device_pages(-1=uncapped), mshr, has_block,
#                      n_ft, lane-lo mod 2^32 (random-policy priority draws),
#                      tenant boundary (dense; IMAX = single-tenant lane),
#                      q0, q1 (per-tenant quota pages; q0 = -1 = shared mode)
STAT_FIELDS = ("cycles", "hits", "late", "faults", "prefetch_issued",
               "prefetch_used", "pages_migrated", "pages_evicted",
               "pcie_bytes")
#: extra per-lane stat column of multi-tenant kernels (``mt=True``):
#: tenant-0 hits, appended after STAT_FIELDS (tenant-1 hits = hits - t0)
MT_STAT_FIELDS = ("hits_t0",)

#: lane-family max trace lengths (see MAX_LANE_ACCESSES note above)
_FAMILY_MAX_ACCESSES = {
    "demand": MAX_LANE_ACCESSES,
    "tree": MAX_TREE_LANE_ACCESSES,
    "learned": MAX_LANE_ACCESSES,
    "oracle": MAX_LANE_ACCESSES,
}


def lane_family(pf: Prefetcher) -> Optional[str]:
    """Lane-family bucket of a prefetcher, or None when unpackable.

    A lane batch is always family-homogeneous: each family is a distinct
    kernel with different per-lane state/inputs, so the scheduler and
    :meth:`PallasReplayBackend.fits_batch` must never co-bucket two
    families.  Oracle lanes carry their lookahead in the family id (the
    scan-window width is a static kernel shape).
    """
    family = FAMILY_BY_TYPE.get(type(pf))    # exact type: unknown
    if family == "oracle":                   # subclasses are unpackable
        return f"oracle/{int(pf.lookahead)}"
    return family


def _family_kind(family: str) -> str:
    """Kernel kind of a family id (strips the oracle lookahead suffix)."""
    return family.split("/")[0]


def _bucket(n: int, floor: int) -> int:
    """Round up to the next power of two (>= floor) so repeated batches of
    similar shape reuse one compiled kernel."""
    b = max(floor, 1)
    while b < n:
        b <<= 1
    return b


@functools.lru_cache(maxsize=None)
def _lane_replay_fn(family: str, policy: str, n_lanes: int, t_max: int,
                    span: int, buf_len: int, ft_len: int, lookahead: int,
                    steps_len: int, mt: bool, interpret: bool):
    """Build (and cache) the jitted multi-lane replay for one batch shape.

    ``family`` is the kernel kind (demand/tree/learned/oracle); ``ft_len``
    and ``lookahead`` are only meaningful for oracle lanes (0 otherwise).
    ``policy`` is the eviction policy every lane of the batch runs under
    (a batch is policy-homogeneous: the victim-selection code and the
    extra per-lane carry — ``random`` priority draws, ``hotcold``
    frequency counts — are static kernel structure).

    ``mt`` enables multi-tenant lane support (``repro.traces.interleave``):
    per-lane tenancy parameters (dense region boundary + per-tenant
    quotas), a tenant-0 residency carry, per-tenant quota eviction with
    tenant-masked victim selection, and a tenant-0 hit-count carry drained
    into one extra stat column (:data:`MT_STAT_FIELDS`).  Tenancy is
    *per-lane dynamic*: a single-tenant lane of an mt batch rides with
    boundary = IMAX and ``q0 = -1``, which makes every tenant branch a
    no-op — its stats stay bit-identical to the ``mt=False`` kernel, so
    mixed batches need no extra homogeneity rule.  ``mt=False`` builds
    the exact pre-tenancy kernel.

    ``steps_len > 0`` enables in-kernel step-clock capture
    (``ReplayRequest.step_bounds``): each access carries its window id in
    an extra int32 input stream, and a ``steps_len + 1`` float64 carry
    records the post-access clock per window (the last write of a window
    is the clock after its last access — exactly the legacy recording
    point).  Slot ``steps_len`` is a trash slot for accesses past the
    last bound and for no-bounds lanes of a mixed batch.  The clock
    chain itself is untouched, so stats stay bit-identical with capture
    on; ``steps_len == 0`` builds the exact pre-capture kernel (no extra
    input, single output).
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    blk_pages = BASIC_BLOCK_PAGES
    blk_shift = blk_pages.bit_length() - 1
    levels = TreePrefetcher.LEVELS
    i32 = jnp.int32
    u32 = jnp.uint32
    IMAX_NP = np.iinfo(np.int32).max
    IMAX64_NP = np.iinfo(np.int64).max
    hotcold = policy == "hotcold"
    randomp = policy == "random"
    # the random victim key is (prio << 21) | slot: every state slot
    # (span + oracle trash) must fit the low 21 bits or slot indices
    # would bleed into the priority bits and silently reorder victims —
    # raising MAX_LANE_SPAN_PAGES past 2^21 - 1 must fail loudly here
    assert span + 1 <= 1 << 21, (
        f"lane span {span} overflows the random-policy victim key; "
        "widen the slot field before raising MAX_LANE_SPAN_PAGES")

    def _rand_score(pages_u32, draw_i32):
        """jnp port of ``repro.uvm.eviction.eviction_scores`` — the exact
        same uint32 wraparound chain, pinned equal by the golden and
        differential suites."""
        x = pages_u32 ^ (draw_i32.astype(u32) * u32(SCORE_SEED_MULT))
        x = x ^ (x >> u32(16))
        x = x * u32(SCORE_MULT_1)
        x = x ^ (x >> u32(15))
        x = x * u32(SCORE_MULT_2)
        x = x ^ (x >> u32(15))
        return x
    # oracle lanes get one extra "trash" slot at index ``span``: window
    # scatters direct every masked-off write there, so duplicate scatter
    # indices never land on a real page.  The slot reads as resident
    # (arrival 0.0) and is never the LRU victim (stamp pinned at IMAX).
    state_len = span + 1 if family == "oracle" else span
    n_inputs = ({"demand": 3, "tree": 3, "learned": 4, "oracle": 5}[family]
                + (1 if steps_len else 0))

    def kernel(*refs):
        pages_ref = refs[0]
        fparams_ref = refs[n_inputs - 2]
        iparams_ref = refs[n_inputs - 1]
        out_ref = refs[n_inputs]
        if steps_len:
            # the per-access window-id stream rides just before the
            # parameter blocks; the per-window clock carry drains into a
            # second output block
            sids = refs[n_inputs - 3][0]
            steps_out_ref = refs[n_inputs + 1]
        INF = jnp.float64(jnp.inf)
        IMAX = jnp.int32(IMAX_NP)
        pages = pages_ref[0]
        fp = fparams_ref[0]
        cpa, page_tx, ff, ptw, pcie_lat = fp[0], fp[1], fp[2], fp[3], fp[4]
        pfo, extra_lat, page_size = fp[5], fp[6], fp[7]
        n = iparams_ref[0, 0]
        cap = iparams_ref[0, 1]
        mshr = iparams_ref[0, 2]
        has_block = iparams_ref[0, 3] > 0
        track_lru = cap >= 0
        IMAX64 = jnp.int64(IMAX64_NP)
        if mt:
            # per-lane tenancy: dense boundary page (IMAX = single-tenant
            # lane: every page compares tenant 0 and the branches no-op),
            # per-tenant quotas (q0 < 0 = shared capacity)
            bnd = iparams_ref[0, 6]
            q0 = iparams_ref[0, 7]
            q1 = iparams_ref[0, 8]
            tsplit = q0 >= 0
            slot_iota = jnp.arange(state_len, dtype=i32)
        if randomp:
            # absolute page ids mod 2^32 per state slot: the random
            # policy's priority draws hash the absolute page, so all
            # backends agree whatever the lane's dense-span offset is
            lane_lo = iparams_ref[0, 5].astype(u32)
            abs_u32 = lane_lo + jnp.arange(state_len, dtype=i32).astype(u32)
            iota64 = jnp.arange(state_len, dtype=jnp.int64)
        # The legacy loop rounds every multiply before the dependent add,
        # but LLVM contracts ``a + b * c`` into a fused multiply-add
        # (single rounding, 1-ULP drift vs CPython) and neither
        # optimization_barrier nor a bitcast round-trip survives to
        # codegen.  ``abs`` does: it is an identity on these provably
        # non-negative products and fabs() breaks the fmul->fadd
        # contraction pattern, pinning the separately-rounded product.
        def _nofma(x):
            return jnp.abs(x)

        if family == "learned":
            preds = refs[1][0]
        if family == "oracle":
            ft = refs[1][0]
            posarr = refs[2][0]
            n_ft = iparams_ref[0, 4]
            look_iota = jnp.arange(lookahead, dtype=i32)

        def step(t, s):
            arrival, stamp, pfu = s["arrival"], s["stamp"], s["pfu"]
            buf = s["buf"]
            counter = s["counter"]
            pcie_free = s["pcie_free"]
            if family == "tree":
                counts = list(s["counts"])
            if hotcold:
                freq = s["freq"]
            if randomp:
                prio = s["prio"]

            p = pages[t]
            clock = s["clock"] + cpa
            a = arrival[p]
            is_res = a < INF
            is_hit = is_res & (a <= clock)
            is_late = is_res & ~is_hit
            is_fault = ~is_res
            hits = s["hits"] + is_hit.astype(i32)
            late = s["late"] + is_late.astype(i32)
            faults = s["faults"] + is_fault.astype(i32)
            if mt:
                th0 = s["th0"] + (is_hit & (p < bnd)).astype(i32)
                rc0 = s["rc0"]

            # prefetched-but-unused consumption (False on faults by
            # construction: eviction clears the flag with the residency)
            used = s["used"] + pfu[p].astype(i32)
            pfu = pfu.at[p].set(False)

            # far-fault service window.  ``(clock // ff)`` in the legacy
            # loop is CPython float floor-division: fmod-based, so the
            # quotient is exact even when clock/ff rounds across an
            # integer — replay that algorithm branch-free (args positive).
            mod = jax.lax.rem(clock, ff)
            div = (clock - mod) / ff
            fd = jnp.floor(div)
            fd = jnp.where(div - fd > 0.5, fd + 1.0, fd)
            ready = _nofma((fd + 2.0) * ff) + ptw
            start = jnp.maximum(ready, pcie_free)
            arr_v = start + pcie_lat + page_tx

            # demand insert (fault) / LRU retouch (hit, late): both stamp
            # the page at the current touch counter
            arrival = arrival.at[p].set(jnp.where(is_fault, arr_v, a))
            stamp = stamp.at[p].set(counter)
            if hotcold:
                # touches since migration: reset at insert, +1 per touch
                freq = freq.at[p].set(jnp.where(is_fault, 0, freq[p] + 1))
            if randomp:
                # insert-time priority draw, seeded by the touch counter
                prio = prio.at[p].set(jnp.where(
                    is_fault, _rand_score(abs_u32[p], counter), prio[p]))
            counter = counter + 1
            resident = s["resident"] + is_fault.astype(i32)
            if mt:
                rc0 = rc0 + (is_fault & (p < bnd)).astype(i32)
            migrated = s["migrated"] + is_fault.astype(i32)
            pcie_free = jnp.where(is_fault, start + page_tx, pcie_free)

            # outstanding-stall push: a fault waits on its own migration,
            # a late access on the in-flight page's arrival (<=1 per step,
            # so the buffer never overflows mshr+1 before the trim below)
            push = is_fault | is_late
            push_val = jnp.where(is_fault, arr_v, a)
            slot = jnp.argmax(buf)               # some empty (+inf) slot
            buf = buf.at[slot].set(jnp.where(push, push_val, buf[slot]))
            nbuf = s["nbuf"] + push.astype(i32)

            issued = s["issued"]

            if family == "tree":
                # the engine raises on_migrate([demand]) BEFORE on_fault,
                # so node occupancy includes the demand page when the
                # escalation walk below reads it (legacy double-counts it
                # again through ``pending`` — replayed exactly)
                for lv in range(levels + 1):
                    counts[lv] = counts[lv].at[p >> (blk_shift + lv)].add(
                        is_fault.astype(i32))

            if family in ("demand", "learned"):
                # block prefetcher on_fault: batch-DMA the faulting 64 KB
                # basic block's non-resident pages (the demand page is
                # already in flight, so the window compare excludes it)
                blk = (p // blk_pages) * blk_pages
                win = jax.lax.dynamic_slice(arrival, (blk,), (blk_pages,))
                mask = (win == INF) & is_fault & has_block
                k = jnp.sum(mask, dtype=i32)
                kf = k.astype(jnp.float64)
                ex_ready = clock + pfo + extra_lat
                ex_start = jnp.maximum(pcie_free, ex_ready)
                end = ex_start + _nofma(kf * page_tx)
                ex_arr = end + pcie_lat          # batch completes as one DMA
                arrival = jax.lax.dynamic_update_slice(
                    arrival, jnp.where(mask, ex_arr, win), (blk,))
                pwin = jax.lax.dynamic_slice(pfu, (blk,), (blk_pages,))
                pfu = jax.lax.dynamic_update_slice(pfu, pwin | mask, (blk,))
                swin = jax.lax.dynamic_slice(stamp, (blk,), (blk_pages,))
                rank = counter + jnp.cumsum(mask, dtype=i32) - 1
                stamp = jax.lax.dynamic_update_slice(
                    stamp, jnp.where(mask, rank, swin), (blk,))
                if hotcold:
                    fwin = jax.lax.dynamic_slice(freq, (blk,), (blk_pages,))
                    freq = jax.lax.dynamic_update_slice(
                        freq, jnp.where(mask, 0, fwin), (blk,))
                if randomp:
                    uwin = jax.lax.dynamic_slice(abs_u32, (blk,),
                                                 (blk_pages,))
                    prwin = jax.lax.dynamic_slice(prio, (blk,), (blk_pages,))
                    prio = jax.lax.dynamic_update_slice(
                        prio,
                        jnp.where(mask, _rand_score(uwin, rank), prwin),
                        (blk,))
                counter = counter + k
                resident = resident + k
                if mt:
                    # the 64 KB block never straddles the (root-aligned)
                    # tenant boundary: the whole batch is p's tenant
                    rc0 = rc0 + jnp.where(p < bnd, k, 0)
                migrated = migrated + k
                issued = issued + k
                pcie_free = jnp.where(k > 0, end, pcie_free)

            if family == "tree":
                # tree on_fault: classify the 2 MB root window, then the
                # >50% escalation walk.  Extras are emitted per level in
                # ascending page order (the legacy list order), which the
                # per-level cumsum ranks reproduce so LRU stamps match.
                root = (p // ROOT_PAGES) * ROOT_PAGES
                rwin = jax.lax.dynamic_slice(arrival, (root,), (ROOT_PAGES,))
                nonres = rwin == INF
                offs = jnp.arange(ROOT_PAGES, dtype=i32)
                rel = p - root
                in_blk = (offs >> blk_shift) == (rel >> blk_shift)
                m0 = in_blk & nonres & is_fault
                out_mask = m0
                pend = m0 | (offs == rel)        # about-to-arrive + demand
                rank = jnp.where(m0, jnp.cumsum(m0.astype(i32)) - 1, 0)
                k = jnp.sum(m0, dtype=i32)
                go = is_fault
                for lv in range(1, levels + 1):
                    span_lv = blk_pages << lv
                    in_node = (offs // span_lv) == (rel // span_lv)
                    node_abs = ((root + (rel // span_lv) * span_lv)
                                >> (blk_shift + lv))
                    cnt = (counts[lv][node_abs]
                           + jnp.sum(in_node & pend, dtype=i32))
                    fire = go & (cnt * 2 > span_lv)
                    ex = in_node & nonres & ~pend & fire
                    rank = jnp.where(
                        ex, k + jnp.cumsum(ex.astype(i32)) - 1, rank)
                    k = k + jnp.sum(ex, dtype=i32)
                    pend = pend | ex
                    out_mask = out_mask | ex
                    go = fire
                kf = k.astype(jnp.float64)
                ex_ready = clock + pfo + extra_lat
                ex_start = jnp.maximum(pcie_free, ex_ready)
                end = ex_start + _nofma(kf * page_tx)
                ex_arr = end + pcie_lat
                arrival = jax.lax.dynamic_update_slice(
                    arrival, jnp.where(out_mask, ex_arr, rwin), (root,))
                pwin = jax.lax.dynamic_slice(pfu, (root,), (ROOT_PAGES,))
                pfu = jax.lax.dynamic_update_slice(
                    pfu, pwin | out_mask, (root,))
                swin = jax.lax.dynamic_slice(stamp, (root,), (ROOT_PAGES,))
                stamp = jax.lax.dynamic_update_slice(
                    stamp, jnp.where(out_mask, counter + rank, swin), (root,))
                if hotcold:
                    fwin = jax.lax.dynamic_slice(freq, (root,), (ROOT_PAGES,))
                    freq = jax.lax.dynamic_update_slice(
                        freq, jnp.where(out_mask, 0, fwin), (root,))
                if randomp:
                    uwin = jax.lax.dynamic_slice(abs_u32, (root,),
                                                 (ROOT_PAGES,))
                    prwin = jax.lax.dynamic_slice(prio, (root,),
                                                  (ROOT_PAGES,))
                    prio = jax.lax.dynamic_update_slice(
                        prio,
                        jnp.where(out_mask,
                                  _rand_score(uwin, counter + rank), prwin),
                        (root,))
                counter = counter + k
                resident = resident + k
                if mt:
                    # the 2 MB root window is entirely on p's side of the
                    # root-aligned tenant boundary
                    rc0 = rc0 + jnp.where(p < bnd, k, 0)
                migrated = migrated + k
                issued = issued + k
                pcie_free = jnp.where(k > 0, end, pcie_free)
                # on_migrate of the batch: per-level node occupancy grows
                # by the per-node page counts of the scheduled window
                for lv in range(levels + 1):
                    node_span = blk_pages << lv
                    n_nodes = ROOT_PAGES // node_span
                    inc = jnp.sum(
                        out_mask.reshape(n_nodes, node_span).astype(i32),
                        axis=1, dtype=i32)
                    node0 = root >> (blk_shift + lv)
                    cwin = jax.lax.dynamic_slice(
                        counts[lv], (node0,), (n_nodes,))
                    counts[lv] = jax.lax.dynamic_update_slice(
                        counts[lv], cwin + inc, (node0,))

            if family == "learned":
                # LearnedPrefetcher.on_access: serialized inference server
                # — an access consumes the gate iff clock >= next_free
                # (whether or not a prefetch results), and only a valid,
                # non-demand, non-resident top-1 prediction migrates.
                # Runs after the fault path, so the prediction's residency
                # check sees the block batch, exactly like the legacy
                # callback order.
                next_free = s["next_free"]
                fire = clock >= next_free
                next_free = jnp.where(fire, clock + extra_lat, next_free)
                pred = preds[t]
                safe = jnp.maximum(pred, 0)
                do_pf = (fire & (pred >= 0) & (pred != p)
                         & (arrival[safe] == INF))
                ex_ready2 = clock + pfo + extra_lat
                ex_start2 = jnp.maximum(pcie_free, ex_ready2)
                end2 = ex_start2 + page_tx       # single-page transfer
                ex_arr2 = end2 + pcie_lat
                arrival = arrival.at[safe].set(
                    jnp.where(do_pf, ex_arr2, arrival[safe]))
                stamp = stamp.at[safe].set(
                    jnp.where(do_pf, counter, stamp[safe]))
                if hotcold:
                    freq = freq.at[safe].set(
                        jnp.where(do_pf, 0, freq[safe]))
                if randomp:
                    prio = prio.at[safe].set(jnp.where(
                        do_pf, _rand_score(abs_u32[safe], counter),
                        prio[safe]))
                pfu = pfu.at[safe].set(do_pf | pfu[safe])
                counter = counter + do_pf.astype(i32)
                resident = resident + do_pf.astype(i32)
                if mt:
                    rc0 = rc0 + (do_pf & (safe < bnd)).astype(i32)
                migrated = migrated + do_pf.astype(i32)
                issued = issued + do_pf.astype(i32)
                pcie_free = jnp.where(do_pf, end2, pcie_free)

            if family == "oracle":
                # OraclePrefetcher: scan a lookahead window of the
                # first-touch stream (position precomputed per access) for
                # up to 16 non-resident pages, in stream order.  A fault
                # scans twice — on_fault (batch DMA) then on_access
                # (continuous, sequential per-page arrivals) — with the
                # second scan seeing the first's insertions.
                pos_t = posarr[t]
                base_valid = (pos_t + look_iota) < n_ft
                win_idx = jax.lax.dynamic_slice(ft, (pos_t,), (lookahead,))

                def scan(arrival, stamp, pfu, counter, resident, migrated,
                         issued, pcie_free, pol, rc0, active, batch):
                    got = arrival[win_idx]
                    nonres = base_valid & (got == INF) & active
                    csum = jnp.cumsum(nonres.astype(i32))
                    take = nonres & (csum <= ORACLE_MAX_EXTRAS)
                    k = jnp.sum(take, dtype=i32)
                    if mt:
                        # oracle lookahead windows can span both tenant
                        # regions: count the tenant-0 insertions directly
                        rc0 = rc0 + jnp.sum(take & (win_idx < bnd),
                                            dtype=i32)
                    rank = csum - 1              # emission order rank
                    kf = k.astype(jnp.float64)
                    ex_ready = clock + pfo + extra_lat
                    ex_start = jnp.maximum(pcie_free, ex_ready)
                    end = ex_start + _nofma(kf * page_tx)
                    if batch:
                        arr_vals = jnp.broadcast_to(end + pcie_lat,
                                                    (lookahead,))
                    else:
                        # legacy non-batch arrivals are the sequential
                        # ``t += page_tx`` chain — replay the exact fp
                        # additions, not ex_start + j * page_tx
                        chain = []
                        tv = ex_start
                        for _ in range(ORACLE_MAX_EXTRAS):
                            tv = tv + page_tx
                            chain.append(tv)
                        chain = jnp.stack(chain)
                        arr_vals = chain[jnp.clip(
                            rank, 0, ORACLE_MAX_EXTRAS - 1)] + pcie_lat
                    tgt = jnp.where(take, win_idx, span)   # span = trash
                    arrival = arrival.at[tgt].set(
                        jnp.where(take, arr_vals, 0.0))
                    stamp = stamp.at[tgt].set(
                        jnp.where(take, counter + rank, IMAX))
                    pfu = pfu.at[tgt].set(take)
                    if hotcold:
                        (freq,) = pol
                        freq = freq.at[tgt].set(
                            jnp.where(take, 0, freq[tgt]))
                        pol = (freq,)
                    if randomp:
                        (prio,) = pol
                        prw = _rand_score(abs_u32[win_idx], counter + rank)
                        prio = prio.at[tgt].set(
                            jnp.where(take, prw, prio[tgt]))
                        pol = (prio,)
                    counter = counter + k
                    resident = resident + k
                    migrated = migrated + k
                    issued = issued + k
                    pcie_free = jnp.where(k > 0, end, pcie_free)
                    return (arrival, stamp, pfu, counter, resident,
                            migrated, issued, pcie_free, pol, rc0)

                pol = ()
                if hotcold:
                    pol = (freq,)
                if randomp:
                    pol = (prio,)
                rc0_c = rc0 if mt else zero
                (arrival, stamp, pfu, counter, resident, migrated, issued,
                 pcie_free, pol, rc0_c) = scan(arrival, stamp, pfu, counter,
                                               resident, migrated, issued,
                                               pcie_free, pol, rc0_c,
                                               is_fault, True)
                (arrival, stamp, pfu, counter, resident, migrated, issued,
                 pcie_free, pol, rc0_c) = scan(arrival, stamp, pfu, counter,
                                               resident, migrated, issued,
                                               pcie_free, pol, rc0_c,
                                               jnp.bool_(True), False)
                if mt:
                    rc0 = rc0_c
                if hotcold:
                    (freq,) = pol
                if randomp:
                    (prio,) = pol

            # MSHR pressure: beyond ``mshr`` outstanding stalls the clock
            # jumps to the oldest completion (single pop suffices: pushes
            # are <=1 per access and the buffer is trimmed every access)
            pop = nbuf > mshr
            mi = jnp.argmin(buf)
            clock = jnp.where(pop, jnp.maximum(clock, buf[mi]), clock)
            buf = buf.at[mi].set(jnp.where(pop, INF, buf[mi]))
            nbuf = nbuf - pop.astype(i32)

            if steps_len:
                # the clock is final for this access here (eviction below
                # never moves it), so the window slot ends up holding the
                # clock after its last access — the legacy recording point
                steps = s["steps"].at[sids[t]].set(clock)

            # eviction under oversubscription: the policy picks the victim
            # (lru = min touch stamp, exact OrderedDict order; random =
            # min insert-time priority draw; hotcold = min (freq, stamp));
            # an in-flight victim is retouched at MRU and stops the loop
            def _allowed(c):
                """Per-tenant residency ceilings (Tenancy.allowed in
                int32) + the over-allowance flags of a quota-split lane."""
                rc0c = c["rc0"]
                rc1c = c["resident"] - rc0c
                spill = cap - q0 - q1
                a0 = q0 + jnp.maximum(0, spill - jnp.maximum(0, rc1c - q1))
                a1 = q1 + jnp.maximum(0, spill - jnp.maximum(0, rc0c - q0))
                return rc0c > a0, rc1c > a1

            def econd(c):
                if mt:
                    over0, over1 = _allowed(c)
                    return c["cont"] & jnp.where(
                        tsplit, over0 | over1, c["resident"] > cap)
                return c["cont"] & (c["resident"] > cap)

            def ebody(c):
                arrival, stamp, pfu = c["arrival"], c["stamp"], c["pfu"]
                counter = c["counter"]
                res_mask = arrival < INF
                if mt:
                    # quota split: trim whichever tenant is over its
                    # allowance (tenant 0 first, like the legacy loop),
                    # victim masked to that tenant's state slots; shared
                    # mode keeps the unmasked single-tenant selection
                    over0, _ = _allowed(c)
                    u = jnp.where(over0, 0, 1)
                    res_mask = res_mask & (
                        ~tsplit | ((slot_iota >= bnd).astype(i32) == u))
                if hotcold:
                    fq = c["freq"]
                    key = jnp.where(
                        res_mask & (stamp < IMAX),
                        (fq.astype(jnp.int64) << 32)
                        | stamp.astype(jnp.int64), IMAX64)
                    vi = jnp.argmin(key)
                elif randomp:
                    # prio is static while resident: safe to close over
                    key = jnp.where(
                        res_mask & (stamp < IMAX),
                        (prio.astype(jnp.int64) << 21) | iota64, IMAX64)
                    vi = jnp.argmin(key)
                else:
                    vi = jnp.argmin(jnp.where(res_mask, stamp, IMAX))
                v_arr = arrival[vi]
                in_flight = v_arr > clock
                stamp = stamp.at[vi].set(
                    jnp.where(in_flight, counter, stamp[vi]))
                if hotcold:
                    fq = fq.at[vi].add(in_flight.astype(i32))
                counter = counter + in_flight.astype(i32)
                arrival = arrival.at[vi].set(
                    jnp.where(in_flight, v_arr, INF))
                pfu = pfu.at[vi].set(jnp.where(in_flight, pfu[vi], False))
                ev = (~in_flight).astype(i32)
                resident = c["resident"] - ev
                evicted = c["evicted"] + ev
                # writeback traffic (half the evictions dirty)
                wb = (~in_flight) & (evicted % 2 == 0)
                wbacks = c["wbacks"] + wb.astype(i32)
                pcie_free = c["pcie_free"] + jnp.where(wb, page_tx, 0.0)
                out = dict(c, cont=~in_flight, arrival=arrival, stamp=stamp,
                           pfu=pfu, counter=counter, resident=resident,
                           evicted=evicted, wbacks=wbacks,
                           pcie_free=pcie_free)
                if mt:
                    out["rc0"] = c["rc0"] - ((~in_flight)
                                             & (vi < bnd)).astype(i32)
                if hotcold:
                    out["freq"] = fq
                if family == "tree":
                    cts = list(c["counts"])
                    for lv in range(levels + 1):
                        cts[lv] = cts[lv].at[vi >> (blk_shift + lv)].add(-ev)
                    out["counts"] = tuple(cts)
                return out

            ecarry = {"cont": track_lru, "arrival": arrival, "stamp": stamp,
                      "pfu": pfu, "counter": counter, "resident": resident,
                      "evicted": s["evicted"], "wbacks": s["wbacks"],
                      "pcie_free": pcie_free}
            if mt:
                ecarry["rc0"] = rc0
            if hotcold:
                ecarry["freq"] = freq
            if family == "tree":
                ecarry["counts"] = tuple(counts)
            ecarry = jax.lax.while_loop(econd, ebody, ecarry)

            out = {
                "arrival": ecarry["arrival"], "stamp": ecarry["stamp"],
                "pfu": ecarry["pfu"], "buf": buf,
                "clock": clock, "pcie_free": ecarry["pcie_free"],
                "counter": ecarry["counter"],
                "resident": ecarry["resident"], "nbuf": nbuf,
                "hits": hits, "late": late, "faults": faults,
                "issued": issued, "used": used, "migrated": migrated,
                "evicted": ecarry["evicted"], "wbacks": ecarry["wbacks"],
            }
            if mt:
                out["rc0"] = ecarry["rc0"]
                out["th0"] = th0
            if family == "learned":
                out["next_free"] = next_free
            if family == "tree":
                out["counts"] = ecarry["counts"]
            if hotcold:
                out["freq"] = ecarry["freq"]
            if randomp:
                out["prio"] = prio
            if steps_len:
                out["steps"] = steps
            return out

        zero = jnp.int32(0)
        init = {
            "arrival": jnp.full((state_len,), jnp.inf, dtype=jnp.float64),
            "stamp": jnp.zeros((state_len,), dtype=i32),
            "pfu": jnp.zeros((state_len,), dtype=jnp.bool_),
            "buf": jnp.full((buf_len,), jnp.inf, dtype=jnp.float64),
            "clock": jnp.float64(0.0), "pcie_free": jnp.float64(0.0),
            "counter": zero, "resident": zero, "nbuf": zero,
            "hits": zero, "late": zero, "faults": zero,
            "issued": zero, "used": zero, "migrated": zero,
            "evicted": zero, "wbacks": zero,
        }
        if mt:
            init["rc0"] = zero
            init["th0"] = zero
        if family == "oracle":
            # trash slot: reads resident, never the LRU victim
            init["arrival"] = init["arrival"].at[span].set(0.0)
            init["stamp"] = init["stamp"].at[span].set(IMAX)
        if family == "learned":
            init["next_free"] = jnp.float64(0.0)
        if family == "tree":
            init["counts"] = tuple(
                jnp.zeros((span >> (blk_shift + lv),), dtype=i32)
                for lv in range(levels + 1))
        if hotcold:
            init["freq"] = jnp.zeros((state_len,), dtype=i32)
        if randomp:
            init["prio"] = jnp.zeros((state_len,), dtype=u32)
        if steps_len:
            # +1 trash slot: accesses past the last bound (and no-bounds
            # lanes of a mixed batch) scatter there instead of a window
            init["steps"] = jnp.zeros((steps_len + 1,), dtype=jnp.float64)
        final = jax.lax.fori_loop(0, n, step, init)

        # drain: every outstanding stall resolves (max over the buffer is
        # the max over any heap-pop order)
        buf = final["buf"]
        tail = jnp.max(jnp.where(buf < jnp.inf, buf, -jnp.inf))
        clock = jnp.where(final["nbuf"] > 0,
                          jnp.maximum(final["clock"], tail), final["clock"])

        if steps_len:
            steps_out_ref[0, :] = final["steps"][:steps_len]
        out_ref[0, 0] = clock
        out_ref[0, 1] = final["hits"].astype(jnp.float64)
        out_ref[0, 2] = final["late"].astype(jnp.float64)
        out_ref[0, 3] = final["faults"].astype(jnp.float64)
        out_ref[0, 4] = final["issued"].astype(jnp.float64)
        out_ref[0, 5] = final["used"].astype(jnp.float64)
        out_ref[0, 6] = final["migrated"].astype(jnp.float64)
        out_ref[0, 7] = final["evicted"].astype(jnp.float64)
        out_ref[0, 8] = ((final["migrated"] + final["wbacks"])
                         .astype(jnp.float64) * page_size)
        if mt:
            out_ref[0, 9] = final["th0"].astype(jnp.float64)

    in_specs = [pl.BlockSpec((1, t_max), lambda l: (l, 0))]
    if family == "learned":
        in_specs.append(pl.BlockSpec((1, t_max), lambda l: (l, 0)))
    if family == "oracle":
        in_specs.append(pl.BlockSpec((1, ft_len), lambda l: (l, 0)))
        in_specs.append(pl.BlockSpec((1, t_max), lambda l: (l, 0)))
    if steps_len:
        in_specs.append(pl.BlockSpec((1, t_max), lambda l: (l, 0)))
    in_specs += [pl.BlockSpec((1, _N_FPARAMS), lambda l: (l, 0)),
                 pl.BlockSpec((1, _N_IPARAMS), lambda l: (l, 0))]
    n_stats = len(STAT_FIELDS) + (len(MT_STAT_FIELDS) if mt else 0)
    out_specs = pl.BlockSpec((1, n_stats), lambda l: (l, 0))
    out_shape = jax.ShapeDtypeStruct((n_lanes, n_stats), jnp.float64)
    if steps_len:
        out_specs = [out_specs,
                     pl.BlockSpec((1, steps_len), lambda l: (l, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((n_lanes, steps_len),
                                          jnp.float64)]
    call = pl.pallas_call(
        kernel,
        grid=(n_lanes,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    return jax.jit(call)


#: executable-cache format version: bump when the serialized layout or
#: the kernel calling convention changes incompatibly
_KERNEL_CACHE_SCHEMA = 1


def _kernel_cache_dir() -> Optional[str]:
    """Directory of the on-disk lane-executable cache, or None when
    disabled (``REPRO_KERNEL_CACHE=0``/``off``).  Defaults to a per-user
    cache dir so every sweep process on a host shares warm kernels."""
    env = os.environ.get("REPRO_KERNEL_CACHE")
    if env is not None:
        if env.strip().lower() in ("", "0", "off"):
            return None
        return env
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "repro-lane-kernels")


@functools.lru_cache(maxsize=1)
def _kernel_src_tag() -> str:
    """Hash of this module's source: kernel code changes must never be
    served a stale executable, even without a schema bump."""
    try:
        with open(__file__, "rb") as fh:
            return hashlib.sha256(fh.read()).hexdigest()[:16]
    except OSError:  # pragma: no cover - frozen/zipped installs
        return "unknown"


def _kernel_cache_path(cache_dir: str, key: Tuple) -> str:
    import jax
    tag = hashlib.sha256(
        repr((_KERNEL_CACHE_SCHEMA, jax.__version__, _kernel_src_tag(),
              key)).encode()
    ).hexdigest()[:32]
    return os.path.join(cache_dir, f"lane_{key[0]}_{key[1]}_{tag}.jaxexec")


@functools.lru_cache(maxsize=None)
def _lane_replay_exec(family: str, policy: str, n_lanes: int, t_max: int,
                      span: int, buf_len: int, ft_len: int, lookahead: int,
                      steps_len: int, mt: bool, interpret: bool):
    """Compiled lane executable for one batch shape, loaded from the
    on-disk kernel cache when possible.

    On CPU hosts the dominant cold-start cost of a sweep process is not
    running the lane kernels but *building* them — pallas tracing, XLA
    lowering, and compilation are a sizable fraction of an entire
    serve-smoke sweep.  The first process to need a batch shape builds
    it and serializes the compiled executable
    (``jax.experimental.serialize_executable``) next to the trace cache;
    every later process deserializes in milliseconds and skips straight
    to execution.  Entries are keyed by the full kernel shape, the cache
    schema, and the jax version; any load failure (stale jax, corrupt
    file, foreign platform) silently falls back to a fresh build, and
    writes go through the crash-safe tmp + ``os.replace`` idiom so a
    killed sweep never publishes a torn executable.
    """
    import jax
    import jax.numpy as jnp

    key = (family, policy, n_lanes, t_max, span, buf_len, ft_len,
           lookahead, steps_len, mt, interpret)
    cache_dir = _kernel_cache_dir()
    path = _kernel_cache_path(cache_dir, key) if cache_dir else None
    if path is not None and os.path.exists(path):
        try:
            from jax.experimental.serialize_executable import (
                deserialize_and_load)
            with open(path, "rb") as fh:
                payload, in_tree, out_tree = pickle.load(fh)
            return deserialize_and_load(payload, in_tree, out_tree)
        except Exception:
            pass                   # stale or torn entry: rebuild below
    fn = _lane_replay_fn(*key)
    i32 = jnp.dtype("int32")
    arg_shapes = [jax.ShapeDtypeStruct((n_lanes, t_max), i32)]  # pages
    if family == "learned":
        arg_shapes.append(jax.ShapeDtypeStruct((n_lanes, t_max), i32))
    if family == "oracle":
        arg_shapes.append(jax.ShapeDtypeStruct((n_lanes, ft_len), i32))
        arg_shapes.append(jax.ShapeDtypeStruct((n_lanes, t_max), i32))
    if steps_len:
        arg_shapes.append(jax.ShapeDtypeStruct((n_lanes, t_max), i32))
    arg_shapes.append(jax.ShapeDtypeStruct((n_lanes, _N_FPARAMS),
                                           jnp.dtype("float64")))
    arg_shapes.append(jax.ShapeDtypeStruct((n_lanes, _N_IPARAMS), i32))
    compiled = fn.lower(*arg_shapes).compile()
    if path is not None:
        try:
            from jax.experimental.serialize_executable import serialize
            payload, in_tree, out_tree = serialize(compiled)
            os.makedirs(cache_dir, exist_ok=True)
            tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
            with open(tmp, "wb") as fh:
                pickle.dump((payload, in_tree, out_tree), fh)
            os.replace(tmp, path)
        except Exception:
            pass                   # caching is best-effort, never fatal
    return compiled


def _lane_shape(request: ReplayRequest) -> Tuple[str, str, int, int]:
    """(family, eviction policy, length, span) of one request's lane.

    The eviction policy is part of the shape because a batch must be
    policy-homogeneous: victim selection and the extra per-lane carry
    (random priorities, hotcold frequencies) are static kernel structure,
    so :meth:`PallasReplayBackend.fits_batch` never co-buckets policies.
    """
    lo, hi = dense_bounds(request.trace, request.prefetcher)
    return (lane_family(request.prefetcher) or "unpackable",
            request.config.eviction,
            len(request.trace.pages), hi - lo)


class PallasReplayBackend(ReplayBackend):
    name = "pallas"
    experimental = True   # runtime failures degrade down the chain

    def is_native(self) -> bool:
        """Native only when jax is already up on an accelerator the lanes
        actually *compile* for (the same :func:`_interpret_mode` policy:
        TPU, or ``REPRO_PALLAS_COMPILE=1`` elsewhere): ``auto``
        resolution must not drag jax into NumPy-only sweep workers, and
        interpret-mode lanes lose to the NumPy engine on any host."""
        import sys
        jax = sys.modules.get("jax")
        if jax is None:
            return False
        try:
            if jax.default_backend() == "cpu":
                return False
        except Exception:  # pragma: no cover - uninitialized backends
            return False
        return not _interpret_mode()

    # ------------------------------------------------------------------
    def can_replay(self, request: ReplayRequest) -> bool:
        pf = request.prefetcher
        family = lane_family(pf)
        if family is None:
            return False
        kind = _family_kind(family)
        if request.config.eviction not in EVICTION_POLICIES:
            return False          # unknown policy: legacy raises clearly
        if request.record_timeline:
            return False          # per-transfer timelines stay host-side
        if request.step_bounds is not None:
            # per-step clocks are captured in-kernel (a per-window f64
            # carry keyed by an access->window id stream); malformed or
            # oversized bounds fall back to the host-side backends, whose
            # validation raises the canonical ValueError
            sb = np.asarray(request.step_bounds, dtype=np.int64)
            if (sb.ndim != 1 or sb.size == 0 or sb.size > MAX_LANE_STEPS
                    or np.any(np.diff(sb) < 0) or sb[0] < 0
                    or sb[-1] > len(request.trace.pages)):
                return False
        try:
            # invalid tenancy (quotas without an mt trace / capacity):
            # decline so the host-side backends raise the canonical error
            resolve_tenancy(request.trace, request.config)
        except ValueError:
            return False
        n = len(request.trace.pages)
        if n == 0 or n > _FAMILY_MAX_ACCESSES[kind]:
            return False          # int32 stamp/counter headroom (above)
        if kind == "learned" and len(pf.predicted_pages) < n:
            return False          # decision stream must cover the trace
        if kind == "oracle" and not (0 < pf.lookahead
                                     <= MAX_ORACLE_LOOKAHEAD):
            return False          # window width is a static kernel shape
        lo, hi = dense_bounds(request.trace, pf)
        span = hi - lo
        return lo >= 0 and span <= min(request.max_span_pages,
                                       MAX_LANE_SPAN_PAGES)

    # ------------------------------------------------------------------
    @staticmethod
    def fits_batch(shapes: Sequence[Tuple[str, str, int, int]],
                   shape: Tuple[str, str, int, int]) -> bool:
        """True if a lane of ``shape`` = (family, policy, length, span) —
        the :func:`_lane_shape` of a request — fits a batch that already
        holds lanes of ``shapes`` under the family- and
        policy-homogeneity rules and the lane-count, padded state, and
        padded access budgets.  The scheduler uses this to flush batches
        incrementally instead of materializing whole grids.
        """
        fam, pol, t, sp = shape
        if any(f != fam or p != pol for f, p, _, _ in shapes):
            return False    # never co-bucket families or eviction policies
        n = len(shapes) + 1
        t = max([t] + [s[2] for s in shapes])
        sp = max([sp] + [s[3] for s in shapes])
        return (n <= MAX_LANES_PER_BATCH
                and n * sp <= MAX_BATCH_STATE_PAGES
                and n * t <= MAX_BATCH_ACCESSES)

    def pack_lanes(self, requests: Sequence[ReplayRequest]
                   ) -> List[List[int]]:
        """Group request indices into family- and policy-homogeneous lane
        batches.

        Cells are sorted by (family, policy, length, span) so lanes of
        one batch share a kernel and pad to similar shapes, then greedily
        packed under :meth:`fits_batch`'s budgets.  Deterministic in the
        request order.
        """
        order = sorted(range(len(requests)),
                       key=lambda i: _lane_shape(requests[i]), reverse=True)
        batches: List[List[int]] = []
        cur: List[int] = []
        cur_shapes: List[Tuple[str, str, int, int]] = []
        for i in order:
            shape = _lane_shape(requests[i])
            if cur and not self.fits_batch(cur_shapes, shape):
                batches.append(cur)
                cur, cur_shapes = [], []
            cur.append(i)
            cur_shapes.append(shape)
        if cur:
            batches.append(cur)
        return batches

    # ------------------------------------------------------------------
    def replay(self, requests: Sequence[ReplayRequest]) -> List[UVMStats]:
        for req in requests:
            if not self.can_replay(req):
                raise ValueError(
                    f"request not packable into pallas lanes "
                    f"({type(req.prefetcher).__name__}); route it through "
                    "the numpy backend")
        # chaos injection site: a "raise" spec here surfaces as a
        # TransientBackendFault, which the dispatch chain and the sweep
        # scheduler re-raise (retry on this backend) instead of degrading
        from repro.uvm import faults
        faults.fire("backend.replay",
                    f"{len(requests)}:{requests[0].trace.name}")
        out: List[UVMStats] = [None] * len(requests)  # type: ignore
        for batch in self.pack_lanes(requests):
            for i, stats in zip(batch,
                                self._replay_batch([requests[i]
                                                    for i in batch])):
                out[i] = stats
        return out

    # ------------------------------------------------------------------
    def _replay_batch(self, requests: Sequence[ReplayRequest]
                      ) -> List[UVMStats]:
        """Replay one family-homogeneous lane batch: pad, launch, unpack."""
        import jax  # noqa: F401  (jax must import before enable_x64)
        from jax.experimental import enable_x64

        families = {lane_family(r.prefetcher) for r in requests}
        assert len(families) == 1, \
            f"lane batch must be family-homogeneous, got {families}"
        family = families.pop()
        kind = _family_kind(family)
        lookahead = int(family.split("/")[1]) if kind == "oracle" else 0
        policies = {r.config.eviction for r in requests}
        assert len(policies) == 1, \
            f"lane batch must be policy-homogeneous, got {policies}"
        policy = policies.pop()

        lanes = len(requests)
        shapes = [_lane_shape(r) for r in requests]
        t_max = _bucket(max(t for _, _, t, _ in shapes), 64)
        span = _bucket(max(s for _, _, _, s in shapes), ROOT_PAGES)
        buf_len = max(int(r.config.mshr_entries) for r in requests) + 1
        n_lanes = _bucket(lanes, 1)
        ft_len = 0
        if kind == "oracle":
            ft_len = _bucket(max(len(r.prefetcher.ft_pages)
                                 for r in requests), 64) + lookahead
        step_sizes = [0 if r.step_bounds is None
                      else int(np.asarray(r.step_bounds).size)
                      for r in requests]
        steps_len = _bucket(max(step_sizes), 64) if any(step_sizes) else 0
        # mt is a static kernel flag but tenancy stays per-lane dynamic:
        # single-tenant lanes of a mixed batch ride with boundary = IMAX
        # and q0 = -1, which keeps their replay bit-identical (see
        # _lane_replay_fn), so packing needs no tenancy homogeneity
        tenancies = [resolve_tenancy(r.trace, r.config) for r in requests]
        mt = any(t is not None for t in tenancies)

        pages = np.zeros((n_lanes, t_max), dtype=np.int32)
        fparams = np.zeros((n_lanes, _N_FPARAMS), dtype=np.float64)
        iparams = np.full((n_lanes, _N_IPARAMS), -1, dtype=np.int32)
        iparams[:, 0] = 0                      # padding lanes replay nothing
        iparams[:, 6] = np.iinfo(np.int32).max  # single-tenant boundary
        extra_in: List[np.ndarray] = []
        if kind == "learned":
            preds_in = np.full((n_lanes, t_max), -1, dtype=np.int32)
            extra_in = [preds_in]
        elif kind == "oracle":
            # padded first-touch entries point at the trash slot ``span``
            ft_in = np.full((n_lanes, ft_len), span, dtype=np.int32)
            pos_in = np.zeros((n_lanes, t_max), dtype=np.int32)
            extra_in = [ft_in, pos_in]
        if steps_len:
            sids_in = np.zeros((n_lanes, t_max), dtype=np.int32)
            extra_in = extra_in + [sids_in]
        for l, req in enumerate(requests):
            trace, cfg, pf = req.trace, req.config, req.prefetcher
            pf.reset()
            n = len(trace.pages)
            lo, _ = dense_bounds(trace, pf)
            pages[l, :n] = np.asarray(trace.pages, dtype=np.int64) - lo
            fparams[l] = (
                cycles_per_access(trace, cfg), cfg.page_transfer_cycles,
                cfg.far_fault_cycles, cfg.page_table_walk_cycles,
                cfg.pcie_latency_cycles, cfg.prefetch_overhead_cycles,
                pf.extra_latency_cycles, cfg.page_size)
            has_block = (type(pf) is BlockPrefetcher
                         or (type(pf) is LearnedPrefetcher
                             and pf.prefetch_block))
            iparams[l, :4] = (
                n,
                -1 if cfg.device_pages is None else int(cfg.device_pages),
                int(cfg.mshr_entries),
                1 if has_block else 0)
            # lane lo mod 2^32 (int32 bit pattern): random-policy draws
            # hash the absolute page id, identical across backends
            iparams[l, 5] = np.array(lo & 0xFFFFFFFF,
                                     dtype=np.uint32).astype(np.int32)
            tn = tenancies[l]
            if tn is not None:
                # dense boundary: may fall outside [0, span) when a trace
                # slice only touches one tenant's region — the compares
                # stay correct either way (all-0 / all-1 lanes)
                iparams[l, 6] = int(tn.boundary) - lo
                if tn.split:
                    iparams[l, 7] = int(tn.quotas[0])
                    iparams[l, 8] = int(tn.quotas[1])
            if kind == "learned":
                pr = np.asarray(pf.predicted_pages, dtype=np.int64)[:n]
                preds_in[l, :n] = np.where(pr >= 0, pr - lo, -1)
            elif kind == "oracle":
                ftp = np.asarray(pf.ft_pages, dtype=np.int64) - lo
                ft_in[l, :len(ftp)] = ftp
                # the stream position is a pure function of the access
                # index (it only ever advances): precompute it host-side
                pos_in[l, :n] = np.searchsorted(
                    pf.ft_index, np.arange(n), side="right")
                iparams[l, 4] = len(ftp)
            if steps_len and req.step_bounds is not None:
                sb = np.asarray(req.step_bounds, dtype=np.int64)
                # window id per access; accesses past the last bound go
                # to the trash slot ``steps_len``
                sid = np.searchsorted(sb, np.arange(n), side="right")
                sids_in[l, :n] = np.where(sid >= sb.size, steps_len,
                                          sid).astype(np.int32)

        interpret = _interpret_mode()
        with enable_x64():
            fn = _lane_replay_exec(kind, policy, n_lanes, t_max, span,
                                   buf_len, ft_len, lookahead, steps_len,
                                   mt, interpret)
            raw = fn(pages, *extra_in, fparams, iparams)
        if steps_len:
            raw, raw_steps = (np.asarray(raw[0]), np.asarray(raw[1]))
        else:
            raw = np.asarray(raw)

        out = []
        for l, req in enumerate(requests):
            row = raw[l]
            stats = UVMStats(
                name=req.trace.name,
                prefetcher=req.prefetcher.name,
                n_accesses=len(req.trace.pages),
                n_instructions=req.trace.n_instructions,
                cycles=float(row[0]),
                hits=int(row[1]),
                late=int(row[2]),
                faults=int(row[3]),
                prefetch_issued=int(row[4]),
                prefetch_used=int(row[5]),
                pages_migrated=int(row[6]),
                pages_evicted=int(row[7]),
                pcie_bytes=float(row[8]),
                zero_copy_bytes=0.0,
                timeline=None,
                eviction=req.config.eviction,
            )
            stats.backend = self.name
            if tenancies[l] is not None:
                th0 = int(row[len(STAT_FIELDS)])
                stats.tenant_hits = (th0, stats.hits - th0)
                stats.tenant_accesses = _tenant_accesses(
                    req.trace.pages, tenancies[l])
            if steps_len and req.step_bounds is not None:
                stats.step_clocks = _fill_step_clocks(
                    np.asarray(req.step_bounds, dtype=np.int64),
                    raw_steps[l])
            out.append(stats)
        return out


def _fill_step_clocks(bounds: np.ndarray, lane_steps: np.ndarray
                      ) -> np.ndarray:
    """Kernel per-window clock maxima -> ``UVMStats.step_clocks``.

    The kernel only writes windows that own at least one access, so empty
    windows (duplicate bounds) forward-fill from the previous non-empty
    window and leading empty windows end at clock 0.0 — the exact
    semantics of the legacy/numpy recording loop (``replay_chunked``),
    which writes the then-current clock as it crosses duplicate bounds.
    """
    n_steps = bounds.size
    vals = np.asarray(lane_steps[:n_steps], dtype=np.float64)
    sizes = np.diff(np.concatenate([[0], bounds]))
    idx = np.where(sizes > 0, np.arange(n_steps), -1)
    idx = np.maximum.accumulate(idx)
    return np.where(idx >= 0, vals[np.maximum(idx, 0)], 0.0)


def _interpret_mode() -> bool:
    """Shared repo policy (``repro.kernels.ops.default_interpret``):
    interpret everywhere except on a real TPU.  ``REPRO_PALLAS_COMPILE=1``
    forces native compilation for experiments on other accelerators."""
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    from repro.kernels.ops import default_interpret
    return default_interpret()
