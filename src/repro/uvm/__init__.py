"""UVM substrate: page-granular CPU-GPU unified-virtual-memory simulation.

Implements on-demand page migration with far-faults, a PCIe interconnect
queue, the CUDA-driver tree-based neighborhood prefetcher (the UVMSmart
baseline), delayed migration / zero-copy policies, LRU eviction under
oversubscription, and the paper's evaluation metrics (page hit rate, PCIe
traffic, prefetcher accuracy/coverage, Unity).

Two equivalent replay engines
-----------------------------
* ``UVMSimulator`` — the reference per-access Python loop (simple, slow).
* ``VectorizedUVMSimulator`` — the batched engine: NumPy-chunked replay that
  skips runs of plain hits and only drops to scalar code on the
  fault/late/prefetch/eviction event subsequence.  It is **bit-identical**
  to the reference on every integer counter and float accumulator; the
  guarantee is pinned by ``tests/test_uvm_golden.py`` against recorded
  fixtures (regenerate after an intentional timing-model change with
  ``PYTHONPATH=src python scripts/regen_uvm_golden.py``).
* ``simulate(trace, prefetcher, config, engine=...)`` picks an engine
  (``auto`` → vectorized with automatic legacy fallback).

Batched sweeps
--------------
``repro.uvm.sweep`` runs (trace × prefetcher × config) grids in one call::

    from repro.uvm.sweep import SweepCell, expand_grid, run_sweep
    cells = expand_grid(["ATAX", "Pathfinder"], ["none", "tree", "oracle"],
                        device_fracs=[None, 0.5])
    rows = run_sweep(cells, out_dir="results/", workers=8)

Traces are generated once and cached on disk; each completed cell is
persisted under ``out_dir/cells/`` so an interrupted sweep resumes where it
stopped; aggregate results are written as both JSON and CSV.  The CLI wraps
the same API: ``PYTHONPATH=src python -m repro.uvm.sweep --help``.

Learned cells are train-once: ``repro.uvm.predcache`` content-addresses the
predictor's ``predict_trace`` arrays by (trace content, model config), so a
(trace × prediction_us × device_frac) grid trains one model per trace and
every variant — in-process, across ``--workers`` processes (atomic
write-rename + training lock), and across runs — reuses the cached array.
"""
from repro.uvm.config import UVMConfig
from repro.uvm.engine import VectorizedUVMSimulator, simulate
from repro.uvm.metrics import unity
from repro.uvm.prefetchers import (
    NoPrefetcher, TreePrefetcher, LearnedPrefetcher, OraclePrefetcher,
    Prefetcher,
)
from repro.uvm.simulator import UVMSimulator, UVMStats

__all__ = [
    "UVMConfig", "UVMSimulator", "UVMStats", "VectorizedUVMSimulator",
    "simulate", "unity",
    "Prefetcher", "NoPrefetcher", "TreePrefetcher", "LearnedPrefetcher",
    "OraclePrefetcher",
]
