"""UVM substrate: page-granular CPU-GPU unified-virtual-memory simulation.

Implements on-demand page migration with far-faults, a PCIe interconnect
queue, the CUDA-driver tree-based neighborhood prefetcher (the UVMSmart
baseline), delayed migration / zero-copy policies, pluggable eviction
under oversubscription (LRU / counter-based random / access-frequency
hot-cold, see ``repro.uvm.eviction``), and the paper's evaluation metrics
(page hit rate, PCIe traffic, prefetcher accuracy/coverage, Unity).
``repro.uvm.scenarios`` holds the declarative oversubscription scenario
matrix (benchmark × capacity ratio × eviction policy × prefetcher;
``python -m repro.uvm.sweep --scenario oversub-full``).

Backend-pluggable replay core
-----------------------------
The replay stack has three layers (see ``repro.uvm.backends/README.md``):

* ``repro.uvm.replay_core`` — the backend-agnostic chunked state machine
  (pure array program) and the narrow ``ReplayBackend`` interface.
* ``repro.uvm.backends`` — ``legacy`` (the reference per-access Python
  loop, accepts anything), ``numpy`` (NumPy-chunked replay,
  **bit-identical** to the reference), and ``pallas`` (jax_pallas
  multi-lane kernel packing many cells into one accelerator launch;
  integer counters exact, floats within the golden tolerance).  All
  backends are pinned by ``tests/test_uvm_golden.py`` against recorded
  fixtures (regenerate after an intentional timing-model change with
  ``PYTHONPATH=src python scripts/regen_uvm_golden.py``).
* the scheduler in ``repro.uvm.sweep`` — groups packable sweep cells into
  lane batches, dispatches to the selected backend
  (``--backend {numpy,pallas,auto}``), falls back per cell to the NumPy
  path for anything unpackable, and records the backend that actually
  ran in every result row.

``UVMSimulator`` is the reference loop; ``VectorizedUVMSimulator`` is a
drop-in equivalent on the numpy backend; ``simulate(trace, prefetcher,
config, engine=..., backend=...)`` picks both per cell.

Batched sweeps
--------------
``repro.uvm.sweep`` runs (trace × prefetcher × config) grids in one call::

    from repro.uvm.sweep import SweepCell, expand_grid, run_sweep
    cells = expand_grid(["ATAX", "Pathfinder"], ["none", "tree", "oracle"],
                        device_fracs=[None, 0.5])
    rows = run_sweep(cells, out_dir="results/", workers=8)

Traces are generated once and cached on disk; each completed cell is
persisted under ``out_dir/cells/`` so an interrupted sweep resumes where it
stopped; aggregate results are written as both JSON and CSV.  The CLI wraps
the same API: ``PYTHONPATH=src python -m repro.uvm.sweep --help``.

Learned cells are train-once: ``repro.uvm.predcache`` content-addresses the
predictor's ``predict_trace`` arrays by (trace content, model config), so a
(trace × prediction_us × device_frac) grid trains one model per trace and
every variant — in-process, across ``--workers`` processes (atomic
write-rename + training lock), and across runs — reuses the cached array.
"""
from repro.uvm.config import UVMConfig
from repro.uvm.engine import VectorizedUVMSimulator, simulate
from repro.uvm.eviction import EVICTION_POLICIES
from repro.uvm.metrics import unity
from repro.uvm.replay_core import (ReplayBackend, ReplayRequest,
                                   available_backends, get_backend)
from repro.uvm.prefetchers import (
    NoPrefetcher, TreePrefetcher, LearnedPrefetcher, OraclePrefetcher,
    Prefetcher,
)
from repro.uvm.simulator import UVMSimulator, UVMStats

__all__ = [
    "UVMConfig", "UVMSimulator", "UVMStats", "VectorizedUVMSimulator",
    "simulate", "unity", "EVICTION_POLICIES",
    "ReplayBackend", "ReplayRequest", "available_backends", "get_backend",
    "Prefetcher", "NoPrefetcher", "TreePrefetcher", "LearnedPrefetcher",
    "OraclePrefetcher",
]
