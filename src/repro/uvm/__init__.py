"""UVM substrate: page-granular CPU-GPU unified-virtual-memory simulator.

Implements on-demand page migration with far-faults, a PCIe interconnect
queue, the CUDA-driver tree-based neighborhood prefetcher (the UVMSmart
baseline), delayed migration / zero-copy policies, LRU eviction under
oversubscription, and the paper's evaluation metrics (page hit rate, PCIe
traffic, prefetcher accuracy/coverage, Unity).
"""
from repro.uvm.config import UVMConfig
from repro.uvm.prefetchers import (
    NoPrefetcher, TreePrefetcher, LearnedPrefetcher, OraclePrefetcher,
    Prefetcher,
)
from repro.uvm.simulator import UVMSimulator, UVMStats
from repro.uvm.metrics import unity

__all__ = [
    "UVMConfig", "UVMSimulator", "UVMStats", "unity",
    "Prefetcher", "NoPrefetcher", "TreePrefetcher", "LearnedPrefetcher",
    "OraclePrefetcher",
]
