"""Deterministic fault-injection plane + chaos convergence harness.

Production-scale sweep grids (the 660-cell ``oversub-full`` matrix and
bigger) must survive killed workers, torn result files, corrupted cached
artifacts, and flaky experimental backends — and *provably converge to
bit-identical results* when they do.  This module is the injection side
of that proof:

* A **fault plan** (:class:`FaultPlan`) is a seed-driven, JSON-serializable
  spec of faults to inject at named *sites* in the sweep's execution:
  worker kills (``SIGKILL``, no cleanup), injected exceptions, slow-worker
  delays, and artifact corruption (truncation / bit flips) of cell rows,
  cached traces, and prediction-cache entries.
* Whether a given (site, key) fires is a **deterministic** function of the
  plan seed — two runs of the same plan against the same grid inject the
  same faults — and every spec carries a ``max_count`` budget enforced
  through an on-disk **ledger** (atomic ``O_EXCL`` claim files), so a
  retried cell eventually stops being sabotaged and the sweep can
  converge.  The ledger is shared across processes and driver restarts.
* The plan rides in the ``REPRO_FAULT_PLAN`` environment variable (inline
  JSON, or a path to a JSON file), so spawned sweep workers and restarted
  drivers all see the same plan without plumbing.
* The **chaos harness** (:func:`chaos_converge`, CLI below) drives a sweep
  under a plan — restarting the driver process when a kill takes it down —
  and proves the final rows are byte-identical to a fault-free baseline
  (:func:`rows_digest`, which canonicalizes rows minus the volatile
  execution-metadata columns ``seconds``/``retries``) with an empty
  quarantine manifest.

Injection sites
---------------

==========================  =================  =============================
site                        kinds              where it fires
==========================  =================  =============================
``cell.start``              kill, raise,       entering a leased cell
                            delay              attempt (``repro.uvm.sweep``)
``cell.result.write``       kill               after a cell row's tempfile
                                               is written, *before* the
                                               atomic rename (torn write)
``cell.result.artifact``    truncate, bitflip  the persisted
                                               ``cells/<key>.json`` after
                                               the rename (fs corruption)
``trace.artifact``          truncate, bitflip  a cached trace ``.npz`` after
                                               its atomic rename
``pred.artifact``           truncate, bitflip  a prediction-cache entry
                                               after its atomic rename
``backend.replay``          raise, delay       entering the pallas lane
                                               kernel (raises a *transient*
                                               backend fault: retried on
                                               the same backend, never
                                               silently degraded — see
                                               ``replay_core``)
``lane.flush``              kill, delay        before a lane batch launch
                                               in the sweep scheduler
``worker.loop``             kill, delay        a lease worker between cells
==========================  =================  =============================

CLI (the chaos convergence check ``scripts/ci_check.sh`` runs)::

    PYTHONPATH=src python -m repro.uvm.faults --scenario chaos-smoke \
        --backend numpy --workers 2 --out /tmp/chaos

runs the scenario fault-free (baseline), then under a kill+corrupt+raise
plan with driver restarts, and exits nonzero unless every cell converged
byte-identically with an empty quarantine manifest.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import signal
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

#: environment variable carrying the active plan: inline JSON (starts with
#: ``{``) or a path to a JSON file
FAULT_PLAN_ENV = "REPRO_FAULT_PLAN"

SITES = ("cell.start", "cell.result.write", "cell.result.artifact",
         "trace.artifact", "pred.artifact", "backend.replay", "lane.flush",
         "worker.loop")
KINDS = ("kill", "raise", "delay", "truncate", "bitflip")

#: sites where a fault acts on a file (the ``path`` argument is required)
_ARTIFACT_KINDS = ("truncate", "bitflip")

#: row columns excluded from convergence digests: timing and the retry
#: counter are execution metadata, everything else must be byte-identical
#: between a chaotic and a fault-free run
VOLATILE_ROW_FIELDS = ("seconds", "retries")


class InjectedFault(RuntimeError):
    """An exception injected by the fault plane (``kind="raise"``)."""


# imported lazily where needed to keep this module numpy/jax-free
def _transient_base():
    from repro.uvm.replay_core import TransientBackendFault
    return TransientBackendFault


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault rule: *what* to inject (``kind``), *where* (``site``,
    optionally narrowed to keys containing ``match``), with what
    probability per (site, key) draw, and at most how many times overall
    (``max_count``; ``None`` = unbounded — convergence plans must bound
    every destructive spec)."""

    site: str
    kind: str
    prob: float = 1.0
    max_count: Optional[int] = 1
    match: Optional[str] = None
    delay_s: float = 0.05        # kind="delay"
    fraction: float = 0.5        # kind="truncate": bytes kept

    def validate(self) -> "FaultSpec":
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"choose from {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {KINDS}")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"fault prob must be in [0, 1], "
                             f"got {self.prob}")
        if self.max_count is not None and self.max_count < 1:
            raise ValueError(f"max_count must be >= 1 or None, "
                             f"got {self.max_count}")
        if not 0.0 <= self.fraction < 1.0:
            raise ValueError(f"truncate fraction must be in [0, 1), "
                             f"got {self.fraction}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")
        return self


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seed plus fault specs plus the shared ledger directory that
    enforces ``max_count`` across processes and driver restarts."""

    seed: int
    specs: Tuple[FaultSpec, ...]
    ledger_dir: Optional[str] = None

    def validate(self) -> "FaultPlan":
        for spec in self.specs:
            spec.validate()
            if spec.max_count is not None and self.ledger_dir is None:
                raise ValueError(
                    f"spec {spec.site}/{spec.kind} has max_count="
                    f"{spec.max_count} but the plan has no ledger_dir — "
                    "bounded faults need the on-disk ledger to stay "
                    "bounded across workers and driver restarts")
        return self

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)


def plan_from_dict(doc: Dict) -> FaultPlan:
    specs = tuple(FaultSpec(**s) for s in doc.get("specs", ()))
    return FaultPlan(seed=int(doc.get("seed", 0)), specs=specs,
                     ledger_dir=doc.get("ledger_dir")).validate()


def load_plan(source: str) -> FaultPlan:
    """Parse a plan from inline JSON or a path to a JSON file."""
    text = source.strip()
    if not text.startswith("{"):
        with open(text) as f:
            text = f.read()
    return plan_from_dict(json.loads(text))


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------

def _draw(seed: int, spec_index: int, site: str, key: str) -> float:
    """Deterministic uniform draw in [0, 1) for one (spec, site, key)."""
    blob = f"{seed}|{spec_index}|{site}|{key}".encode()
    return int.from_bytes(hashlib.sha256(blob).digest()[:8], "big") / 2**64


class FaultInjector:
    """Evaluates a plan at injection sites.  Thread-compatible, cheap when
    no spec matches a site."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan.validate()
        self._local_counts: Dict[Tuple[int, str], int] = {}

    # -- ledger ---------------------------------------------------------
    def _claim(self, spec_index: int, spec: FaultSpec, key: str) -> bool:
        """Claim one firing slot.  With a ``max_count``, slots are atomic
        ``O_EXCL`` files in the ledger dir — shared across processes —
        keyed per (spec, site, key) so a retried cell is sabotaged at
        most ``max_count`` times and then left alone."""
        if spec.max_count is None:
            return True
        token = hashlib.sha256(
            f"{spec_index}|{spec.site}|{key}".encode()).hexdigest()[:20]
        if self.plan.ledger_dir is None:      # unreachable post-validate
            n = self._local_counts.get((spec_index, key), 0)
            if n >= spec.max_count:
                return False
            self._local_counts[(spec_index, key)] = n + 1
            return True
        os.makedirs(self.plan.ledger_dir, exist_ok=True)
        for slot in range(spec.max_count):
            path = os.path.join(self.plan.ledger_dir,
                                f"fired_{token}_{slot}")
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                continue
            with os.fdopen(fd, "w") as f:
                f.write(f"{spec.site} {spec.kind} {key} pid={os.getpid()}")
            return True
        return False

    def _matching(self, site: str, key: str,
                  kinds: Tuple[str, ...]) -> List[Tuple[int, FaultSpec]]:
        out = []
        for i, spec in enumerate(self.plan.specs):
            if spec.site != site or spec.kind not in kinds:
                continue
            if spec.match is not None and spec.match not in key:
                continue
            out.append((i, spec))
        return out

    # -- control-flow faults -------------------------------------------
    def fire(self, site: str, key: str) -> None:
        """Inject kill / raise / delay faults at a control-flow site."""
        for i, spec in self._matching(site, key,
                                      ("kill", "raise", "delay")):
            if _draw(self.plan.seed, i, site, key) >= spec.prob:
                continue
            if not self._claim(i, spec, key):
                continue
            if spec.kind == "delay":
                time.sleep(spec.delay_s)
            elif spec.kind == "raise":
                if site == "backend.replay":
                    base = _transient_base()

                    class _InjectedBackendFault(InjectedFault, base):
                        pass
                    raise _InjectedBackendFault(
                        f"injected transient backend fault at {site} "
                        f"({key})")
                raise InjectedFault(f"injected fault at {site} ({key})")
            elif spec.kind == "kill":
                os.kill(os.getpid(), signal.SIGKILL)

    # -- artifact faults -----------------------------------------------
    def corrupt(self, site: str, path: str, key: str) -> None:
        """Inject truncation / bit-flip corruption into a finished
        artifact (fires *after* the writer's atomic rename, simulating
        filesystem rot a later reader must detect and quarantine)."""
        for i, spec in self._matching(site, key, _ARTIFACT_KINDS):
            if _draw(self.plan.seed, i, site, key) >= spec.prob:
                continue
            try:
                size = os.path.getsize(path)
            except OSError:
                continue
            if size == 0 or not self._claim(i, spec, key):
                continue
            if spec.kind == "truncate":
                os.truncate(path, int(size * spec.fraction))
            else:                             # bitflip
                offset = int(_draw(self.plan.seed, i, "offset", key)
                             * size * 8)
                byte_i, bit_i = offset // 8, offset % 8
                with open(path, "r+b") as f:
                    f.seek(byte_i)
                    b = f.read(1)
                    f.seek(byte_i)
                    f.write(bytes([b[0] ^ (1 << bit_i)]))


# ---------------------------------------------------------------------------
# process-level plumbing (the sites call these free functions)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None
_ACTIVE_RAW: Optional[str] = None


def active() -> Optional[FaultInjector]:
    """The process's injector, rebuilt whenever ``REPRO_FAULT_PLAN``
    changes (spawned workers inherit the env and build their own)."""
    global _ACTIVE, _ACTIVE_RAW
    raw = os.environ.get(FAULT_PLAN_ENV)
    if raw != _ACTIVE_RAW:
        _ACTIVE_RAW = raw
        _ACTIVE = FaultInjector(load_plan(raw)) if raw else None
    return _ACTIVE


def reset() -> None:
    """Drop the cached injector (tests)."""
    global _ACTIVE, _ACTIVE_RAW
    _ACTIVE = None
    _ACTIVE_RAW = None


def fire(site: str, key: str) -> None:
    inj = active()
    if inj is not None:
        inj.fire(site, key)


def corrupt(site: str, path: str, key: str) -> None:
    inj = active()
    if inj is not None:
        inj.corrupt(site, path, key)


# ---------------------------------------------------------------------------
# convergence digests
# ---------------------------------------------------------------------------

def rows_digest(rows: Sequence[Dict],
                ignore: Sequence[str] = VOLATILE_ROW_FIELDS) -> str:
    """Canonical sha256 of a result-row list minus the volatile
    execution-metadata columns.  Two sweeps converged iff their digests
    are equal — every remaining column, ``backend`` and ``quarantined``
    included, must match byte-for-byte."""
    ignore = set(ignore)
    canon = [{k: v for k, v in sorted(row.items()) if k not in ignore}
             for row in rows]
    return hashlib.sha256(
        json.dumps(canon, sort_keys=True).encode()).hexdigest()


# ---------------------------------------------------------------------------
# the chaos harness
# ---------------------------------------------------------------------------

def default_chaos_plan(ledger_dir: str, seed: int = 0) -> FaultPlan:
    """The reference kill+corrupt+raise+delay plan the smoke check runs:
    every destructive spec is bounded, so a resumed sweep always
    converges once the ledger fills."""
    return FaultPlan(seed=seed, ledger_dir=ledger_dir, specs=(
        FaultSpec("cell.start", "kill", prob=0.4, max_count=2),
        FaultSpec("cell.start", "raise", prob=0.4, max_count=2),
        FaultSpec("cell.start", "delay", prob=0.3, max_count=4,
                  delay_s=0.05),
        FaultSpec("cell.result.write", "kill", prob=0.3, max_count=2),
        FaultSpec("cell.result.artifact", "bitflip", prob=0.4,
                  max_count=2),
        FaultSpec("cell.result.artifact", "truncate", prob=0.3,
                  max_count=1),
        FaultSpec("trace.artifact", "truncate", prob=0.5, max_count=1),
        FaultSpec("backend.replay", "raise", prob=0.5, max_count=2),
        FaultSpec("lane.flush", "kill", prob=0.3, max_count=1),
        FaultSpec("worker.loop", "kill", prob=0.3, max_count=2),
    ))


#: sites whose faults burn one *cell attempt* each time they fire: the
#: fault lands after the attempt counter was bumped under the lease
#: (cell.start, backend.replay, cell.result.write), or it corrupts the
#: committed row so a later resume requeues the cell (cell.result.artifact)
_ATTEMPT_CONSUMING_SITES = ("cell.start", "cell.result.write",
                            "cell.result.artifact", "backend.replay")


def attempt_budget(plan: FaultPlan, margin: int = 2) -> int:
    """The quarantine threshold a *recoverable* plan needs: in the worst
    case every attempt-consuming spec spends its whole ``max_count``
    budget on the same cell, so the cell must be allowed that many failed
    attempts plus ``margin`` real ones before quarantine kicks in.  The
    chaos harness exports this as ``REPRO_SWEEP_MAX_ATTEMPTS`` — with the
    stock threshold, a heavily-sabotaged cell would quarantine and the
    convergence check would (correctly) fail."""
    sabotage = sum(spec.max_count or 0 for spec in plan.specs
                   if spec.site in _ATTEMPT_CONSUMING_SITES
                   and spec.kind != "delay")
    return sabotage + margin


def _sweep_argv(out_dir: str, *, scenario: Optional[str] = None,
                benches: Optional[str] = None,
                prefetchers: Optional[str] = None,
                backend: str = "numpy", engine: str = "auto",
                workers: int = 1, scale: Optional[float] = None) -> List[str]:
    argv = [sys.executable, "-m", "repro.uvm.sweep", "--out", out_dir,
            "--backend", backend, "--engine", engine,
            "--workers", str(workers)]
    if scenario:
        argv += ["--scenario", scenario]
    else:
        argv += ["--benches", benches or "ATAX,Pathfinder",
                 "--prefetchers", prefetchers or "none,tree"]
        if scale is not None:
            argv += ["--scales", str(scale)]
    return argv


def _run_env(plan: Optional[FaultPlan]) -> Dict[str, str]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    if plan is None:
        env.pop(FAULT_PLAN_ENV, None)
    else:
        env[FAULT_PLAN_ENV] = plan.to_json()
    return env


def chaos_converge(argv: List[str], plan: FaultPlan, *,
                   max_restarts: int = 30,
                   env_extra: Optional[Dict[str, str]] = None,
                   verbose: bool = False) -> int:
    """Run a sweep command under ``plan``, restarting the driver process
    every time an injected kill (or any crash) takes it down, until it
    exits cleanly.  Returns the number of restarts; raises RuntimeError
    when the restart budget is exhausted (a fault plan whose destructive
    specs are not all bounded can loop forever — that is a plan bug)."""
    env = _run_env(plan)
    if env_extra:
        env.update(env_extra)
    restarts = 0
    while True:
        proc = subprocess.run(argv, env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.STDOUT)
        if proc.returncode == 0:
            return restarts
        restarts += 1
        if verbose:
            tail = proc.stdout.decode(errors="replace").strip()
            print(f"[chaos] driver died (rc={proc.returncode}), "
                  f"restart {restarts}/{max_restarts}; tail:\n"
                  + "\n".join(tail.splitlines()[-4:]), flush=True)
        if restarts > max_restarts:
            raise RuntimeError(
                f"chaos sweep did not converge within {max_restarts} "
                f"driver restarts — is every destructive fault spec "
                f"bounded by max_count?  last output:\n"
                + proc.stdout.decode(errors="replace")[-2000:])


def run_chaos_check(out_dir: str, *, scenario: Optional[str] = None,
                    benches: Optional[str] = None,
                    prefetchers: Optional[str] = None,
                    backend: str = "numpy", engine: str = "auto",
                    workers: int = 1, seed: int = 0,
                    scale: Optional[float] = None,
                    plan: Optional[FaultPlan] = None,
                    max_restarts: int = 30,
                    verbose: bool = True) -> Dict:
    """The full convergence check: fault-free baseline, chaotic run with
    driver restarts, then digest + quarantine comparison.

    Returns a report dict; raises AssertionError on divergence, lost
    cells, or a non-empty quarantine manifest (recoverable faults must
    never quarantine a cell)."""
    from repro.uvm.sweep import read_results

    base_out = os.path.join(out_dir, "baseline")
    chaos_out = os.path.join(out_dir, "chaos")
    ledger = os.path.join(out_dir, "ledger")
    if plan is None:
        plan = default_chaos_plan(ledger, seed=seed)

    kw = dict(scenario=scenario, benches=benches, prefetchers=prefetchers,
              backend=backend, engine=engine, workers=workers, scale=scale)
    if verbose:
        print(f"[chaos] baseline run -> {base_out}", flush=True)
    proc = subprocess.run(_sweep_argv(base_out, **kw), env=_run_env(None),
                          stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    if proc.returncode != 0:
        raise RuntimeError("fault-free baseline failed:\n"
                           + proc.stdout.decode(errors="replace")[-2000:])
    if verbose:
        print(f"[chaos] chaotic run under plan (seed={plan.seed}, "
              f"{len(plan.specs)} specs) -> {chaos_out}", flush=True)
    restarts = chaos_converge(
        _sweep_argv(chaos_out, **kw), plan, max_restarts=max_restarts,
        env_extra={"REPRO_SWEEP_MAX_ATTEMPTS": str(attempt_budget(plan))},
        verbose=verbose)

    base_rows = read_results(base_out)
    chaos_rows = read_results(chaos_out)
    assert len(chaos_rows) == len(base_rows), (
        f"lost cells: chaos run has {len(chaos_rows)} rows, "
        f"baseline {len(base_rows)}")
    quarantined = [r for r in chaos_rows if r.get("quarantined")]
    assert not quarantined, (
        f"{len(quarantined)} cells quarantined under a recoverable fault "
        f"plan: {[(r['bench'], r['prefetcher']) for r in quarantined]}")
    with open(os.path.join(chaos_out, "quarantine.json")) as f:
        manifest = json.load(f)
    assert manifest["cells"] == [], manifest
    d_base, d_chaos = rows_digest(base_rows), rows_digest(chaos_rows)
    assert d_base == d_chaos, (
        "chaos run diverged from the fault-free baseline: "
        f"{d_chaos} != {d_base} — first differing row: "
        + next((f"{b} vs {c}" for b, c in zip(base_rows, chaos_rows)
                if {k: v for k, v in b.items()
                    if k not in VOLATILE_ROW_FIELDS}
                != {k: v for k, v in c.items()
                    if k not in VOLATILE_ROW_FIELDS}), "<none>"))
    retries = sum(int(r.get("retries") or 0) for r in chaos_rows)
    fired = (len(os.listdir(ledger)) if os.path.isdir(ledger) else 0)
    report = {"cells": len(chaos_rows), "restarts": restarts,
              "retries": retries, "faults_fired": fired,
              "digest": d_base}
    if verbose:
        print(f"[chaos] converged: {report['cells']} cells byte-identical "
              f"to baseline after {fired} injected faults, "
              f"{restarts} driver restarts, {retries} cell retries; "
              "quarantine empty", flush=True)
    return report


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Chaos convergence check: sweep under an injected "
                    "fault plan must produce rows byte-identical to a "
                    "fault-free baseline")
    ap.add_argument("--scenario", default=None,
                    help="scenario to drive (e.g. chaos-smoke); "
                         "alternatively --benches/--prefetchers")
    ap.add_argument("--benches", default=None)
    ap.add_argument("--prefetchers", default=None)
    ap.add_argument("--backend", default="numpy",
                    choices=["auto", "numpy", "pallas"])
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "vectorized", "legacy"])
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan", default=None,
                    help="fault plan (inline JSON or a file path); "
                         "default: the built-in bounded kill+corrupt+"
                         "raise plan")
    ap.add_argument("--max-restarts", type=int, default=30)
    ap.add_argument("--out", required=True,
                    help="working directory (baseline/, chaos/, ledger/)")
    args = ap.parse_args(argv)

    plan = None
    if args.plan:
        plan = load_plan(args.plan)
    report = run_chaos_check(
        args.out, scenario=args.scenario, benches=args.benches,
        prefetchers=args.prefetchers, backend=args.backend,
        engine=args.engine, workers=args.workers, seed=args.seed,
        plan=plan, max_restarts=args.max_restarts)
    print(json.dumps(report, sort_keys=True))


if __name__ == "__main__":
    main()
