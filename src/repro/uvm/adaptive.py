"""Adaptive eviction: the ``adaptive`` pseudo-policy and its resolvers.

The intelligent-oversubscription framework (arXiv 2204.02974 — the same
place this repo's ``hotcold`` policy comes from) observes that no single
eviction policy wins across benchmarks: access patterns decide whether
recency (``lru``), randomization (``random``), or hotness segregation
(``hotcold``) keeps the right pages resident.  This module makes that a
sweepable axis: grids and scenarios may request ``eviction="adaptive"``,
and the sweep resolves it to a *concrete* policy per cell at prepare
time — the result row records the resolved policy in its ``eviction``
column (never the literal ``adaptive``), so downstream consumers see
exactly what replayed and lane batches stay policy-homogeneous.

Resolution order:

1. **Selector table** (``REPRO_ADAPTIVE_TABLE``: path to a JSON
   ``{bench: policy}`` mapping, e.g. distilled from a previous scenario
   matrix via :func:`selector_from_rows`) — the "pick the policy per
   benchmark from scenario-matrix results" path.
2. **Probe replay**: with no table entry, a short replay of the cell's
   own trace prefix under every policy (NumPy backend, capacity scaled
   to preserve the cell's oversubscription ratio) picks the
   cheapest-in-cycles policy.  The probe runs under a cheap *proxy* of
   the cell's prefetcher family — demand paging for ``none``, the real
   block/tree prefetchers for theirs, and an oracle over the prefix for
   ``oracle`` **and** ``learned`` (training a predictor inside a probe
   would cost more than the cell) — because the best policy depends on
   which pages prefetching keeps warm, not just the demand stream.
   Deterministic, memoized per (trace content, device capacity, proxy
   family), and cheap relative to a full cell replay.
3. **No eviction pressure** (capacity absent or >= working set): every
   policy is a no-op, resolve to the canonical first policy (``lru``).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Iterable, Optional, Tuple

from repro.uvm.eviction import EVICTION_POLICIES, validate_policy

#: the pseudo-policy name accepted by sweep grids and scenarios
ADAPTIVE_POLICY = "adaptive"

#: accesses replayed per policy by the probe resolver
PROBE_ACCESSES = 20000

_MEMO: Dict[Tuple, str] = {}
_MEMO_LOCK = threading.Lock()


def is_adaptive(policy: Optional[str]) -> bool:
    return policy == ADAPTIVE_POLICY


def clear_memo() -> None:
    """Drop the probe memo and the parsed-table cache (tests)."""
    with _MEMO_LOCK:
        _MEMO.clear()
        _TABLE_CACHE.clear()


def selector_from_rows(rows: Iterable[Dict]) -> Dict[str, str]:
    """Distill sweep/scenario result rows into a ``{bench: policy}``
    selector: per benchmark, the concrete policy with the lowest mean
    ``cycles`` across its rows (ties break in ``EVICTION_POLICIES``
    order).  Feed the output to ``REPRO_ADAPTIVE_TABLE`` (as JSON) to
    pin later adaptive sweeps to matrix-derived choices."""
    sums: Dict[Tuple[str, str], Tuple[int, int]] = {}
    for row in rows:
        pol = row.get("eviction")
        if pol not in EVICTION_POLICIES or row.get("cycles") is None:
            continue
        k = (row["bench"], pol)
        total, n = sums.get(k, (0, 0))
        sums[k] = (total + int(row["cycles"]), n + 1)
    out: Dict[str, str] = {}
    for bench in sorted({b for b, _ in sums}):
        scored = [(sums[(bench, p)][0] / sums[(bench, p)][1], i, p)
                  for i, p in enumerate(EVICTION_POLICIES)
                  if (bench, p) in sums]
        out[bench] = min(scored)[2]
    return out


#: parsed selector tables keyed by (path, mtime_ns): the sweep's prepare
#: stage resolves a cell per *thread*, and re-reading + re-parsing the
#: JSON once per cell turned the table lookup into a hot stat+parse loop
#: on large grids — the cache re-reads only when the file actually
#: changes on disk
_TABLE_CACHE: Dict[Tuple[str, int], Dict[str, str]] = {}


def _table() -> Dict[str, str]:
    path = os.environ.get("REPRO_ADAPTIVE_TABLE")
    if not path:
        return {}
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError as e:
        raise FileNotFoundError(
            f"REPRO_ADAPTIVE_TABLE points at an unreadable selector "
            f"table {path!r} ({e}); unset the variable or fix the path "
            "(the table format is the JSON written by "
            "'python -m repro.uvm.adaptive')") from e
    key = (path, mtime)
    with _MEMO_LOCK:
        hit = _TABLE_CACHE.get(key)
    if hit is not None:
        return hit
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict) and isinstance(doc.get("selector"), dict):
        doc = doc["selector"]
    table = {str(b): validate_policy(p) for b, p in doc.items()}
    with _MEMO_LOCK:
        _TABLE_CACHE.clear()          # one live table at a time
        _TABLE_CACHE[key] = table
    return table


#: probe prefetcher proxy per cell prefetcher family — ``learned``
#: probes under an oracle over the prefix (its predictions are
#: near-oracle when trained, and training inside a probe would dwarf
#: the cell itself)
_PROBE_PROXIES = {"none": "none", "block": "block", "tree": "tree",
                  "oracle": "oracle", "learned": "oracle"}


def probe_proxy(prefetcher: Optional[str]) -> str:
    """The proxy family a cell's prefetcher probes under (also the memo
    key component, so e.g. oracle and learned cells share one probe)."""
    return _PROBE_PROXIES.get(prefetcher or "none", "none")


def _probe_prefetcher(proxy: str, prefix):
    from repro.uvm.prefetchers import (BlockPrefetcher, NoPrefetcher,
                                       OraclePrefetcher, TreePrefetcher)
    if proxy == "block":
        return BlockPrefetcher()
    if proxy == "tree":
        return TreePrefetcher()
    if proxy == "oracle":
        import numpy as np
        return OraclePrefetcher(np.asarray(prefix.pages))
    return NoPrefetcher()


def _probe(trace, device_pages: int, probe_accesses: int,
           proxy: str = "none") -> str:
    """Replay a prefix of ``trace`` under every concrete policy (with
    the cell's probe-proxy prefetcher) and return the cheapest.
    Capacity is scaled so the prefix sees the same oversubscription
    ratio as the full cell."""
    # local imports: this module is part of the sweep's jax-free surface
    from repro.uvm.config import UVMConfig
    from repro.uvm.replay_core import ReplayRequest, dispatch

    n = len(trace.accesses)
    prefix = trace
    if n > probe_accesses:
        prefix = trace.split(probe_accesses / n)[0]
    ratio = device_pages / trace.working_set_pages
    probe_pages = max(1, int(prefix.working_set_pages * ratio))
    best = None
    for i, policy in enumerate(EVICTION_POLICIES):
        cfg = UVMConfig(device_pages=probe_pages, eviction=policy)
        stats = dispatch(
            ReplayRequest(prefix, _probe_prefetcher(proxy, prefix), cfg),
            backend="numpy")
        score = (stats.cycles, i)
        if best is None or score < best[0]:
            best = (score, policy)
    return best[1]


def resolve_eviction(policy: str, bench: str, trace=None,
                     device_pages: Optional[int] = None,
                     probe_accesses: int = PROBE_ACCESSES,
                     prefetcher: Optional[str] = None) -> str:
    """Resolve a cell's eviction policy to a concrete one.

    Non-adaptive policies validate and pass through unchanged.  For
    ``adaptive``: selector table first, then the probe replay (memoized
    per (trace content, capacity, probe-proxy family) — thread-safe,
    the sweep's prepare stage runs in a pool), and ``lru`` when there
    is no eviction pressure to measure.  ``prefetcher`` is the cell's
    prefetcher name: the probe replays under its proxy family (see
    :func:`probe_proxy`) so a tree-prefetched cell is not resolved from
    demand-paging behavior it will never exhibit.
    """
    if not is_adaptive(policy):
        return validate_policy(policy)
    table = _table()
    if bench in table:
        return table[bench]
    if (trace is None or device_pages is None
            or device_pages >= trace.working_set_pages):
        return EVICTION_POLICIES[0]
    proxy = probe_proxy(prefetcher)
    from repro.uvm import predcache
    memo_key = (predcache.trace_content_key(trace), device_pages,
                probe_accesses, proxy)
    with _MEMO_LOCK:
        hit = _MEMO.get(memo_key)
    if hit is not None:
        return hit
    choice = _probe(trace, device_pages, probe_accesses, proxy)
    with _MEMO_LOCK:
        _MEMO.setdefault(memo_key, choice)
    return choice


def main(argv=None) -> None:
    """Distill sweep results into a selector table::

        python -m repro.uvm.adaptive results.json --out table.json

    ``results.json`` is a sweep output (``{"rows": [...]}`` or a bare row
    list); the table is the ``{bench: policy}`` JSON that
    ``REPRO_ADAPTIVE_TABLE`` consumes.
    """
    import argparse
    import sys

    ap = argparse.ArgumentParser(
        description="Distill sweep result rows into an adaptive-eviction "
                    "selector table (REPRO_ADAPTIVE_TABLE format)")
    ap.add_argument("results", help="sweep results.json (rows with "
                                    "bench/eviction/cycles)")
    ap.add_argument("--out", default=None,
                    help="write the table here (default: stdout)")
    args = ap.parse_args(argv)
    with open(args.results) as f:
        doc = json.load(f)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    table = selector_from_rows(rows)
    if not table:
        ap.error("no usable rows (need bench, concrete eviction, cycles)")
    blob = json.dumps({"selector": table,
                       "note": "bench -> cheapest mean-cycles eviction "
                               "policy; consumed via REPRO_ADAPTIVE_TABLE"},
                      indent=1, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
    else:
        sys.stdout.write(blob + "\n")


if __name__ == "__main__":
    main()
