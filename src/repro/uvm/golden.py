"""Golden-equivalence matrix for the UVM engines.

Defines the small, fully deterministic (trace × prefetcher × config) matrix
used to pin the legacy :class:`~repro.uvm.simulator.UVMSimulator` against
recorded fixtures, and to prove the vectorized engine reproduces it exactly.

Fixtures live at ``tests/golden/uvm_golden.json``; regenerate after an
*intentional* timing-model change with::

    PYTHONPATH=src python scripts/regen_uvm_golden.py

The matrix covers the paper's interesting regimes: ATAX (dominant-delta
matrix sweeps), Pathfinder (DP row reuse), a BICG-style clustered-fault storm
under MSHR pressure (the paper's Fig 11 serialization effect), an
oversubscribed cyclic sweep with LRU eviction churn, and a tree-churn case
(permuted sweeps alternating between two far-apart regions under
oversubscription, so tree node counts rise and fall continuously — the
regime the vectorized ``_TreeAdapter`` must track exactly).  Each trace runs
against all seven prefetcher variants: on-demand, block, tree, learned,
learned-cached (identical predictions round-tripped through the
``repro.uvm.predcache`` atomic store, pinning the cache path bit-exact
against plain learned), learned-tf (a distance-16 Transformer-family
stand-in cached under ``model_family="transformer"``, pinning the
family-keyed cache path), and oracle.

Per-policy oversubscribed cells (``oversub-random``/``oversub-hotcold``
on a thrashing cyclic sweep, ``churn-random``/``churn-hotcold`` on a
permuted two-region sweep) pin the non-LRU eviction policies
(``repro.uvm.eviction``) bit-equal across every backend, prefetcher
included — the regime where victim-selection order diverges first.

Multi-tenant cells (``mt-shared``/``mt-quota``) replay an interleaved
ATAX+Pathfinder trace (``repro.traces.interleave``) under oversubscribed
shared capacity and under hard per-tenant quotas with a spill pool —
pinning per-tenant residency accounting and tenant-masked victim
selection bit-equal across every backend (the fixtures record
``tenant_hits`` too).
"""
from __future__ import annotations

import atexit
import dataclasses
import functools
import shutil
import tempfile
from typing import Callable, Dict, Iterator, List, Tuple

import numpy as np

from repro.traces.trace import ROOT_PAGES, Trace, make_records
from repro.uvm.config import UVMConfig
from repro.uvm.prefetchers import (BlockPrefetcher, LearnedPrefetcher,
                                   NoPrefetcher, OraclePrefetcher, Prefetcher,
                                   TreePrefetcher)
from repro.uvm.simulator import UVMStats

#: integer counters that must match the legacy engine exactly
INT_FIELDS = ("n_accesses", "n_instructions", "hits", "late", "faults",
              "prefetch_issued", "prefetch_used", "pages_migrated",
              "pages_evicted")
#: float accumulators (bit-equal in practice; compared to tight rel. tol.)
FLOAT_FIELDS = ("cycles", "pcie_bytes", "zero_copy_bytes")

PREFETCHER_NAMES = ("none", "block", "tree", "learned", "learned-cached",
                    "learned-tf", "oracle")

#: prediction distance / inference overhead of the synthetic learned model
LEARNED_DISTANCE = 32
LEARNED_OVERHEAD_US = 1.0

#: the learned-tf cells model the reference-Transformer family: a
#: *different* prediction distance (so their predictions measurably
#: differ from the simplified cells') round-tripped through predcache
#: under ``model_family="transformer"`` — the fixtures then pin the
#: family-keyed cache path: a key collision would cross-serve
#: distance-32 predictions into these cells and fail every backend
LEARNED_TF_DISTANCE = 16


@dataclasses.dataclass(frozen=True)
class GoldenCase:
    name: str
    trace: Trace
    config: UVMConfig


def _mk_trace(name: str, pages: np.ndarray, inst_per_access: int = 100) -> Trace:
    recs = make_records(len(pages))
    recs["page"] = pages
    recs["sm"] = np.arange(len(pages)) % 4
    return Trace(name, recs, {}, {}, len(pages) * inst_per_access)


@functools.lru_cache(maxsize=1)
def golden_cases() -> Tuple[GoldenCase, ...]:
    from repro.traces import GPUModel, generate_benchmark

    atax = GPUModel().run(generate_benchmark("ATAX", scale=0.25))
    pathfinder = GPUModel().run(generate_benchmark("Pathfinder", scale=0.25))

    # BICG-style clustered faults: bursts of new pages a large stride apart,
    # replayed under a tight MSHR so the fault storms serialize (Fig 11).
    bicg = np.concatenate([np.arange(k, k + 50, dtype=np.int64)
                           for k in range(0, 12000, 200)])

    # Oversubscribed cyclic sweep: the working set is ~1.7x device memory,
    # so LRU eviction churns continuously (including in-flight victims).
    oversub = np.tile(np.arange(2500, dtype=np.int64), 6)

    # Tree churn under oversubscription: two far-apart 3-chunk regions are
    # swept alternately in a stride-7 permuted order (blocks fill out of
    # sequence, so >50% escalations fire at varied points), with capacity
    # for only ~2/3 of the union — chunks migrate, evict, and re-migrate,
    # driving tree node counts up and down for the whole replay.
    n_churn = 3 * ROOT_PAGES
    perm = (np.arange(n_churn, dtype=np.int64) * 7) % n_churn
    churn = np.concatenate([perm + (0 if k % 2 == 0 else 8192)
                            for k in range(8)])

    # Per-policy oversubscribed regimes (smaller traces — every cell also
    # replays through the interpret-mode pallas lanes in CI): a thrashing
    # cyclic sweep at ~1.8x capacity, and a permuted two-region sweep
    # whose blocks migrate/evict/re-migrate continuously, so random
    # priority draws and hot/cold frequency ranks churn the whole replay.
    pol_oversub = np.tile(np.arange(2000, dtype=np.int64), 4)
    n_pol = 2 * ROOT_PAGES
    pol_perm = (np.arange(n_pol, dtype=np.int64) * 5) % n_pol
    pol_churn = np.concatenate([pol_perm + (0 if k % 2 == 0 else 4096)
                                for k in range(6)])

    # Multi-tenant interleave: ATAX and Pathfinder zipped into one stream
    # with disjoint page regions, replayed at ~0.6x the union working set
    # so both tenants feel eviction pressure — once contending for the
    # whole device (mt-shared) and once under hard 40%/40% quotas with a
    # 20% spill pool and tenant-masked hotcold victim selection (mt-quota)
    from repro.traces.interleave import build_mt_trace
    mt = build_mt_trace("ATAX+Pathfinder", scale=0.25)
    mt_cap = int(0.6 * mt.working_set_pages)

    return (
        GoldenCase("atax", atax, UVMConfig()),
        GoldenCase("pathfinder", pathfinder, UVMConfig()),
        GoldenCase("bicg-cluster", _mk_trace("bicg-cluster", bicg),
                   UVMConfig(mshr_entries=16)),
        GoldenCase("oversub", _mk_trace("oversub", oversub),
                   UVMConfig(device_pages=1500)),
        GoldenCase("tree-churn", _mk_trace("tree-churn", churn),
                   UVMConfig(device_pages=2048)),
        GoldenCase("oversub-random", _mk_trace("oversub-random", pol_oversub),
                   UVMConfig(device_pages=1100, eviction="random")),
        GoldenCase("oversub-hotcold",
                   _mk_trace("oversub-hotcold", pol_oversub),
                   UVMConfig(device_pages=1100, eviction="hotcold")),
        GoldenCase("churn-random", _mk_trace("churn-random", pol_churn),
                   UVMConfig(device_pages=700, eviction="random",
                             mshr_entries=16)),
        GoldenCase("churn-hotcold", _mk_trace("churn-hotcold", pol_churn),
                   UVMConfig(device_pages=700, eviction="hotcold",
                             mshr_entries=16)),
        GoldenCase("mt-shared", mt, UVMConfig(device_pages=mt_cap)),
        GoldenCase("mt-quota", mt,
                   UVMConfig(device_pages=mt_cap,
                             tenant_pages=(int(0.4 * mt_cap),
                                           int(0.4 * mt_cap)),
                             eviction="hotcold")),
    )


def perfect_preds(trace: Trace, distance: int = LEARNED_DISTANCE) -> np.ndarray:
    """Deterministic stand-in for the trained model: perfect distance-k
    predictions (exercises the LearnedPrefetcher pipeline without jax)."""
    pages = np.asarray(trace.pages, dtype=np.int64)
    preds = np.full(len(pages), -1, dtype=np.int64)
    if len(pages) > distance:
        preds[:-distance] = pages[distance:]
    return preds


@functools.lru_cache(maxsize=1)
def _roundtrip_cache_dir() -> str:
    """Process-lifetime scratch dir for the learned-cached golden cells
    (removed at interpreter exit so repeated runs don't litter /tmp)."""
    path = tempfile.mkdtemp(prefix="uvm_golden_predcache_")
    atexit.register(shutil.rmtree, path, ignore_errors=True)
    return path


def make_prefetcher(name: str, trace: Trace, config: UVMConfig) -> Prefetcher:
    if name == "none":
        return NoPrefetcher()
    if name == "block":
        return BlockPrefetcher()
    if name == "tree":
        return TreePrefetcher()
    if name == "learned":
        return LearnedPrefetcher(
            perfect_preds(trace),
            extra_latency_cycles=LEARNED_OVERHEAD_US * config.cycles_per_us)
    if name in ("learned-cached", "learned-tf"):
        # same predictions as "learned" (learned-cached) or the
        # Transformer-family stand-in at a different prediction distance
        # (learned-tf), round-tripped through the prediction cache's
        # atomic npz store — the fixtures pin the cache path to replay
        # bit-identically to the direct array, and the two names differ
        # *only* by model_family in their keys, so a family-blind key
        # would cross-serve the wrong distance and fail every backend
        from repro.uvm import predcache
        family = "transformer" if name == "learned-tf" else "simplified"
        distance = (LEARNED_TF_DISTANCE if name == "learned-tf"
                    else LEARNED_DISTANCE)
        key = predcache.predictions_key(trace, kind="golden-roundtrip",
                                        model_family=family)
        cache_dir = _roundtrip_cache_dir()
        preds = predcache.load(cache_dir, key)
        if preds is None:
            predcache.store(cache_dir, key, perfect_preds(trace, distance))
            preds = predcache.load(cache_dir, key)
        return LearnedPrefetcher(
            preds,
            extra_latency_cycles=LEARNED_OVERHEAD_US * config.cycles_per_us)
    if name == "oracle":
        return OraclePrefetcher(np.asarray(trace.pages))
    raise ValueError(f"unknown prefetcher {name!r}")


def golden_cell_ids() -> List[str]:
    return [f"{case.name}/{pf}" for case in golden_cases()
            for pf in PREFETCHER_NAMES]


def golden_cell(cell_id: str) -> Tuple[Trace, UVMConfig, Callable[[], Prefetcher]]:
    case_name, pf_name = cell_id.split("/")
    case = next(c for c in golden_cases() if c.name == case_name)
    return (case.trace, case.config,
            lambda: make_prefetcher(pf_name, case.trace, case.config))


def golden_cell_policy(cell_id: str) -> str:
    """Eviction policy of one golden cell's config (lane batches are
    policy-homogeneous, so the pallas harness groups by it)."""
    case_name = cell_id.split("/")[0]
    case = next(c for c in golden_cases() if c.name == case_name)
    return case.config.eviction


def iter_golden_cells() -> Iterator[Tuple[str, Trace, UVMConfig,
                                          Callable[[], Prefetcher]]]:
    for cell_id in golden_cell_ids():
        trace, config, factory = golden_cell(cell_id)
        yield cell_id, trace, config, factory


def stats_to_dict(stats: UVMStats) -> Dict:
    out = {f: int(getattr(stats, f)) for f in INT_FIELDS}
    out.update({f: float(getattr(stats, f)) for f in FLOAT_FIELDS})
    if stats.tenant_hits is not None:
        # multi-tenant cells pin the per-tenant accounting too
        out["tenant_hits"] = [int(x) for x in stats.tenant_hits]
        out["tenant_accesses"] = [int(x) for x in stats.tenant_accesses]
    return out
