"""Page-granular UVM simulator.

Queue-based timing model in GPU core cycles:

* the GPU issues coalesced GMMU requests at a fixed instruction throughput;
* a far-fault pays host page-walk + fault service latency (45 us) and then
  queues its page migration on the PCIe channel (bandwidth + latency);
* prefetched pages ride the bus behind the demand page;
* the GPU hides up to ``mshr_entries`` outstanding faults behind fine-grained
  multithreading — beyond that the clock stalls to the oldest completion
  (this is what serializes clustered faults when the bus is saturated, the
  BICG effect in the paper's Fig 11);
* accesses to in-flight pages (late prefetches / duplicate faults) stall the
  warp until the page arrives;
* under oversubscription, pages are evicted (with writeback traffic) by a
  pluggable policy — LRU by default, counter-based random or
  access-frequency hot/cold via ``UVMConfig.eviction``
  (see ``repro.uvm.eviction``).

IPC is instructions / modeled cycles.  Absolute IPC is a proxy, but all
paper-facing results are *normalized* (ours vs UVMSmart), which cancels the
issue-throughput constant.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.traces.trace import Trace
from repro.uvm.config import UVMConfig
from repro.uvm.eviction import make_eviction_policy, resolve_tenancy
from repro.uvm.prefetchers import Prefetcher


@dataclasses.dataclass
class UVMStats:
    name: str
    prefetcher: str
    n_accesses: int
    n_instructions: int
    cycles: float
    hits: int
    late: int              # demanded while in-flight (late prefetch)
    faults: int            # demand far-faults
    prefetch_issued: int
    prefetch_used: int
    pages_migrated: int
    pages_evicted: int
    pcie_bytes: float
    zero_copy_bytes: float
    timeline: Optional[np.ndarray] = None   # (cycle, bytes) per transfer
    #: replay backend that actually produced these stats ("legacy" /
    #: "numpy" / "pallas"); set by the backend layer so sweep rows can
    #: surface silent fallbacks.  None when a simulator was run directly.
    backend: Optional[str] = None
    #: eviction policy the replay ran under (``UVMConfig.eviction``);
    #: surfaced in sweep result rows alongside ``backend``.
    eviction: str = "lru"
    #: replay clock after the last access of each requested step window
    #: (``ReplayRequest.step_bounds`` / ``UVMSimulator.run(step_bounds=)``);
    #: None unless bounds were requested.  Serving traces use this for
    #: per-decode-step latency and TTFT percentiles
    #: (``repro.offload.serve_trace``).
    step_clocks: Optional[np.ndarray] = None
    #: per-tenant (hits, accesses) on multi-tenant interleaved traces
    #: (``repro.traces.interleave``); None on single-tenant replays.  The
    #: sweep's per-tenant hit-rate columns derive from these.
    tenant_hits: Optional[Tuple[int, int]] = None
    tenant_accesses: Optional[Tuple[int, int]] = None

    @property
    def ipc(self) -> float:
        return self.n_instructions / max(self.cycles, 1.0)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.n_accesses, 1)

    @property
    def accuracy(self) -> float:
        """Fraction of prefetched pages that were used before eviction."""
        if self.prefetch_issued == 0:
            return 1.0
        return self.prefetch_used / self.prefetch_issued

    @property
    def coverage(self) -> float:
        """Fraction of would-be misses mitigated by prefetching."""
        would_be = self.prefetch_used + self.faults + self.late
        if would_be == 0:
            return 1.0
        return self.prefetch_used / would_be

    @property
    def unity(self) -> float:
        return float(np.cbrt(self.accuracy * self.coverage * self.hit_rate))


class UVMSimulator:
    def __init__(self, config: UVMConfig | None = None,
                 record_timeline: bool = False) -> None:
        self.config = config or UVMConfig()
        self.record_timeline = record_timeline

    def run(self, trace: Trace, prefetcher: Prefetcher,
            step_bounds: Optional[np.ndarray] = None) -> UVMStats:
        """Replay one trace.  ``step_bounds`` (optional, non-decreasing
        exclusive end indices into the access stream) requests the replay
        clock after the last access of each window — recorded in
        ``UVMStats.step_clocks``.  A bound of 0 (an empty leading window)
        completes at clock 0.0; an empty middle window repeats the
        previous window's clock."""
        cfg = self.config
        # policy name validated even when memory is never oversubscribed,
        # so a typo fails fast instead of silently simulating uncapped
        policy = make_eviction_policy(cfg.eviction)
        prefetcher.reset()
        pages = trace.pages
        n = len(pages)
        # Every trace record is a TLB-missed coalesced request: it pays a GMMU
        # page-table walk plus a DRAM access, and amortizes the kernel's
        # arithmetic.  This per-access cost sets the prefetch lead-time scale
        # (prediction distance d buys ~d * cycles_per_access of slack).
        cycles_per_access = (cfg.page_table_walk_cycles + cfg.dram_cycles
                             + cfg.access_overhead_cycles
                             + (trace.n_instructions / max(n, 1)) / cfg.issue_ipc)

        # page -> arrival cycle (usable when clock >= arrival). OrderedDict
        # doubles as the LRU (move_to_end on touch).
        resident: "OrderedDict[int, float]" = OrderedDict()
        prefetched_unused: Dict[int, bool] = {}

        clock = 0.0
        pcie_free = 0.0
        outstanding: List[float] = []   # min-heap of unresolved stall points

        hits = late = faults = 0
        prefetch_issued = prefetch_used = 0
        pages_migrated = pages_evicted = 0
        pcie_bytes = 0.0
        zero_copy_bytes = 0.0
        timeline: List[Tuple[float, float]] = []

        page_tx = cfg.page_transfer_cycles
        cap = cfg.device_pages
        track = cap is not None      # policy callbacks only matter capped

        # multi-tenant traces: per-tenant hit counters always; per-tenant
        # residency counters + tenant-masked victim selection only when
        # hard quotas split the capacity (see repro.uvm.eviction.Tenancy)
        tenancy = resolve_tenancy(trace, cfg)
        split = track and tenancy is not None and tenancy.split
        if split:
            policy.bind_tenancy(tenancy.tenant_of)
        rc = [0, 0]                  # per-tenant resident page counts
        th = [0, 0]                  # per-tenant hits

        if step_bounds is not None:
            sb = np.asarray(step_bounds, dtype=np.int64)
            if sb.size and (np.any(np.diff(sb) < 0) or sb[-1] > n):
                raise ValueError("step_bounds must be non-decreasing "
                                 "end indices <= n_accesses")
            step_clocks = np.zeros(sb.size, dtype=np.float64)
        else:
            sb = None
            step_clocks = None
        sp = 0
        while sb is not None and sp < sb.size and sb[sp] == 0:
            sp += 1                  # leading empty windows end at clock 0.0

        def schedule_prefetch(extras, batch: bool) -> None:
            nonlocal pcie_free, pages_migrated, pcie_bytes, prefetch_issued
            # Prefetches are driver-initiated: they skip the 45us fault
            # service and only pay runtime overhead (+ model inference
            # latency for the learned prefetcher), then queue on the bus.
            # ``batch=True`` models the driver's block/chunk DMA granularity:
            # the whole group transfers as one DMA and every page in it
            # becomes usable only at *batch completion* — this is the tree
            # prefetcher's timeliness weakness.  Single-page learned
            # prefetches (batch=False) complete page by page.
            ex_ready = (clock + cfg.prefetch_overhead_cycles
                        + prefetcher.extra_latency_cycles)
            ex_start = max(pcie_free, ex_ready)
            end = ex_start + len(extras) * page_tx
            t = ex_start
            for q in extras:
                t += page_tx
                ex_arr = (end if batch else t) + cfg.pcie_latency_cycles
                if split and q not in resident:
                    rc[tenancy.tenant_of(q)] += 1
                resident[q] = ex_arr
                if track:
                    policy.on_insert(q)
                prefetched_unused[q] = True
                pages_migrated += 1
                pcie_bytes += cfg.page_size
                if self.record_timeline:
                    timeline.append((ex_arr, float(cfg.page_size)))
            pcie_free = end
            prefetch_issued += len(extras)
            prefetcher.on_migrate(list(extras))

        for i in range(n):
            p = int(pages[i])
            clock += cycles_per_access
            arr = resident.get(p)
            if arr is not None:
                if arr <= clock:
                    hits += 1
                    if tenancy is not None:
                        th[tenancy.tenant_of(p)] += 1
                    if prefetched_unused.pop(p, None):
                        prefetch_used += 1
                else:
                    # demanded while in flight: warp stalls till arrival
                    late += 1
                    heapq.heappush(outstanding, arr)
                    if prefetched_unused.pop(p, None):
                        prefetch_used += 1
                resident.move_to_end(p)
                if track:
                    policy.on_touch(p)
            else:
                # ---- far fault ----
                # The driver services the GPU fault buffer in batched rounds
                # of ~one fault-service latency: a fault raised during round
                # k is resolved at the end of round k+1 (uniform 1-2x 45us).
                # Driver-initiated prefetches skip this path entirely —
                # that asymmetry is what the paper's prefetcher exploits.
                faults += 1
                ff = cfg.far_fault_cycles
                ready = ((clock // ff) + 2.0) * ff + cfg.page_table_walk_cycles
                start = max(ready, pcie_free)
                arrival = start + cfg.pcie_latency_cycles + page_tx
                pcie_free = start + page_tx
                if split:
                    rc[tenancy.tenant_of(p)] += 1
                resident[p] = arrival
                resident.move_to_end(p)
                if track:
                    policy.on_insert(p)
                pages_migrated += 1
                pcie_bytes += cfg.page_size
                if self.record_timeline:
                    timeline.append((arrival, float(cfg.page_size)))
                heapq.heappush(outstanding, arrival)
                prefetcher.on_migrate([p])

                extras = prefetcher.on_fault(i, p, resident)
                if extras:
                    schedule_prefetch(extras, batch=True)

            # continuous (per-request) prefetching — the learned predictor
            # sits at the UVM backend and predicts on every read-request.
            extras = prefetcher.on_access(i, p, resident, clock)
            if extras:
                schedule_prefetch(extras, batch=False)

            # MSHR pressure: too many outstanding faults -> stall to oldest
            while len(outstanding) > cfg.mshr_entries:
                clock = max(clock, heapq.heappop(outstanding))

            # eviction under oversubscription: the policy picks victims
            # (LRU = first key of the order-maintained dict, exactly the
            # historical popitem(last=False))
            if track:
                while True:
                    if split:
                        # per-tenant quotas: trim whichever tenant is over
                        # its allowance (tenant 0 first — the vectorized
                        # engines and the pallas kernel use the same
                        # order), victim masked to that tenant's pages
                        a0, a1 = tenancy.allowed(rc[0], rc[1])
                        if rc[0] > a0:
                            u: Optional[int] = 0
                        elif rc[1] > a1:
                            u = 1
                        else:
                            break
                    else:
                        if len(resident) <= cap:
                            break
                        u = None
                    victim = policy.select_victim(resident, u)
                    v_arr = resident[victim]
                    if v_arr > clock:
                        # never evict in-flight pages; retouch at MRU
                        resident.move_to_end(victim)
                        policy.on_touch(victim)
                        break
                    del resident[victim]
                    if split:
                        rc[u] -= 1
                    policy.on_evict(victim)
                    prefetched_unused.pop(victim, None)
                    prefetcher.on_evict(victim)
                    pages_evicted += 1
                    # writeback traffic (assume half the evictions dirty)
                    if pages_evicted % 2 == 0:
                        pcie_bytes += cfg.page_size
                        pcie_free += page_tx

            # step-window clocks: the iteration for access i completes
            # windows whose exclusive end is i+1 (duplicates = empty windows)
            if sb is not None:
                while sp < sb.size and sb[sp] <= i + 1:
                    step_clocks[sp] = clock
                    sp += 1

        # drain: all outstanding stalls resolve
        while outstanding:
            clock = max(clock, heapq.heappop(outstanding))

        return UVMStats(
            name=trace.name,
            prefetcher=prefetcher.name,
            n_accesses=n,
            n_instructions=trace.n_instructions,
            cycles=clock,
            hits=hits,
            late=late,
            faults=faults,
            prefetch_issued=prefetch_issued,
            prefetch_used=prefetch_used,
            pages_migrated=pages_migrated,
            pages_evicted=pages_evicted,
            pcie_bytes=pcie_bytes,
            zero_copy_bytes=zero_copy_bytes,
            timeline=np.asarray(timeline) if self.record_timeline else None,
            eviction=cfg.eviction,
            step_clocks=step_clocks,
            tenant_hits=(th[0], th[1]) if tenancy is not None else None,
            tenant_accesses=_tenant_accesses(pages, tenancy),
        )


def _tenant_accesses(pages: np.ndarray,
                     tenancy) -> Optional[Tuple[int, int]]:
    """Host-side per-tenant access counts (every backend derives these
    the same way — the counts are a property of the trace slice, not of
    the replay)."""
    if tenancy is None:
        return None
    n1 = int(np.count_nonzero(np.asarray(pages) >= tenancy.boundary))
    return int(len(pages)) - n1, n1
