"""Data pipeline substrate."""
from repro.data.lm_pipeline import TokenPipeline

__all__ = ["TokenPipeline"]
