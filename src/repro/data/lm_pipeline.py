"""Deterministic, checkpointable synthetic LM token pipeline.

Generates structured token streams (mixture of Zipfian unigrams and repeated
motifs, so models have something learnable) sharded by data-parallel rank.
The iterator state is a plain dict — saved with the checkpoint, restored
exactly: a preempted job resumes on the batch it would have seen.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    batch_size: int            # per-host batch
    seed: int = 0
    rank: int = 0
    world: int = 1
    motif_len: int = 16
    n_motifs: int = 256

    def __post_init__(self) -> None:
        self._step = 0
        base = np.random.default_rng(self.seed)
        v = min(self.vocab, 65536)
        self._motifs = base.integers(
            0, v, size=(self.n_motifs, self.motif_len))
        # Zipf-ish unigram distribution
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()
        self._v = v

    # ------------------------------------------------------------------
    def state(self) -> Dict:
        return {"step": self._step, "seed": self.seed, "rank": self.rank,
                "world": self.world}

    def restore(self, state: Dict) -> None:
        assert state["seed"] == self.seed and state["world"] == self.world, \
            "pipeline config changed across restore"
        self._step = int(state["step"])

    # ------------------------------------------------------------------
    def _rng_for(self, step: int) -> np.random.Generator:
        # counter-based: batch content depends only on (seed, rank, step)
        return np.random.default_rng(
            (self.seed * 1_000_003 + self.rank) * 2_000_003 + step)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._rng_for(self._step)
        self._step += 1
        toks = rng.choice(self._v, p=self._p,
                          size=(self.batch_size, self.seq_len))
        # overwrite random spans with motifs (learnable repeats)
        n_spans = self.seq_len // (self.motif_len * 4)
        for b in range(self.batch_size):
            for _ in range(max(n_spans, 1)):
                m = rng.integers(0, self.n_motifs)
                at = rng.integers(0, max(self.seq_len - self.motif_len, 1))
                toks[b, at:at + self.motif_len] = self._motifs[m]
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
