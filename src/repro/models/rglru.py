"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training runs a chunked associative scan (log-depth); decode carries the
(B, D) hidden state with one update per token.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm

_C = 8.0


def _lru_scan(a: jnp.ndarray, bx: jnp.ndarray,
              h0: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t h_{t-1} + bx_t via associative scan over S.
    a, bx: (B, S, D)."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_c, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h, h[:, -1]


def rglru_block(x: jnp.ndarray, p: Dict, *,
                state: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gated-MLP wrapper around the RG-LRU temporal mixer (Griffin block).
    x: (B, S, D).  state: (B, D_rnn).  Returns (y, new_state)."""
    b, s, d = x.shape
    h = rmsnorm(x, p["ln"])
    u = jnp.einsum("bsd,de->bse", h, p["w_in"])          # (B,S,Drnn)
    gate_branch = jax.nn.gelu(jnp.einsum("bsd,de->bse", h, p["w_gate"]))

    r = jax.nn.sigmoid(jnp.einsum("bse,ef->bsf", u, p["w_r"]) + p["b_r"])
    i = jax.nn.sigmoid(jnp.einsum("bse,ef->bsf", u, p["w_i"]) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]).astype(x.dtype) * r  # (B,S,Dr)
    a = jnp.exp(log_a).astype(x.dtype)
    gated = (jnp.sqrt(jnp.maximum(1.0 - a.astype(jnp.float32) ** 2, 1e-6))
             .astype(x.dtype) * (i * u))

    if s == 1 and state is not None:
        hseq = a[:, 0] * state + gated[:, 0]
        new_state = hseq.astype(x.dtype)
        hseq = hseq[:, None]
    else:
        hseq, new_state = _lru_scan(a, gated, state)
        new_state = new_state.astype(x.dtype)

    y = jnp.einsum("bse,ed->bsd", hseq.astype(x.dtype) * gate_branch,
                   p["w_out"])
    return (x + y).astype(x.dtype), new_state
