"""Mamba-2 SSD (state-space duality) block.

Chunked linear-recurrence formulation (Dao & Gu, 2024): within chunks the
quadratic "attention-like" form runs on the MXU; across chunks a scalar-decay
state recurrence propagates (B, H, P, N) states.  Decode is O(1): one state
update per token.

Shapes: d_inner = 2 * d_model, P = head_dim (64), H = d_inner / P,
N = ssm_state (128), single B/C group.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


def _ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """x: (B,S,H,P); dt: (B,S,H); b,c: (B,S,N).  Returns (B,S,H,P).

    Sequential ``lax.scan`` over chunks: intra-chunk work is the quadratic
    MXU-friendly form; the carried (B,H,P,N) state gives the inter-chunk
    recurrence.  Peak live memory is one chunk's (B,L,L,H) decay tensor,
    independent of sequence length.
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nc = s // chunk
    xa = (x * dt[..., None]).reshape(bsz, nc, chunk, h, p)
    la = (-jnp.exp(a_log)[None, None, :] * dt).reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(state, inp):
        xa_c, la_c, b_c, c_c = inp        # (B,L,H,P),(B,L,H),(B,L,N),(B,L,N)
        cum = jnp.cumsum(la_c, axis=1)                      # (B,L,H)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        decay = jnp.where(causal[None, :, :, None], decay, 0.0)
        scores = jnp.einsum("bln,bsn->bls", c_c, b_c)       # (B,L,L)
        y = jnp.einsum("bls,blsh,bshp->blhp", scores, decay, xa_c)
        # contribution of the carried state
        y = y + jnp.einsum("bln,blh,bhpn->blhp", c_c, jnp.exp(cum), state)
        total = cum[:, -1]                                  # (B,H)
        sdecay = jnp.exp(total[:, None, :] - cum)           # (B,L,H)
        new_state = (state * jnp.exp(total)[..., None, None]
                     + jnp.einsum("bsh,bsn,bshp->bhpn", sdecay, b_c, xa_c))
        return new_state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final_state, ys = jax.lax.scan(
        step, init,
        (xa.transpose(1, 0, 2, 3, 4), la.transpose(1, 0, 2, 3),
         bc.transpose(1, 0, 2, 3), cc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final_state.astype(x.dtype)


def ssd_block(x: jnp.ndarray, p: Dict, *, head_dim: int, ssm_state: int,
              chunk: int = 256,
              state: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, final_state).  state (B, H, P, N) enables O(1) decode
    when x has S == 1."""
    bsz, s, d = x.shape
    h = rmsnorm(x, p["ln"])
    d_inner = p["wx"].shape[1] // 2
    nheads = d_inner // head_dim
    xz = jnp.einsum("bsd,de->bse", h, p["wx"])                 # (B,S,2*din)
    xi, z = jnp.split(xz, 2, axis=-1)
    bc = jnp.einsum("bsd,dn->bsn", h, p["wbc"])                # (B,S,2N)
    b_in, c_in = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", h, p["wdt"])
                         + p["dt_bias"])                       # (B,S,H)
    xh = xi.reshape(bsz, s, nheads, head_dim)

    if s == 1 and state is not None:
        # decode: h' = exp(-exp(A) dt) h + dt * B x ; y = C h'
        la = -jnp.exp(p["a_log"])[None, None, :] * dt          # (B,1,H)
        dec = jnp.exp(la).astype(x.dtype)                      # (B,1,H)
        xb = jnp.einsum("bshp,bsn->bhpn", xh * dt[..., None].astype(x.dtype),
                        b_in)
        new_state = (state * dec[:, 0, :, None, None] + xb).astype(x.dtype)
        y = jnp.einsum("bhpn,bsn->bshp", new_state, c_in)
    else:
        y, new_state = _ssd_chunked(xh, dt, p["a_log"], b_in, c_in,
                                    min(chunk, s))
    y = y.reshape(bsz, s, d_inner).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    return (x + out).astype(x.dtype), new_state
