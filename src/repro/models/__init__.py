"""Model zoo: the 10 assigned architectures as composable JAX model defs.

Architectures are described by ``ArchConfig`` (repro.configs): a sequence of
homogeneous *segments*, each a repeated block pattern (attention + dense FFN,
attention + MoE, SSD, RG-LRU, local attention, ...).  Segments scan over
stacked per-layer parameters so HLO size stays flat in depth — essential for
the 94-layer Qwen3 multi-pod dry-run.
"""
from repro.models.builder import (
    build_model, init_params, train_loss, prefill, decode, Model,
)

__all__ = ["build_model", "init_params", "train_loss", "prefill", "decode",
           "Model"]
