"""Shared neural layers: RMSNorm, RoPE, GQA attention (train/prefill/decode),
SwiGLU MLP, embeddings.

Attention has two execution paths: a pure-XLA einsum path (used for the
multi-pod dry-run — Pallas cannot lower on CPU hosts) and the Pallas flash
kernel path for TPU runtime (``use_flash``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * g


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool, window: Optional[int] = None,
            q_offset: int = 0, f32_logits: bool = True) -> jnp.ndarray:
    """q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D).  Pure-XLA GQA attention.
    ``q_offset``: position of q[0] within the kv sequence (decode)."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    acc_t = jnp.float32 if f32_logits else q.dtype
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=acc_t)
    logits = (logits / jnp.sqrt(jnp.asarray(d, acc_t))).astype(acc_t)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, d)


def attention_block(x: jnp.ndarray, p: Dict, *, n_heads: int, n_kv: int,
                    head_dim: int, causal: bool = True,
                    window: Optional[int] = None,
                    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    cache_index: Optional[jnp.ndarray] = None,
                    positions: Optional[jnp.ndarray] = None,
                    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    want_cache: bool = False, f32_logits: bool = True):
    """Pre-norm attention block.  Returns (y, new_cache).

    * train: cache None, want_cache False -> new_cache None.
    * prefill: cache None, want_cache True -> new_cache = fresh (k, v).
    * decode: cache (B, S_max, Hkv, D) x2 + cache_index -> updated in place.
    * cross attention: cross_kv provides fixed K/V (encoder output).
    """
    b, s, dm = x.shape
    h = rmsnorm(x, p["ln"])
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    q_off_arr = None
    if positions is None:
        if cache_index is None:
            positions = jnp.arange(s)[None, :]
        else:
            positions = jnp.arange(s)[None, :] + cache_index
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        q = rope(q, positions)
        k = rope(k, positions)
        new_cache = (k, v) if want_cache else None
        q_off = 0
        if cache is not None:
            ck, cv = cache
            idx = cache_index if cache_index is not None else 0
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, idx, axis=1)
            k, v = ck, cv
            new_cache = (ck, cv)
            q_off = idx
        out = _attend(q, k, v, causal=causal, window=window,
                      q_offset=q_off, f32_logits=f32_logits)
    else:
        k, v = cross_kv
        out = _attend(q, k, v, causal=False, f32_logits=f32_logits)
        new_cache = None
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return x + y, new_cache


def cross_kv_proj(enc: jnp.ndarray, p: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    return k, v


def swiglu_block(x: jnp.ndarray, p: Dict) -> jnp.ndarray:
    h = rmsnorm(x, p["ln"])
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, p["wg"]))
    up = jnp.einsum("bsd,df->bsf", h, p["wu"])
    return x + jnp.einsum("bsf,fd->bsd", gate * up, p["wd"])
