"""Mixture-of-experts FFN with top-k routing and capacity-based
scatter/gather dispatch.

Dispatch uses scatter-add into per-expert slot buffers and combine gathers
back — O(T*K*D) data movement plus the expert matmuls.  (The classic
one-hot-einsum dispatch costs O(T*E*C*D) compute, which at 65k tokens x 16
experts is ~100x the expert FLOPs themselves; the §Perf log records that
before/after.)  The expert dimension shards over the "model" mesh axis;
GSPMD turns the slot scatter/gather into expert-parallel exchanges.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


def _routing(ht, router, n_experts, top_k, capacity):
    """Shared routing: returns (gate_vals, gate_idx, pos, keep, probs)."""
    logits = jnp.einsum("td,de->te", ht.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)             # (T, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # (T,K,E)
    flat = onehot.reshape(-1, n_experts)
    pos = jnp.cumsum(flat, axis=0) - flat                          # (TK, E)
    pos = (pos * flat).sum(-1).reshape(gate_idx.shape)
    keep = pos < capacity
    return gate_vals * keep, gate_idx, pos, keep, probs, onehot


def moe_block(x: jnp.ndarray, p: Dict, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, dispatch: str = "grouped",
              group_tokens: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D).  p: router (D, E), experts wg/wu (E, D, F), wd (E, F, D).
    Returns (y, aux_loss)."""
    b, s, d = x.shape
    h = rmsnorm(x, p["ln"])
    n_tokens = b * s
    ht = h.reshape(n_tokens, d)

    if dispatch == "grouped":
        # GShard-style token groups: one-hot einsum dispatch costs
        # O(T * Tg * K * D) instead of O(T^2 K D) — group size bounds the
        # quadratic term while keeping the all-to-all-friendly einsum form.
        g = max(n_tokens // max(group_tokens, 1), 1)
        tg = n_tokens // g
        cap = max(int(capacity_factor * tg * top_k / n_experts), 4)
        gate_vals, gate_idx, pos, keep, probs, onehot = _routing(
            ht, p["router"], n_experts, top_k, cap)
        # per-group positions: recompute cumsum within groups
        oh_g = onehot.reshape(g, tg, top_k, n_experts)
        flat = oh_g.reshape(g, tg * top_k, n_experts)
        posg = jnp.cumsum(flat, axis=1) - flat
        posg = (posg * flat).sum(-1).reshape(g, tg, top_k)
        keep = posg < cap
        gv = (gate_vals.reshape(g, tg, top_k) * keep)
        hg = ht.reshape(g, tg, d)
        disp = (jax.nn.one_hot(gate_idx.reshape(g, tg, top_k), n_experts,
                               dtype=ht.dtype)[..., None]
                * jax.nn.one_hot(jnp.where(keep, posg, cap), cap + 1,
                                 dtype=ht.dtype)[..., None, :])
        disp = disp[..., :cap]                       # (G,Tg,K,E,C)
        dispatch_t = disp.sum(2)                     # (G,Tg,E,C)
        combine_t = (disp * gv[..., None, None].astype(ht.dtype)).sum(2)
        xe = jnp.einsum("gtd,gtec->gecd", hg, dispatch_t)   # (G,E,C,D)
        xe = xe.transpose(1, 0, 2, 3).reshape(n_experts, g * cap, d)
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
        up = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
        ye = jnp.einsum("ecf,efd->ecd", gate * up, p["wd"])
        ye = ye.reshape(n_experts, g, cap, d).transpose(1, 0, 2, 3)
        y = jnp.einsum("gecd,gtec->gtd", ye, combine_t).reshape(b, s, d)

    elif dispatch == "einsum":
        cap = max(int(capacity_factor * n_tokens * top_k / n_experts), 4)
        gate_vals, gate_idx, pos, keep, probs, onehot = _routing(
            ht, p["router"], n_experts, top_k, cap)
        disp = (jax.nn.one_hot(gate_idx, n_experts, dtype=ht.dtype)[..., None]
                * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                                 dtype=ht.dtype)[..., None, :])
        disp = disp[..., :cap]                                  # (T,K,E,C)
        dispatch_t = disp.sum(1)
        combine_t = (disp * gate_vals[..., None, None].astype(ht.dtype)).sum(1)
        xe = jnp.einsum("td,tec->ecd", ht, dispatch_t)
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
        up = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
        ye = jnp.einsum("ecf,efd->ecd", gate * up, p["wd"])
        y = jnp.einsum("ecd,tec->td", ye, combine_t).reshape(b, s, d)

    elif dispatch == "scatter":
        cap = max(int(capacity_factor * n_tokens * top_k / n_experts), 4)
        gate_vals, gate_idx, pos, keep, probs, onehot = _routing(
            ht, p["router"], n_experts, top_k, cap)
        n_slots = n_experts * cap
        slot = jnp.where(keep, gate_idx * cap + pos, n_slots)   # (T, K)
        xe_flat = jnp.zeros((n_slots + 1, d), ht.dtype)
        for k in range(top_k):
            xe_flat = xe_flat.at[slot[:, k]].add(ht)
        xe = xe_flat[:n_slots].reshape(n_experts, cap, d)
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
        up = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
        ye = jnp.einsum("ecf,efd->ecd", gate * up, p["wd"])
        ye_flat = jnp.concatenate(
            [ye.reshape(n_slots, d), jnp.zeros((1, d), ye.dtype)])
        y = jnp.zeros_like(ht)
        for k in range(top_k):
            y = y + (ye_flat[slot[:, k]]
                     * gate_vals[:, k, None].astype(ht.dtype))
        y = y.reshape(b, s, d)
    else:
        raise ValueError(f"unknown moe dispatch {dispatch!r}")

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)
    ce = (onehot.sum(1).astype(jnp.float32)).mean(0) / top_k
    aux = n_experts * jnp.sum(me * ce)
    return x + y.astype(x.dtype), aux
