"""Model builder: ArchConfig -> init / train_loss / prefill / decode.

Depth is organized as *segments* of a repeated block pattern; parameters are
stacked over layers within a segment and applied with ``lax.scan`` so HLO
size is independent of depth.  Supported block kinds:

    attn   causal self-attention (GQA)        lattn  windowed self-attention
    eattn  bidirectional (encoder)            xattn  cross-attention
    ffn    SwiGLU MLP                         moe    top-k mixture of experts
    ssd    Mamba-2 state-space duality        lru    RG-LRU (Griffin)

Decode state: attention blocks carry (k, v) caches; ssd carries (B,H,P,N)
states; lru carries (B,Dr) states — each stacked over the segment's layers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.arch import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.moe import moe_block
from repro.models.rglru import rglru_block
from repro.models.ssm import ssd_block


@dataclasses.dataclass(frozen=True)
class Segment:
    count: int
    pattern: Tuple[str, ...]
    encoder: bool = False      # bidirectional, no cache


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    segments: Tuple[Segment, ...]
    enc_segments: Tuple[Segment, ...] = ()


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "vlm"):
        segs = (Segment(cfg.n_layers, ("attn", "ffn")),)
    elif cfg.family == "moe":
        segs = (Segment(cfg.n_layers, ("attn", "moe")),)
    elif cfg.family == "ssm":
        segs = (Segment(cfg.n_layers, ("ssd",)),)
    elif cfg.family == "hybrid":
        period = cfg.pattern or ("lru", "lru", "lattn")
        full, rem = divmod(cfg.n_layers, len(period))
        segs = []
        if full:
            segs.append(Segment(full, tuple(period)))
        if rem:
            segs.append(Segment(1, tuple(period[:rem])))
        segs = tuple(segs)
    elif cfg.family == "audio":
        segs = (Segment(cfg.n_layers, ("attn", "xattn", "ffn")),)
        enc = (Segment(cfg.enc_layers, ("eattn", "ffn"), encoder=True),)
        return Model(cfg, segs, enc)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return Model(cfg, segs)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(kind: str, cfg: ArchConfig, key, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.hd
    ks = jax.random.split(key, 10)

    def dense(k, shape):
        scale = 1.0 / math.sqrt(shape[0] if len(shape) == 2 else shape[-2])
        if kind == "moe" and len(shape) == 3:
            scale = 1.0 / math.sqrt(shape[1])
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    if kind in ("attn", "lattn", "eattn", "xattn"):
        return {
            "ln": jnp.ones((d,), dtype),
            "wq": dense(ks[0], (d, cfg.n_heads, hd)),
            "wk": dense(ks[1], (d, cfg.n_kv, hd)),
            "wv": dense(ks[2], (d, cfg.n_kv, hd)),
            "wo": (jax.random.normal(ks[3], (cfg.n_heads, hd, d), jnp.float32)
                   / math.sqrt(cfg.n_heads * hd)).astype(dtype),
        }
    if kind == "ffn":
        return {
            "ln": jnp.ones((d,), dtype),
            "wg": dense(ks[0], (d, cfg.d_ff)),
            "wu": dense(ks[1], (d, cfg.d_ff)),
            "wd": dense(ks[2], (cfg.d_ff, d)),
        }
    if kind == "moe":
        e, f = cfg.n_experts, cfg.d_ff
        return {
            "ln": jnp.ones((d,), dtype),
            "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02,
            "wg": dense(ks[1], (e, d, f)),
            "wu": dense(ks[2], (e, d, f)),
            "wd": dense(ks[3], (e, f, d)),
        }
    if kind == "ssd":
        din = 2 * d
        h = din // cfg.ssm_head_dim
        n = cfg.ssm_state
        return {
            "ln": jnp.ones((d,), dtype),
            "wx": dense(ks[0], (d, 2 * din)),
            "wbc": dense(ks[1], (d, 2 * n)),
            "wdt": dense(ks[2], (d, h)),
            "dt_bias": jnp.zeros((h,), dtype),
            "a_log": jnp.zeros((h,), jnp.float32),
            "wo": dense(ks[3], (din, d)),
        }
    if kind == "lru":
        dr = d
        return {
            "ln": jnp.ones((d,), dtype),
            "w_in": dense(ks[0], (d, dr)),
            "w_gate": dense(ks[1], (d, dr)),
            "w_r": dense(ks[2], (dr, dr)),
            "w_i": dense(ks[3], (dr, dr)),
            "b_r": jnp.zeros((dr,), dtype),
            "b_i": jnp.zeros((dr,), dtype),
            "lam": jnp.full((dr,), 1.0, jnp.float32),
            "w_out": dense(ks[4], (dr, d)),
        }
    raise ValueError(f"unknown block kind {kind}")


def _init_segment(seg: Segment, cfg: ArchConfig, key, dtype) -> Dict:
    def one_layer(k):
        kk = jax.random.split(k, len(seg.pattern))
        return {f"b{i}_{kind}": _init_block(kind, cfg, kk[i], dtype)
                for i, kind in enumerate(seg.pattern)}
    keys = jax.random.split(key, seg.count)
    per_layer = [one_layer(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def init_params(model: Model, key) -> Dict:
    cfg = model.cfg
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8 + len(model.segments)
                            + len(model.enc_segments))
    p: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_ln": jnp.ones((cfg.d_model,), dtype),
        "segments": [
            _init_segment(seg, cfg, keys[2 + i], dtype)
            for i, seg in enumerate(model.segments)
        ],
    }
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(keys[1], (cfg.d_model, cfg.vocab),
                                       jnp.float32)
                     / math.sqrt(cfg.d_model)).astype(dtype)
    if model.enc_segments:
        off = 2 + len(model.segments)
        p["enc_segments"] = [
            _init_segment(seg, cfg, keys[off + i], dtype)
            for i, seg in enumerate(model.enc_segments)
        ]
        p["enc_final_ln"] = jnp.ones((cfg.d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_block(kind: str, h, bp, cfg: ArchConfig, *, mode: str,
                 state=None, cache_index=None, enc_out=None):
    """Returns (h, new_state)."""
    if kind in ("attn", "lattn", "eattn"):
        window = cfg.window if kind == "lattn" else None
        causal = kind != "eattn"
        want_cache = mode == "prefill" and kind != "eattn"
        return L.attention_block(
            h, bp, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            causal=causal, window=window,
            cache=state, cache_index=cache_index, want_cache=want_cache,
            f32_logits=cfg.attn_f32_logits)
    if kind == "xattn":
        if mode == "prefill":
            ckv = L.cross_kv_proj(enc_out, bp)
            y, _ = L.attention_block(
                h, bp, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
                cross_kv=ckv, f32_logits=cfg.attn_f32_logits)
            return y, ckv
        ckv = state if state is not None else L.cross_kv_proj(enc_out, bp)
        y, _ = L.attention_block(
            h, bp, n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            cross_kv=ckv, f32_logits=cfg.attn_f32_logits)
        return y, (ckv if mode == "decode" else None)
    if kind == "ffn":
        return L.swiglu_block(h, bp), None
    if kind == "moe":
        y, aux = moe_block(h, bp, n_experts=cfg.n_experts, top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           dispatch=cfg.moe_dispatch,
                           group_tokens=cfg.moe_group_tokens)
        return y, aux          # aux routed through "state" slot, summed later
    if kind == "ssd":
        return ssd_block(h, bp, head_dim=cfg.ssm_head_dim,
                         ssm_state=cfg.ssm_state, state=state,
                         chunk=cfg.ssd_chunk)
    if kind == "lru":
        return rglru_block(h, bp, state=state)
    raise ValueError(kind)


_STATEFUL = ("attn", "lattn", "xattn", "ssd", "lru")


def _segment_scan(seg: Segment, seg_params, h, cfg: ArchConfig, *,
                  mode: str, states=None, cache_index=None, enc_out=None,
                  remat: bool):
    """Scan one segment.  states: dict block-slot -> stacked state (or None).
    Returns (h, new_states, aux)."""

    def body(carry, xs):
        hh = carry
        layer_params, layer_states = xs
        new_states = {}
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(seg.pattern):
            key = f"b{i}_{kind}"
            st = None if layer_states is None else layer_states.get(key)
            hh, out = _apply_block(kind, hh, layer_params[key], cfg,
                                   mode=mode, state=st,
                                   cache_index=cache_index, enc_out=enc_out)
            if kind == "moe":
                aux = aux + out
            elif out is not None and (mode != "train"):
                new_states[key] = out
        return hh, (new_states if new_states else None, aux)

    if remat:
        body = jax.checkpoint(body)

    if seg.count <= 2:
        # unrolled: exact cost accounting for the dry-run probes (XLA's
        # cost_analysis counts a while-loop body once, so probe programs
        # must not scan) — and no scan overhead for 1-2 layer segments.
        outs = []
        for i in range(seg.count):
            layer_params = jax.tree.map(lambda x: x[i], seg_params)
            layer_states = (None if states is None
                            else jax.tree.map(lambda x: x[i], states))
            h, y = body(h, (layer_params, layer_states))
            outs.append(y)
        new_states = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[o[0] for o in outs])
        auxs = jnp.stack([o[1] for o in outs])
        return h, new_states, auxs.sum()

    h, (new_states, auxs) = jax.lax.scan(body, h, (seg_params, states))
    return h, new_states, auxs.sum()


def all_segments(model: Model):
    """Main + encoder segments, in probe order."""
    return tuple(model.segments) + tuple(model.enc_segments)


def with_counts(model: Model, counts) -> Model:
    """Probe helper: same architecture with overridden segment layer counts
    (used by the dry-run's cost-extrapolation probes).  ``counts`` covers
    main segments then encoder segments."""
    n = len(model.segments)
    segs = tuple(dataclasses.replace(s, count=c)
                 for s, c in zip(model.segments, counts[:n]))
    enc = tuple(dataclasses.replace(s, count=c)
                for s, c in zip(model.enc_segments, counts[n:]))
    return Model(model.cfg, segs, enc)


def _embed_tokens(cfg: ArchConfig, params, tokens):
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    return h * jnp.sqrt(jnp.float32(cfg.d_model)).astype(h.dtype)


def _logits(cfg: ArchConfig, params, h):
    h = L.rmsnorm(h, params["final_ln"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["head"])
    return constrain(logits, ("pod", "data"), None, "model")


def _encode(cfg: ArchConfig, model: Model, params, frames, *, remat):
    h = frames.astype(jnp.dtype(cfg.dtype))
    for seg, sp in zip(model.enc_segments, params["enc_segments"]):
        h, _, _ = _segment_scan(seg, sp, h, cfg, mode="train", remat=remat)
    return L.rmsnorm(h, params["enc_final_ln"])


def _backbone(cfg, model, params, h, *, mode, states=None, cache_index=None,
              enc_out=None, remat=True):
    all_states = []
    aux_total = jnp.zeros((), jnp.float32)
    for si, (seg, sp) in enumerate(zip(model.segments, params["segments"])):
        st = None if states is None else states[si]
        h = constrain(h, ("pod", "data"), None, None)
        h, new_st, aux = _segment_scan(
            seg, sp, h, cfg, mode=mode, states=st, cache_index=cache_index,
            enc_out=enc_out, remat=remat)
        all_states.append(new_st)
        aux_total = aux_total + aux
    return h, all_states, aux_total


def train_loss(model: Model, params, batch: Dict[str, jnp.ndarray],
               aux_weight: float = 0.01) -> Tuple[jnp.ndarray, Dict]:
    """Next-token CE (+ MoE load-balance aux).  batch:
    tokens (B, S) int32; vlm: + patches (B, P, d); audio: + frames (B, F, d).
    """
    cfg = model.cfg
    tokens = batch["tokens"]
    h = _embed_tokens(cfg, params, tokens)
    n_text = tokens.shape[1]
    enc_out = None
    if cfg.family == "vlm":
        h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
    if cfg.family == "audio":
        enc_out = _encode(cfg, model, params, batch["frames"],
                          remat=cfg.remat)
    h = constrain(h, ("pod", "data"), None, None)
    h, _, aux = _backbone(cfg, model, params, h, mode="train",
                          enc_out=enc_out, remat=cfg.remat)
    h = h[:, -n_text:]
    logits = _logits(cfg, params, h)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp[:, :-1], tgt[..., None], axis=-1)
    loss = nll.mean() + aux_weight * aux
    return loss, {"nll": nll.mean(), "aux": aux}


def init_decode_state(model: Model, params_shape, batch: int, max_len: int,
                      enc_len: int = 0):
    """Abstract/concrete decode-state skeleton matching `prefill` output."""
    cfg = model.cfg
    dtype = jnp.dtype(cfg.dtype)
    states = []
    for seg in model.segments:
        seg_states: Dict[str, Any] = {}
        for i, kind in enumerate(seg.pattern):
            key = f"b{i}_{kind}"
            if kind in ("attn", "lattn"):
                shp = (seg.count, batch, max_len, cfg.n_kv, cfg.hd)
                seg_states[key] = (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))
            elif kind == "xattn":
                shp = (seg.count, batch, enc_len, cfg.n_kv, cfg.hd)
                seg_states[key] = (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))
            elif kind == "ssd":
                din = 2 * cfg.d_model
                h = din // cfg.ssm_head_dim
                seg_states[key] = jnp.zeros(
                    (seg.count, batch, h, cfg.ssm_head_dim, cfg.ssm_state),
                    dtype)
            elif kind == "lru":
                seg_states[key] = jnp.zeros(
                    (seg.count, batch, cfg.d_model), dtype)
        states.append(seg_states if seg_states else None)
    return states


def prefill(model: Model, params, batch: Dict[str, jnp.ndarray],
            max_len: Optional[int] = None):
    """Run the prompt; returns (last-position logits, decode states).
    KV caches are padded to ``max_len``."""
    cfg = model.cfg
    tokens = batch["tokens"]
    bsz, s = tokens.shape
    h = _embed_tokens(cfg, params, tokens)
    enc_out = None
    if cfg.family == "vlm":
        h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
    if cfg.family == "audio":
        enc_out = _encode(cfg, model, params, batch["frames"],
                          remat=cfg.remat)
    h, states, _ = _backbone(cfg, model, params, h, mode="prefill",
                             enc_out=enc_out, remat=cfg.remat)
    logits = _logits(cfg, params, h[:, -1:])
    if max_len is not None and max_len > h.shape[1]:
        pad = max_len - h.shape[1]

        def pad_seg(seg_states):
            if seg_states is None:
                return None
            out = {}
            for key, st in seg_states.items():
                if ("attn" in key) and ("xattn" not in key):
                    out[key] = tuple(
                        jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                        for x in st)
                else:
                    out[key] = st
            return out

        states = [pad_seg(s) for s in states]
    return logits, states


def decode(model: Model, params, states, tokens_1: jnp.ndarray,
           index: jnp.ndarray):
    """One decode step.  tokens_1: (B, 1); index: scalar int32 position.
    Returns (logits (B, 1, V), new states)."""
    cfg = model.cfg
    h = _embed_tokens(cfg, params, tokens_1)
    h, new_states, _ = _backbone(cfg, model, params, h, mode="decode",
                                 states=states, cache_index=index,
                                 remat=False)
    return _logits(cfg, params, h), new_states
