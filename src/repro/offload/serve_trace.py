"""Serving-traffic trace source: PagedKVStore fault streams as first-class
UVM replay traces.

The paged KV store (``repro.offload.paged_store``) is the serving-side
analogue of the paper's UVM page system, and its access/fault stream is the
same object the replay core consumes — so this module closes the loop and
makes serving workloads replayable on every registered backend:

* **block ↔ page** — one KV block (``BLOCK_TOKENS`` tokens, 64 KB) maps to
  one UVM page.  Each request's block space is laid out as its own
  2 MB-aligned (``ROOT_PAGES``) region, exactly like ``cudaMallocManaged``
  arrays in ``repro.traces.generators._Alloc``: request *r*, block *b*
  lives at page ``base + r * region_pages + b``, so the tree prefetcher's
  2 MB root windows align with per-request KV caches and the ``array``
  feature is the request id.
* **DMA ↔ far-fault** — a host→HBM block DMA is a page migration; a block
  miss is a far fault; the learned offload prefetcher's lookahead is the
  paper's prediction distance.
* **decode step ↔ kernel launch** — the decode-step index rides in the
  ``kernel`` field of :data:`~repro.traces.trace.ACCESS_DTYPE` (the access
  stream is step-major, so the column is non-decreasing);
  :func:`trace_step_bounds` recovers per-step access boundaries with one
  ``searchsorted``, and the replay core's ``step_bounds`` support
  (``repro.uvm.replay_core``) turns them into per-step completion clocks —
  the p50/p95/p99 decode-latency and TTFT columns of serve sweep rows.

Workloads are registered in :data:`SERVE_WORKLOADS` (continuous-batching
decode, multi-tenant mixes, bursty open-loop arrivals); rate-parameterized
variants parse on demand (``"ServeBursty@r128"`` = 128 requests/s), so
spawn-based sweep workers resolve any serve bench name without import-time
side effects.  :func:`build_serve_trace` is the sweep's trace generator:
a pure function of (bench, scale, seed), which is what the npz trace cache
and multi-process workers require.

The access stream is a pure function of the *workload* (decode attention
sweeps every history block regardless of residency), so one serve trace
replays unchanged under every (prefetcher × eviction × capacity) cell —
the same trace-vs-policy separation the UVM benchmarks have.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.offload.paged_store import BLOCK_TOKENS
from repro.traces.trace import ACCESS_DTYPE, ROOT_PAGES, Trace

#: decode-step compute time used to convert open-loop arrival times into
#: decode-step indices (a ~2 ms decode step at serving batch sizes)
DEFAULT_STEP_US = 2000.0

#: the ``kernel`` field of ACCESS_DTYPE is uint16 — a serve episode must
#: fit its step ids in it (with headroom below the 65535 ceiling)
MAX_SERVE_STEPS = 60_000


# ---------------------------------------------------------------------------
# workload specs + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeWorkload:
    """One serving workload spec (continuous-batching decode traffic).

    ``tenants`` is a tuple of (weight, prompt_mult, gen_mult) classes:
    each request draws a class by weight and scales its prompt/decode
    lengths by the class multipliers (the multi-tenant request mix).
    ``arrival`` is ``"batch"`` (all requests queued at step 0 — closed
    loop) or ``"open"`` (Poisson arrivals at ``rate_rps`` requests/s;
    ``burstiness`` b > 1 collapses a 1-1/b fraction of inter-arrival gaps
    to zero and stretches the rest by b, keeping the mean rate while
    clustering arrivals).
    """

    name: str
    n_requests: int = 24
    slots: int = 8                  # continuous-batching width
    prompt_len: int = 384
    gen: int = 96                   # decode steps per request (x gen_mult)
    arrival: str = "batch"          # "batch" | "open"
    rate_rps: float = 64.0
    burstiness: float = 1.0
    step_us: float = DEFAULT_STEP_US
    tenants: Tuple[Tuple[float, float, float], ...] = ((1.0, 1.0, 1.0),)


SERVE_WORKLOADS: Dict[str, ServeWorkload] = {
    # continuous-batching decode: two admission waves through 8 slots, so
    # late-wave requests see real queueing in their TTFT
    "ServeDecode": ServeWorkload(name="ServeDecode"),
    # multi-tenant mix: 3:1 short interactive vs long analytical requests
    "ServeTenantMix": ServeWorkload(
        name="ServeTenantMix", prompt_len=256,
        tenants=((3.0, 0.5, 0.75), (1.0, 3.0, 1.5))),
    # bursty open-loop arrivals: Poisson at rate_rps with 4x clustering
    "ServeBursty": ServeWorkload(
        name="ServeBursty", n_requests=32, prompt_len=256, gen=64,
        arrival="open", rate_rps=64.0, burstiness=4.0),
}


def is_serve_bench(name: str) -> bool:
    """True when ``name`` resolves to a registered serve workload
    (including ``Base@r<rate>`` rate-parameterized variants)."""
    try:
        get_serve_workload(name)
        return True
    except (KeyError, ValueError):
        return False


def get_serve_workload(name: str) -> ServeWorkload:
    """Resolve a serve bench name, parsing ``@r<rate>`` suffixes on demand
    (``"ServeBursty@r128"`` -> the ServeBursty spec at 128 requests/s,
    open-loop).  Parsing instead of registering keeps resolution a pure
    function of the name — spawn-based sweep workers need that."""
    base, sep, suffix = name.partition("@")
    try:
        wl = SERVE_WORKLOADS[base]
    except KeyError:
        raise KeyError(
            f"unknown serve workload {base!r}; "
            f"available: {sorted(SERVE_WORKLOADS)}") from None
    if not sep:
        return wl
    if not suffix.startswith("r"):
        raise ValueError(f"bad serve workload suffix {suffix!r} in "
                         f"{name!r}; expected '@r<rate_rps>'")
    rate = float(suffix[1:])
    if rate <= 0:
        raise ValueError(f"serve workload rate must be > 0, got {rate}")
    return dataclasses.replace(wl, name=name, arrival="open", rate_rps=rate)


# ---------------------------------------------------------------------------
# load generator: workload spec -> access/step episode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeEpisode:
    """One driven workload: the (request, block) access stream with its
    decode-step structure and per-request arrival/first-decode steps."""

    workload: ServeWorkload
    req: np.ndarray                 # int64 request id per access
    blk: np.ndarray                 # int64 block id per access
    step: np.ndarray                # int64 step id per access, non-decreasing
    n_steps: int
    prompt_lens: np.ndarray         # tokens, per request
    gen_lens: np.ndarray            # decode steps, per request
    arrival_steps: np.ndarray       # step index each request arrived at
    first_steps: np.ndarray         # step index of each request's first decode


def drive_workload(wl: ServeWorkload, *, scale: float = 1.0,
                   seed: int = 0) -> ServeEpisode:
    """Run the load generator: admit requests FIFO into ``wl.slots``
    continuous-batching slots and sweep every active request's history
    blocks each decode step (the ``PagedKVStore.on_decode_step`` access
    pattern, generalized to per-request positions).  ``scale`` multiplies
    decode lengths, keeping the arrival process — a pure function of
    (wl, scale, seed)."""
    if wl.slots <= 0 or wl.n_requests <= 0:
        raise ValueError(f"{wl.name}: slots and n_requests must be > 0")
    n = wl.n_requests
    rng = np.random.default_rng([seed, n, wl.slots])

    weights = np.asarray([t[0] for t in wl.tenants], dtype=np.float64)
    classes = rng.choice(len(wl.tenants), size=n, p=weights / weights.sum())
    p_mult = np.asarray([t[1] for t in wl.tenants])[classes]
    g_mult = np.asarray([t[2] for t in wl.tenants])[classes]
    prompt = np.maximum(
        BLOCK_TOKENS, np.rint(wl.prompt_len * p_mult)).astype(np.int64)
    gen = np.maximum(
        2, np.rint(max(wl.gen * scale, 2.0) * g_mult)).astype(np.int64)

    if wl.arrival == "batch":
        arrival_steps = np.zeros(n, dtype=np.int64)
    elif wl.arrival == "open":
        gaps = rng.exponential(1e6 / wl.rate_rps, size=n)
        gaps[0] = 0.0
        if wl.burstiness > 1.0:
            burst = rng.random(n) < (1.0 - 1.0 / wl.burstiness)
            gaps = np.where(burst, 0.0, gaps * wl.burstiness)
        arrival_steps = (np.cumsum(gaps) // wl.step_us).astype(np.int64)
    else:
        raise ValueError(f"{wl.name}: unknown arrival model {wl.arrival!r}")

    slots: List[Optional[int]] = [None] * wl.slots
    req_chunks: List[np.ndarray] = []
    blk_chunks: List[np.ndarray] = []
    step_chunks: List[np.ndarray] = []
    first_steps = np.full(n, -1, dtype=np.int64)
    decoded = np.zeros(n, dtype=np.int64)
    next_req = 0                    # arrivals are already time-ordered
    remaining = n
    t = 0
    while remaining > 0:
        while (next_req < n and arrival_steps[next_req] <= t
               and None in slots):
            slots[slots.index(None)] = next_req
            next_req += 1
        if all(s is None for s in slots):
            # idle gap before the next arrival: skip the empty steps
            # (they still exist in [0, n_steps) — their step bounds are
            # duplicates and their decode latency is zero-sized)
            t = int(arrival_steps[next_req])
            continue
        for slot in range(wl.slots):
            r = slots[slot]
            if r is None:
                continue
            if first_steps[r] < 0:
                first_steps[r] = t
            pos = int(prompt[r] + decoded[r])
            nb = pos // BLOCK_TOKENS + 1
            req_chunks.append(np.full(nb, r, dtype=np.int64))
            blk_chunks.append(np.arange(nb, dtype=np.int64))
            step_chunks.append(np.full(nb, t, dtype=np.int64))
            decoded[r] += 1
            if decoded[r] >= gen[r]:
                slots[slot] = None
                remaining -= 1
        t += 1
        if t > MAX_SERVE_STEPS:
            raise ValueError(
                f"{wl.name}: episode exceeds {MAX_SERVE_STEPS} decode "
                "steps (the uint16 kernel field); lower the request "
                "count or raise the arrival rate")
    return ServeEpisode(
        workload=wl,
        req=np.concatenate(req_chunks),
        blk=np.concatenate(blk_chunks),
        step=np.concatenate(step_chunks),
        n_steps=t,
        prompt_lens=prompt, gen_lens=gen,
        arrival_steps=arrival_steps, first_steps=first_steps)


# ---------------------------------------------------------------------------
# access log <-> Trace round-trip
# ---------------------------------------------------------------------------

def _serve_meta(*, n_requests: int, blocks_per_seq: int, base: int,
                region_pages: int, n_steps: int, step_us: float,
                arrival_steps: Sequence[int],
                first_steps: Sequence[int]) -> Dict:
    """The ``trace.meta["serve"]`` sidecar: pure-Python values only (the
    sweep's npz cache serializes meta through JSON)."""
    return {
        "n_requests": int(n_requests),
        "blocks_per_seq": int(blocks_per_seq),
        "base": int(base),
        "region_pages": int(region_pages),
        "n_steps": int(n_steps),
        "step_us": float(step_us),
        "arrival_steps": [int(x) for x in arrival_steps],
        "first_steps": [int(x) for x in first_steps],
    }


def _encode_trace(req: np.ndarray, blk: np.ndarray, step: np.ndarray, *,
                  name: str, seed: int, n_requests: int,
                  blocks_per_seq: int, n_steps: int, step_us: float,
                  arrival_steps: Sequence[int],
                  first_steps: Sequence[int]) -> Trace:
    if np.any(np.diff(step) < 0):
        raise ValueError("serve access stream must be step-major "
                         "(non-decreasing step ids)")
    if n_steps > MAX_SERVE_STEPS:
        raise ValueError(f"{n_steps} steps exceed the uint16 kernel field")
    if blk.size and int(blk.max()) >= blocks_per_seq:
        raise ValueError(
            f"block id {int(blk.max())} outside blocks_per_seq="
            f"{blocks_per_seq}: position and capacity accounting disagree")
    region = ((blocks_per_seq - 1) // ROOT_PAGES + 1) * ROOT_PAGES
    # seeded heap base, 2 MB-aligned — the same idiom as the benchmark
    # generators' cudaMallocManaged model (traces.generators._Alloc)
    base_rng = np.random.default_rng([seed, 0x5E12])
    base = int(base_rng.integers(1 << 10, 1 << 18)) * ROOT_PAGES

    n = req.size
    recs = np.zeros(n, dtype=ACCESS_DTYPE)
    recs["pc"] = (0x400000 + (req << 5)).astype(np.uint32)
    recs["sm"] = (req % 28).astype(np.uint16)
    recs["tpc"] = (recs["sm"] // 2).astype(np.uint16)
    recs["cta"] = req.astype(np.uint32)
    recs["warp"] = (req * 4 + blk % 4).astype(np.uint32)
    recs["kernel"] = step.astype(np.uint16)
    recs["array"] = req.astype(np.uint16)     # 'In' feature = request id
    recs["page"] = base + req * region + blk

    array_bases = {f"req{r}": int(base + r * region)
                   for r in range(n_requests)}
    array_pages = {f"req{r}": int(blocks_per_seq)
                   for r in range(n_requests)}
    meta = {"serve": _serve_meta(
        n_requests=n_requests, blocks_per_seq=blocks_per_seq, base=base,
        region_pages=region, n_steps=n_steps, step_us=step_us,
        arrival_steps=arrival_steps, first_steps=first_steps)}
    # each access is one coalesced attention block read; the instruction
    # budget amortizes the per-block attention math like the benchmark
    # generators amortize kernel arithmetic
    return Trace(name=name, accesses=recs, array_bases=array_bases,
                 array_pages=array_pages, n_instructions=n * 300, meta=meta)


def episode_to_trace(ep: ServeEpisode, *, name: Optional[str] = None,
                     seed: int = 0) -> Trace:
    """Encode a driven episode as a replay-core :class:`Trace`."""
    max_pos = int((ep.prompt_lens + ep.gen_lens - 1).max())
    return _encode_trace(
        ep.req, ep.blk, ep.step, name=name or ep.workload.name, seed=seed,
        n_requests=ep.workload.n_requests,
        blocks_per_seq=max_pos // BLOCK_TOKENS + 1, n_steps=ep.n_steps,
        step_us=ep.workload.step_us, arrival_steps=ep.arrival_steps,
        first_steps=ep.first_steps)


def access_log_to_trace(log: Sequence[Tuple[int, int]], *, n_requests: int,
                        blocks_per_seq: int, name: str = "serve-log",
                        seed: int = 0,
                        step_ends: Optional[Sequence[int]] = None,
                        step_us: float = 10.0) -> Trace:
    """Encode a raw ``PagedKVStore.access_log`` as a replay-core trace.

    ``step_ends[k]`` is the log length after decode step *k* (cumulative
    access counts), recovering the step structure the store itself does
    not record; without it the whole log is one step.  The inverse is
    :func:`trace_to_access_log`, and the round trip is byte-identical
    (pinned by ``tests/test_offload.py``).
    """
    arr = np.asarray(list(log), dtype=np.int64).reshape(-1, 2)
    req, blk = arr[:, 0], arr[:, 1]
    if step_ends is None:
        ends = np.asarray([req.size], dtype=np.int64)
    else:
        ends = np.asarray(list(step_ends), dtype=np.int64)
        if ends.size == 0 or int(ends[-1]) != req.size:
            raise ValueError("step_ends must end at len(log)")
    step = np.searchsorted(ends, np.arange(req.size), side="right")
    first = np.zeros(n_requests, dtype=np.int64)
    for r in range(n_requests):
        hits = np.nonzero(req == r)[0]
        first[r] = step[hits[0]] if hits.size else 0
    return _encode_trace(
        req, blk, step, name=name, seed=seed, n_requests=n_requests,
        blocks_per_seq=blocks_per_seq, n_steps=int(ends.size),
        step_us=step_us, arrival_steps=np.zeros(n_requests, dtype=np.int64),
        first_steps=first)


def is_serve_trace(trace: Trace) -> bool:
    return bool(trace.meta) and "serve" in trace.meta


def trace_to_access_log(trace: Trace) -> List[Tuple[int, int]]:
    """Decode a serve trace's pages back to the store's (request, block)
    access log — the inverse of the block ↔ page mapping."""
    sv = _serve_sidecar(trace)
    rel = trace.accesses["page"] - int(sv["base"])
    region = int(sv["region_pages"])
    if rel.size and (rel.min() < 0
                     or rel.max() >= sv["n_requests"] * region):
        raise ValueError(f"pages outside the serve regions of {trace.name}")
    return list(zip((rel // region).tolist(), (rel % region).tolist()))


def _serve_sidecar(trace: Trace) -> Dict:
    if not is_serve_trace(trace):
        raise ValueError(f"trace {trace.name!r} is not a serve trace "
                         "(no meta['serve'] sidecar)")
    return trace.meta["serve"]


# ---------------------------------------------------------------------------
# sweep integration: bench name -> trace, step bounds, latency columns
# ---------------------------------------------------------------------------

def build_serve_trace(bench: str, *, scale: float = 1.0,
                      seed: int = 0) -> Trace:
    """The sweep's serve trace generator — a pure function of
    (bench, scale, seed), like the GPUModel benchmark path, so the npz
    trace cache and spawn workers stay deterministic."""
    wl = get_serve_workload(bench)
    ep = drive_workload(wl, scale=scale, seed=seed)
    return episode_to_trace(ep, name=bench, seed=seed)


def trace_step_bounds(trace: Trace) -> np.ndarray:
    """Per-decode-step access boundaries: ``bounds[k]`` = number of
    accesses in steps 0..k (an exclusive end index; empty steps repeat
    the previous bound).  Feed to ``ReplayRequest.step_bounds`` to get
    per-step completion clocks from any backend (host-side on
    legacy/numpy, in-kernel on the pallas lanes)."""
    sv = _serve_sidecar(trace)
    kern = np.asarray(trace.accesses["kernel"], dtype=np.int64)
    bounds = np.searchsorted(kern, np.arange(int(sv["n_steps"])),
                             side="right").astype(np.int64)
    if bounds.size and int(bounds[-1]) != len(trace):
        raise ValueError(
            f"serve trace {trace.name!r} was truncated after encoding "
            "(window-split?): step bounds no longer cover the accesses")
    return bounds


def serve_latency_columns(trace: Trace, step_clocks: np.ndarray,
                          config) -> Dict[str, Optional[float]]:
    """SLO percentile columns for one serve replay.

    ``step_clocks[k]`` is the replay clock (GPU cycles) after the last
    access of decode step *k* (``UVMStats.step_clocks``).  Per-step decode
    latency is the clock delta across each non-empty step; TTFT is each
    request's first-decode-step completion measured from the completion of
    the step before its arrival step (both in replay time, so queueing
    behind busy slots is included).  Returns the six
    ``decode_lat_p{50,95,99}_us`` / ``ttft_p{50,95,99}_us`` row columns.
    """
    from repro.uvm.metrics import slo_percentiles

    sv = _serve_sidecar(trace)
    bounds = trace_step_bounds(trace)
    clocks = np.asarray(step_clocks, dtype=np.float64)
    if clocks.size != bounds.size:
        raise ValueError(f"step_clocks has {clocks.size} steps, trace has "
                         f"{bounds.size}")
    t_us = config.us_from_cycles(clocks)
    lat = np.diff(np.concatenate([[0.0], t_us]))
    sizes = np.diff(np.concatenate([[0], bounds]))
    row = slo_percentiles(lat[sizes > 0], "decode_lat")
    arrival = np.asarray(sv["arrival_steps"], dtype=np.int64)
    first = np.asarray(sv["first_steps"], dtype=np.int64)
    start_us = np.where(arrival > 0, t_us[np.maximum(arrival - 1, 0)], 0.0)
    row.update(slo_percentiles(t_us[first] - start_us, "ttft"))
    return row


# ---------------------------------------------------------------------------
# npz persistence (the serve.py --dump-trace format == the sweep cache's)
# ---------------------------------------------------------------------------

def save_trace_npz(trace: Trace, path: str) -> None:
    """Persist a trace in the sweep cache's npz layout (accesses array +
    JSON meta), so dumped serving traces replay through the same loader."""
    meta = json.dumps({
        "name": trace.name,
        "array_bases": trace.array_bases,
        "array_pages": trace.array_pages,
        "n_instructions": trace.n_instructions,
        "meta": trace.meta,
    })
    np.savez(path, accesses=trace.accesses, meta=np.array(meta))


def load_trace_npz(path: str) -> Trace:
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"]))
        return Trace(name=meta["name"],
                     accesses=z["accesses"].astype(ACCESS_DTYPE, copy=False),
                     array_bases=meta["array_bases"],
                     array_pages=meta["array_pages"],
                     n_instructions=meta["n_instructions"],
                     meta=meta.get("meta", {}))
