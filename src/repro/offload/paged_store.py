"""Paged KV-cache store: host<->HBM block residency manager.

The TPU analogue of the paper's UVM page system: the KV cache is divided
into fixed-size *blocks* (the 64 KB basic-block analogue: BLOCK_TOKENS
tokens per request per block).  Decoding attention at position ``pos`` reads
every block of the request's history — blocks resident in HBM are hits;
absent blocks must DMA from host memory (the far-fault analogue).

This layer does residency accounting and transfer scheduling against a
bandwidth model (PCIe-class host link), and exposes the access stream the
learned prefetcher trains on.  It is exercised by ``launch/serve.py`` and
benchmarked in ``benchmarks/offload_bench.py``.

The access stream is also a first-class UVM replay trace source:
``repro.offload.serve_trace`` maps blocks to pages (one block = one page,
per-request 2 MB-aligned regions), DMAs to far-faults, and decode steps to
kernel ids, so serving workloads replay through the backend-pluggable
``repro.uvm.replay_core`` on every registered backend (the ``serve-*``
scenario family in ``repro.uvm.scenarios``).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Tuple

BLOCK_TOKENS = 64
BLOCK_BYTES = 64 * 1024          # 64 KB blocks, like the UVM basic block
HOST_LINK_GBS = 32.0             # host<->HBM DMA per chip
DMA_LATENCY_US = 5.0


@dataclasses.dataclass
class PagedKVStore:
    n_requests: int
    max_len: int
    hbm_capacity_blocks: int
    # eviction policy:
    #   "lru"  — rotate (degenerates to 0% under cyclic-sweep thrash);
    #   "pin"  — once HBM is full, new blocks are served from host WITHOUT
    #            caching (insertion bypass).  Decode attention sweeps the
    #            whole history every step; for cyclic sweeps a frozen
    #            resident set is Belady-optimal.  This is the serving-side
    #            analogue of the paper's soft-pinning/zero-copy insight
    #            (§2.1): under thrash, pin hot pages and remote-access the
    #            cold ones.
    evict: str = "lru"

    def __post_init__(self) -> None:
        # (request, block) -> arrival time; OrderedDict doubles as LRU
        self.resident: "OrderedDict[Tuple[int,int], float]" = OrderedDict()
        self.clock_us = 0.0
        self.link_free_us = 0.0
        self.hits = 0
        self.misses = 0
        self.prefetched: Dict[Tuple[int, int], bool] = {}
        self.prefetch_used = 0
        self.prefetch_issued = 0
        self.prefetch_bypassed = 0
        self.host_bytes = 0.0
        self.evictions = 0
        self.access_log: List[Tuple[int, int]] = []

    # ------------------------------------------------------------------
    @property
    def blocks_per_seq(self) -> int:
        """Blocks of KV history one request at ``max_len`` spans — the
        capacity-accounting bound every decode position must respect."""
        return (self.max_len - 1) // BLOCK_TOKENS + 1

    def _touch(self, key: Tuple[int, int]) -> None:
        self.resident.move_to_end(key)

    def _insert(self, key: Tuple[int, int], arrival: float) -> bool:
        """Insert a block; returns False when the pin policy's insertion
        bypass rejects it (served from host, never transferred)."""
        if (self.evict == "pin" and key not in self.resident
                and len(self.resident) >= self.hbm_capacity_blocks):
            return False  # insertion bypass: serve from host, don't thrash
        self.resident[key] = arrival
        self.resident.move_to_end(key)
        while len(self.resident) > self.hbm_capacity_blocks:
            victim, _ = self.resident.popitem(last=False)
            self.prefetched.pop(victim, None)
            self.evictions += 1
        return True

    def _dma(self, n_blocks: int) -> float:
        start = max(self.clock_us + DMA_LATENCY_US, self.link_free_us)
        dur = n_blocks * BLOCK_BYTES / (HOST_LINK_GBS * 1e3)  # us
        self.link_free_us = start + dur
        self.host_bytes += n_blocks * BLOCK_BYTES
        return start + dur

    # ------------------------------------------------------------------
    def on_decode_step(self, pos: int, step_us: float = 10.0) -> None:
        """Account one decode step at sequence position ``pos``: every block
        of every request's history is accessed.  ``pos`` is the *cache*
        position (prefix-inflated for VLM archs) — it must stay inside the
        ``max_len`` the store's capacity accounting was sized with."""
        if not 0 <= pos < self.max_len:
            raise ValueError(
                f"decode position {pos} outside max_len={self.max_len}: "
                "the KV-cache index and the store's capacity accounting "
                "disagree (VLM prefix dropped?)")
        self.clock_us += step_us
        n_blocks = pos // BLOCK_TOKENS + 1
        for r in range(self.n_requests):
            for blk in range(n_blocks):
                key = (r, blk)
                self.access_log.append(key)
                arr = self.resident.get(key)
                if arr is not None and arr <= self.clock_us:
                    self.hits += 1
                    if self.prefetched.pop(key, None):
                        self.prefetch_used += 1
                    self._touch(key)
                elif arr is not None:
                    # in flight: stall until arrival, but never re-DMA
                    self.misses += 1
                    self._touch(key)
                else:
                    self.misses += 1
                    arrival = self._dma(1)
                    self._insert(key, arrival)

    def prefetch(self, keys: List[Tuple[int, int]]) -> None:
        """Batch-DMA non-resident blocks ahead of demand.

        Only blocks *actually inserted* are charged to ``host_bytes`` /
        ``prefetch_issued`` and flagged in ``prefetched``: duplicates in
        one request are collapsed (one block, one transfer), and under the
        ``pin`` policy the batch is trimmed to the remaining HBM room
        up front — blocks the insertion bypass would reject are never
        transferred, so they must not inflate interconnect traffic or the
        prefetch-accuracy denominator (they are counted in
        ``prefetch_bypassed`` instead).
        """
        todo: List[Tuple[int, int]] = []
        seen = set()
        for k in keys:
            if k not in self.resident and k not in seen:
                todo.append(k)
                seen.add(k)
        if self.evict == "pin":
            room = max(self.hbm_capacity_blocks - len(self.resident), 0)
            self.prefetch_bypassed += max(len(todo) - room, 0)
            todo = todo[:room]
        if not todo:
            return
        arrival = self._dma(len(todo))
        for k in todo:
            inserted = self._insert(k, arrival)
            assert inserted, "prefetch batch was trimmed to the HBM room"
            self.prefetched[k] = True
        self.prefetch_issued += len(todo)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hit_rate": self.hits / max(total, 1),
            "prefetch_accuracy": (self.prefetch_used
                                  / max(self.prefetch_issued, 1)),
            "host_bytes": self.host_bytes,
            "evictions": float(self.evictions),
            "prefetch_bypassed": float(self.prefetch_bypassed),
        }
