"""Beyond-paper integration: the learned page prefetcher applied to
host<->HBM KV-cache offload paging during serving (TPUs have no UVM; the
same far-fault economics appear when the KV cache overflows HBM)."""
from repro.offload.paged_store import PagedKVStore
from repro.offload.learned_prefetcher import OffloadPrefetcher

__all__ = ["PagedKVStore", "OffloadPrefetcher"]
