"""Learned prefetcher for the paged KV store.

The decode access pattern over KV blocks is the serving-side analogue of the
paper's GMMU stream: per request, blocks 0..pos/B are swept every step, and
the working set grows by one block every BLOCK_TOKENS steps.  The predictor
here is the paper's *bypass* case in miniature — the block-delta stream has
extreme convergence (+1 sweeps), so per §6 the attention model is bypassed
and a delta-table predictor (the FC-equivalent) drives prefetch; the full
HLSH predictor (repro.core) plugs in through the same interface for
workloads with irregular reuse (benchmarks/offload_bench.py exercises both).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Tuple

from repro.offload.paged_store import BLOCK_TOKENS, PagedKVStore


@dataclasses.dataclass
class OffloadPrefetcher:
    store: PagedKVStore
    lookahead_blocks: int = 2

    def __post_init__(self) -> None:
        # per-request delta histogram over observed block transitions
        self._deltas: Dict[int, Dict[int, int]] = defaultdict(
            lambda: defaultdict(int))
        self._last: Dict[int, int] = {}

    def observe(self) -> None:
        for r, blk in self.store.access_log[-256:]:
            prev = self._last.get(r)
            if prev is not None:
                self._deltas[r][blk - prev] += 1
            self._last[r] = blk

    def step(self, pos: int) -> None:
        """Called before each decode step: prefetch the blocks each request
        will need next (the about-to-be-written frontier block plus the
        top-delta continuation)."""
        self.observe()
        frontier = pos // BLOCK_TOKENS
        keys: List[Tuple[int, int]] = []
        for r in range(self.store.n_requests):
            for ahead in range(1, self.lookahead_blocks + 1):
                keys.append((r, frontier + ahead))
            hist = self._deltas.get(r)
            if hist:
                best = max(hist, key=hist.get)
                last = self._last.get(r, frontier)
                cand = last + best
                if 0 <= cand <= frontier + self.lookahead_blocks:
                    keys.append((r, cand))
        self.store.prefetch(keys)
