"""Pallas TPU kernels for the performance-critical compute layers.

* ``flash_attention`` — block-tiled online-softmax attention (LM prefill /
  serving hot-spot), causal + non-causal, GQA-aware.
* ``hlsh_attention`` — the paper's Hamming-LSH attention, TPU-adapted:
  mask-based erase/share semantics with whole-block skipping driven by
  scalar-prefetched per-block keep counts.
* ``int4_matmul`` — packed-int4 weight matmul with fused dequantization
  (quantized revised-predictor inference, §6).

Each kernel ships a pure-jnp oracle in ``ref.py`` and a jitted public wrapper
in ``ops.py``.  This container is CPU-only: kernels are *validated* with
``interpret=True`` and *targeted* at TPU (explicit VMEM BlockSpecs, MXU-
aligned tiles).
"""
from repro.kernels.ops import flash_attention, hlsh_attention, int4_matmul

__all__ = ["flash_attention", "hlsh_attention", "int4_matmul"]
