"""Block-tiled online-softmax (Flash) attention for TPU via Pallas.

Grid: (batch*heads, q_blocks, k_blocks) — the k dimension is innermost and
"arbitrary" (sequential) so the VMEM scratch accumulators carry across k
blocks.  GQA is handled in the K/V index maps (no materialized repeat).
Causal masking skips strictly-future k blocks entirely and applies an iota
mask on the diagonal block.

VMEM working set per program:
    q (bq, d) + k (bk, d) + v (bk, d) + acc (bq, d) + m/l (bq, 128)
with the default 128/128 blocks and d<=256 this is well under 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128  # TPU lane width for the m/l scratch


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, sm_scale: float, block_q: int, block_k: int,
                  seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: block (qi, ki) contributes iff some q_pos >= some k_pos.
    # q rows are offset by (seq_k - seq_q) (decode: cache longer than query).
    offset = seq_k - seq_q
    run = True
    if causal:
        run = (qi * block_q + block_q - 1 + offset) >= (ki * block_k)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)
        if causal:
            qpos = (qi * block_q + offset
                    + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
            kpos = (ki * block_k
                    + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[:, :1]                                 # (bq, 1)
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = False, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D), H % Hkv == 0."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    def kv_index(bh, qi, ki):
        # flatten (batch, q-head) -> (batch, kv-head)
        return ((bh // h) * hkv + (bh % h) // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, causal=causal, sm_scale=1.0 / (d ** 0.5),
        block_q=block_q, block_k=block_k, seq_q=sq, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
