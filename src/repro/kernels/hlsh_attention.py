"""HLSH (Hamming-LSH) attention — the paper's Algorithm 1, TPU-adapted.

The paper erases near-orthogonal rows (Hamming score >= HTOP) and lets
near-duplicate rows share one representative's output (<= HBOT).  On GPU this
is gather/scatter; on TPU we keep static shapes:

* erase  -> multiplicative keep-mask on Q and K rows (zero logits keep the
  erased columns in the softmax denominator at weight e^0, exactly like the
  paper's zeroed matrix entries);
* share  -> take_along_axis on the output (in the ops wrapper);
* win    -> a k-block whose keys are ALL erased needs no matmul at all: its
  contribution is analytic (each column adds logit 0), i.e.
      l   += exp(-m) * block_k
      acc += exp(-m) * sum_of_v_over_that_block
  The per-block "kept count" rides in scalar-prefetch memory (SMEM) so the
  branch costs nothing; the per-block v-sums are a cheap O(N*D) prologue.

This turns the paper's O((log N)^2) claim into its TPU-native form: whole
128x128 MXU tiles skipped whenever the hash filter erases a full key block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _hlsh_kernel(counts_ref,                       # scalar prefetch (B, nk)
                 q_ref, k_ref, v_ref, keepq_ref, keepk_ref, vsum_ref,
                 o_ref, m_scr, l_scr, acc_scr, *,
                 sm_scale: float, block_q: int, block_k: int):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.zeros_like(m_scr)   # zero logits always exist
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kept = counts_ref[bi, ki]

    @pl.when(kept > 0)
    def _dense_block():
        q = q_ref[0].astype(jnp.float32) * keepq_ref[0][:, :1]
        k = k_ref[0].astype(jnp.float32) * keepk_ref[0][:, :1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        l_scr[...] = jnp.broadcast_to(
            alpha * l_prev + jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(kept == 0)
    def _skipped_block():
        # every key in this block is erased: all logits are exactly 0.
        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        w = jnp.exp(0.0 - m_new)                      # (bq, 1)
        acc_scr[...] = acc_scr[...] * alpha + w * vsum_ref[0]
        l_scr[...] = jnp.broadcast_to(
            alpha * l_scr[:, :1] + w * block_k, l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
                    ).astype(o_ref.dtype)


def hlsh_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          keep: jnp.ndarray, block_q: int = 128,
                          block_k: int = 128,
                          interpret: bool = False) -> jnp.ndarray:
    """Masked-attention core (share map applied by the caller).
    q/k/v: (B, N, D); keep: (B, N) float {0,1}."""
    b, n, d = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    assert n % block_q == 0 and n % block_k == 0
    nq, nk = n // block_q, n // block_k

    keepf = keep.astype(jnp.float32)
    counts = keepf.reshape(b, nk, block_k).sum(-1).astype(jnp.int32)  # (B,nk)
    erased = (1.0 - keepf)[..., None] * v.astype(jnp.float32)
    vsum = erased.reshape(b, nk, block_k, d).sum(axis=2)              # (B,nk,D)
    # broadcast keep into a lane-aligned (B, N, LANES) plane for VMEM tiling
    keep_plane = jnp.broadcast_to(keepf[..., None], (b, n, LANES))

    kernel = functools.partial(_hlsh_kernel, sm_scale=1.0 / (d ** 0.5),
                               block_q=block_q, block_k=block_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bi, qi, ki, _c: (bi, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bi, qi, ki, _c: (bi, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bi, qi, ki, _c: (bi, ki, 0)),
            pl.BlockSpec((1, block_q, LANES), lambda bi, qi, ki, _c: (bi, qi, 0)),
            pl.BlockSpec((1, block_k, LANES), lambda bi, qi, ki, _c: (bi, ki, 0)),
            pl.BlockSpec((1, 1, d), lambda bi, qi, ki, _c: (bi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bi, qi, ki, _c: (bi, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n, d), q.dtype),
        interpret=interpret,
    )(counts, q, k, v, keep_plane, keep_plane, vsum)
    return out
