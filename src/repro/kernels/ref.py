"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = False) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D) with H % Hkv == 0 (GQA)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kx) / jnp.sqrt(jnp.float32(d))
    if causal:
        sk = kx.shape[2]
        mask = jnp.arange(sq)[:, None] + (sk - sq) >= jnp.arange(sk)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), vx)


def hlsh_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       keep: jnp.ndarray, share_src: jnp.ndarray,
                       ) -> jnp.ndarray:
    """Mask-form HLSH oracle.  q/k/v: (B, N, D); keep: (B, N) {0,1};
    share_src: (B, N) int32 source row per output row."""
    d = q.shape[-1]
    keepf = keep[..., None].astype(q.dtype)
    qm = q * keepf
    km = k * keepf
    logits = jnp.einsum("bnd,bmd->bnm", qm, km) / jnp.sqrt(jnp.float32(d))
    out = jnp.einsum("bnm,bmd->bnd",
                     jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
                     .astype(q.dtype), v)
    return jnp.take_along_axis(out, share_src[..., None], axis=1)


def int4_matmul_ref(x: jnp.ndarray, w_packed: jnp.ndarray,
                    scale: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) f32; w_packed: (K, N//2) uint8, two 4-bit codes per byte
    (hi nibble = even n, lo nibble = odd n), code = int4 + 8; scale: ()."""
    hi = (w_packed >> 4).astype(jnp.int32) - 8
    lo = (w_packed & 0xF).astype(jnp.int32) - 8
    w = jnp.stack([hi, lo], axis=-1).reshape(w_packed.shape[0], -1)
    return x @ (w.astype(x.dtype) * scale)
