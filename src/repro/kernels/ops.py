"""Public jitted wrappers for the Pallas kernels.

On this CPU-only container the wrappers run the kernels in ``interpret=True``
mode (the kernel body executes in Python/XLA-CPU, bit-faithful to the TPU
semantics); on a real TPU backend they compile through Mosaic.  The choice is
automatic, overridable via the ``interpret=`` argument.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hlsh_attention import hlsh_attention_pallas
from repro.kernels.int4_matmul import int4_matmul_pallas


def default_interpret() -> bool:
    """Interpret-mode default shared by every Pallas entry point in the
    repo — the kernels below and the UVM multi-lane replay backend
    (``repro.uvm.backends.pallas_backend``): interpret everywhere except
    on a real TPU backend, where kernels compile through Mosaic."""
    return jax.default_backend() != "tpu"


_default_interpret = default_interpret


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = False, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Multi-head attention; q: (B, H, Sq, D), k/v: (B, Hkv, Sk, D)."""
    interp = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret"))
def hlsh_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   keep: jnp.ndarray, share_src: jnp.ndarray,
                   block_q: int = 128, block_k: int = 128,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Full HLSH semantics: masked attention core (Pallas) + share map."""
    interp = _default_interpret() if interpret is None else interpret
    out = hlsh_attention_pallas(q, k, v, keep, block_q=block_q,
                                block_k=block_k, interpret=interp)
    return jnp.take_along_axis(out, share_src[..., None], axis=1)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def int4_matmul(x: jnp.ndarray, w_packed: jnp.ndarray, scale,
                block_m: int = 128, block_n: int = 128, block_k: int = 128,
                interpret: bool | None = None) -> jnp.ndarray:
    interp = _default_interpret() if interpret is None else interpret
    return int4_matmul_pallas(x, w_packed, scale, block_m=block_m,
                              block_n=block_n, block_k=block_k,
                              interpret=interp)
