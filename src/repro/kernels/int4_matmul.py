"""Packed-int4 weight matmul with fused in-VMEM dequantization.

Weights live in HBM as two 4-bit codes per byte (hi nibble = even output
column), are unpacked and dequantized inside the kernel tile-by-tile, and hit
the MXU as f32.  Used by the quantized revised predictor's inference path
(paper §6: [-8, +8] 4-bit weights) and as the serving-time weight-dequant
primitive.

Grid: (m_blocks, n_blocks, k_blocks), k innermost with an f32 VMEM
accumulator.  The per-tensor scale is applied once at finalization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int4_kernel(x_ref, w_ref, o_ref, acc_scr, *, block_n: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)                  # (bm, bk)
    w_packed = w_ref[...]                               # (bk, bn//2) uint8
    hi = (w_packed >> 4).astype(jnp.int32) - 8
    lo = (w_packed & 0xF).astype(jnp.int32) - 8
    w = jnp.stack([hi, lo], axis=-1).reshape(w_packed.shape[0], block_n)
    acc_scr[...] += jax.lax.dot_general(
        x, w.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def int4_matmul_pallas(x: jnp.ndarray, w_packed: jnp.ndarray,
                       scale: jnp.ndarray | float,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    """x: (M, K); w_packed: (K, N//2) uint8 -> (M, N) x.dtype."""
    m, kdim = x.shape
    n = w_packed.shape[1] * 2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, kdim)
    assert m % block_m == 0 and n % block_n == 0 and kdim % block_k == 0
    grid = (m // block_m, n // block_n, kdim // block_k)

    kernel = functools.partial(_int4_kernel, block_n=block_n)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n // 2), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w_packed)
    return out * jnp.asarray(scale, x.dtype)
