"""In-repo optimizers (no optax dependency): AdamW with global-norm clipping,
LR schedules, and distributed gradient compression."""
from repro.optimizer.adam import AdamW, AdamWState
from repro.optimizer.schedule import cosine_schedule, linear_warmup_cosine
from repro.optimizer.grad_compress import (
    int8_compress, int8_decompress, topk_compress, topk_decompress,
    ErrorFeedbackState, compress_with_error_feedback, init_error_feedback,
)

__all__ = [
    "AdamW", "AdamWState", "cosine_schedule", "linear_warmup_cosine",
    "int8_compress", "int8_decompress", "topk_compress", "topk_decompress",
    "ErrorFeedbackState", "compress_with_error_feedback",
    "init_error_feedback",
]
