"""Gradient compression for the cross-pod (DCN) data-parallel axis.

At 1000+ nodes the pod-level all-reduce crosses data-center network links
that are ~10x slower than ICI; compressing gradients there is a standard
distributed-optimization trick.  We provide:

* int8 symmetric quantization (4x compression) with per-tensor scales,
* top-k sparsification (magnitude), and
* error feedback (residual accumulation) so either compressor stays unbiased
  over time (Karimireddy et al., 2019).

All functions are jit-safe and shard_map-safe (no data-dependent shapes:
top-k uses a fixed k per tensor).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def int8_compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_compress(x: jnp.ndarray, frac: float = 0.05):
    """Keep the top ``frac`` fraction of entries by magnitude."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    return sel, idx, x.shape


def topk_decompress(vals: jnp.ndarray, idx: jnp.ndarray, shape) -> jnp.ndarray:
    flat = jnp.zeros(int(jnp.prod(jnp.array(shape))), vals.dtype)
    flat = flat.at[idx].set(vals)
    return flat.reshape(shape)


class ErrorFeedbackState(NamedTuple):
    residual: Any


def compress_with_error_feedback(grads: Any, state: ErrorFeedbackState,
                                 mode: str = "int8"):
    """Compress ``grads + residual``; the new residual is what compression
    lost.  Returns (decompressed grads to feed the all-reduce, new state).

    The round trip happens locally; only the compressed representation would
    travel on the wire.  We return the decompressed value so callers can drop
    this in front of any existing all-reduce.
    """
    carried = jax.tree.map(lambda g, r: g + r, grads, state.residual)

    def roundtrip(x):
        if mode == "int8":
            q, s = int8_compress(x)
            return int8_decompress(q, s)
        elif mode == "topk":
            v, i, shp = topk_compress(x)
            return topk_decompress(v, i, shp)
        raise ValueError(f"unknown mode {mode}")

    sent = jax.tree.map(roundtrip, carried)
    new_resid = jax.tree.map(lambda c, s: c - s, carried, sent)
    return sent, ErrorFeedbackState(residual=new_resid)


def init_error_feedback(params: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(residual=jax.tree.map(jnp.zeros_like, params))
