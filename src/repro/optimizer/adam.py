"""AdamW over arbitrary pytrees, with optional global-norm clipping.

Functional style: ``state = opt.init(params)``, then
``params, state = opt.update(grads, params, state, lr)`` inside a jitted step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = jax.tree.map(jnp.zeros_like, params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.zeros_like, params))

    def update(self, grads: Any, params: Any, state: AdamWState,
               lr: float | jnp.ndarray):
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p
            return p - lr * delta

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in leaves))
