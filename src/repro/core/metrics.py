"""Classification metrics: top-k accuracy and weighted F1 (paper Tables 1-8)."""
from __future__ import annotations

import numpy as np


def topk_accuracy(logits: np.ndarray, y: np.ndarray, k: int = 1) -> float:
    if k == 1:
        return float((logits.argmax(-1) == y).mean())
    topk = np.argpartition(-logits, kth=min(k, logits.shape[-1] - 1), axis=-1)[:, :k]
    return float((topk == y[:, None]).any(axis=1).mean())


def weighted_f1(logits: np.ndarray, y: np.ndarray) -> float:
    """Support-weighted mean of per-class F1 (sklearn 'weighted' semantics)."""
    pred = logits.argmax(-1)
    classes, support = np.unique(y, return_counts=True)
    f1s = np.zeros(len(classes))
    for i, c in enumerate(classes):
        tp = np.sum((pred == c) & (y == c))
        fp = np.sum((pred == c) & (y != c))
        fn = np.sum((pred != c) & (y == c))
        prec = tp / (tp + fp) if tp + fp else 0.0
        rec = tp / (tp + fn) if tp + fn else 0.0
        f1s[i] = 2 * prec * rec / (prec + rec) if prec + rec else 0.0
    return float(np.average(f1s, weights=support))
