"""Sequence dataset: sliding windows of 30 feature tokens -> delta class.

Labels: for prediction distance d, the label of a window ending at position i
is the class of ``page[i+d] - page[i]`` — the page the GPU will touch d
requests later, relative to now (d=1 reduces to the next-access delta, the
setup of paper Tables 1-8; the deployed service uses d=30 for timeliness,
paper §5.2).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

from repro.core.features import ClusteredTrace
from repro.core.vocab import DeltaVocab, encode_features

SEQ_LEN = 30


@dataclasses.dataclass
class SequenceDataset:
    x_train: np.ndarray     # (N, seq, F) int32
    y_train: np.ndarray     # (N,) int32 class ids
    x_valid: np.ndarray
    y_valid: np.ndarray
    x_test: np.ndarray      # 100% of the trace (paper §4)
    y_test: np.ndarray
    n_classes: int
    vocab: DeltaVocab
    features: List[str]

    @property
    def class_counts(self) -> np.ndarray:
        counts = np.bincount(self.y_train, minlength=self.n_classes)
        return counts


def build_dataset(ct: ClusteredTrace, vocab: DeltaVocab,
                  features: List[str] | None = None,
                  seq_len: int = SEQ_LEN, distance: int = 1,
                  train_frac: float = 0.8, stride: int = 1,
                  max_train: int = 24000, max_eval: int = 8000,
                  shuffle_tokens: bool = False,
                  seed: int = 0) -> SequenceDataset:
    """Window each cluster independently; chronological 80/20 split within
    clusters; test set spans 100%.  ``shuffle_tokens`` randomly permutes the
    tokens *within* each window (paper Fig 6's order-sensitivity probe)."""
    rng = np.random.default_rng(seed)
    xs, ys, split_pos = [], [], []
    for c, pages in zip(ct.clusters, ct.pages):
        n = len(pages)
        if n < seq_len + distance + 1:
            continue
        enc = encode_features(c, features)
        n_win = n - seq_len - distance + 1
        starts = np.arange(0, n_win, stride)
        # gather windows: (n_win, seq, F)
        idx = starts[:, None] + np.arange(seq_len)[None, :]
        x = enc[idx]
        ends = starts + seq_len - 1
        deltas = pages[ends + distance] - pages[ends]
        y = vocab.encode_fast(deltas)
        xs.append(x)
        ys.append(y)
        split_pos.append(int(len(starts) * train_frac))

    if not xs:
        raise ValueError(f"trace {ct.name} too short for seq_len={seq_len}")

    xtr = np.concatenate([x[:s] for x, s in zip(xs, split_pos)])
    ytr = np.concatenate([y[:s] for y, s in zip(ys, split_pos)])
    xva = np.concatenate([x[s:] for x, s in zip(xs, split_pos)])
    yva = np.concatenate([y[s:] for y, s in zip(ys, split_pos)])
    xte = np.concatenate(xs)
    yte = np.concatenate(ys)

    def sub(x, y, cap):
        if len(x) > cap:
            sel = rng.choice(len(x), cap, replace=False)
            return x[sel], y[sel]
        return x, y

    xtr, ytr = sub(xtr, ytr, max_train)
    xva, yva = sub(xva, yva, max_eval)
    xte, yte = sub(xte, yte, max_eval)

    if shuffle_tokens:
        def shuf(x):
            perm = rng.permuted(
                np.broadcast_to(np.arange(x.shape[1]), x.shape[:2]), axis=1)
            return np.take_along_axis(x, perm[:, :, None], axis=1)
        xtr, xva, xte = shuf(xtr), shuf(xva), shuf(xte)

    from repro.core.features import FEATURE_NAMES
    return SequenceDataset(
        x_train=xtr, y_train=ytr.astype(np.int32),
        x_valid=xva, y_valid=yva.astype(np.int32),
        x_test=xte, y_test=yte.astype(np.int32),
        n_classes=vocab.n_classes, vocab=vocab,
        features=list(features or FEATURE_NAMES),
    )


def batches(x: np.ndarray, y: np.ndarray, batch_size: int,
            seed: int = 0, epochs: int = 1) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(x)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = perm[i:i + batch_size]
            yield x[sel], y[sel]
