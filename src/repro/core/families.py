"""Predictor configuration layer: the jax-free half of ``repro.core.model``.

The paper's §5-§6 story is a *comparison between model families*: the
unconstrained reference Transformer sets the accuracy bar, and the
simplified (revised) predictor is engineered to match it.  This module
makes that a first-class, config-driven axis — xformers-block-factory
style: each family is a plain dict of :class:`PredictorConfig` overrides
(``MODEL_FAMILY_BLOCKS``), and :func:`family_config` assembles the
resolved config from it.  Families:

* ``simplified`` — the §6 revised predictor (3 features, 12 embedding
  dims, 1 layer, 1 head, HLSH attention with the convergence bypass,
  4-bit quantization-aware).  The default everywhere.
* ``transformer`` — the reference encoder: full 13-feature embedding
  concat (200 dims), 2 layers, 4-head full softmax attention, fp32.
* ``transformer-local`` — the windowed/local-attention variant the
  paper's interpretability analysis derives (recent deltas dominate):
  the same reference stack with attention restricted to a
  ``local_window``-wide band.

Deliberately **jax-free**: :class:`PredictorConfig` is a plain frozen
dataclass and the registry is data, so the sweep CLI, the scenario
registry, and ``repro.uvm.predcache`` can validate family names and
fingerprint architectures (:func:`config_digest` — part of every
prediction-cache key) without importing jax.  ``repro.core.model`` owns
``init_params``/``apply`` and re-exports everything here.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Tuple

# embedding width per feature; the full 13(+kernel)-feature concat is 200
# dims, matching the paper's embedding output of 200 x 30.
EMB_DIMS: Dict[str, int] = {
    "pc": 24, "hit": 4, "warp": 12, "sm": 12, "tpc": 8, "cta": 12,
    "kernel": 8, "paddr": 32, "bbaddr": 16, "raddr": 8, "inarr": 8,
    "dp": 32, "dbb": 16, "dr": 8,
}
# revised predictor (§6): 3 features, 12 total embedding dims
REVISED_EMB_DIMS: Dict[str, int] = {"paddr": 4, "dp": 6, "pc": 2}
REVISED_FEATURES = ("paddr", "dp", "pc")


@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    n_classes: int
    arch: str = "transformer"          # transformer|fc|mlp|cnn|lstm
    attention: str = "full"            # full|local|hlsh|lsh|bypass
    features: Tuple[str, ...] = tuple(EMB_DIMS)
    seq_len: int = 30
    n_layers: int = 2
    n_heads: int = 4
    d_ff_mult: int = 4
    quantize: bool = False
    revised_dims: bool = False         # use the 12-dim embedding set
    n_hashes: int = 8
    n_buckets: int = 8
    htop: float = 0.9
    hbot: float = 0.1
    lsh_seed: int = 7
    hidden: int = 128                  # lstm/cnn/mlp width
    local_window: int = 8              # attention="local": band half-width

    @property
    def emb_dims(self) -> Dict[str, int]:
        base = REVISED_EMB_DIMS if self.revised_dims else EMB_DIMS
        return {f: base[f] for f in self.features}

    @property
    def d_model(self) -> int:
        return sum(self.emb_dims.values())


def revised_config(n_classes: int, convergence: float,
                   bypass_threshold: float = 0.7,
                   quantize: bool = True) -> PredictorConfig:
    """§6: SM+warp clustering is handled upstream; here: 3 features, 1 layer,
    1 head, HLSH attention, and the bypass indicator — if one page delta
    dominates the training data, attention is skipped entirely."""
    bypass = convergence >= bypass_threshold
    return PredictorConfig(
        n_classes=n_classes, arch="transformer",
        attention="bypass" if bypass else "hlsh",
        features=REVISED_FEATURES, revised_dims=True,
        n_layers=1, n_heads=1, quantize=quantize,
    )


# ---------------------------------------------------------------------------
# the family registry (block-factory style: families are config dicts)
# ---------------------------------------------------------------------------

#: per-family encoder blocks: the :class:`PredictorConfig` overrides each
#: reference family is assembled from (``simplified`` is special-cased —
#: its attention/bypass resolution is convergence-driven, see
#: :func:`revised_config`).  The reference families pin ``quantize`` —
#: the paper's unconstrained Transformer is fp32 regardless of the
#: service's quantization knob.
MODEL_FAMILY_BLOCKS: Dict[str, Dict] = {
    "transformer": {
        "arch": "transformer", "attention": "full",
        "features": tuple(EMB_DIMS), "revised_dims": False,
        "n_layers": 2, "n_heads": 4, "d_ff_mult": 4, "quantize": False,
    },
    "transformer-local": {
        "arch": "transformer", "attention": "local", "local_window": 8,
        "features": tuple(EMB_DIMS), "revised_dims": False,
        "n_layers": 2, "n_heads": 4, "d_ff_mult": 4, "quantize": False,
    },
}

#: family vocabulary, in registry order (``simplified`` is the default
#: and must stay first: every pre-family code path assumes it)
MODEL_FAMILIES = ("simplified",) + tuple(MODEL_FAMILY_BLOCKS)


def validate_family(name: str) -> str:
    if name not in MODEL_FAMILIES:
        raise ValueError(f"unknown model family {name!r}; "
                         f"choose from {', '.join(MODEL_FAMILIES)}")
    return name


def family_config(family: str, n_classes: int, convergence: float = 0.0,
                  bypass_threshold: float = 0.7,
                  quantize: bool = True) -> PredictorConfig:
    """Assemble one family's resolved :class:`PredictorConfig`.

    ``convergence``/``bypass_threshold``/``quantize`` only shape the
    ``simplified`` family (the §6 bypass indicator and QAT knob); the
    reference families are fully determined by their registry block.
    """
    validate_family(family)
    if family == "simplified":
        return revised_config(n_classes, convergence, bypass_threshold,
                              quantize=quantize)
    return PredictorConfig(n_classes=n_classes,
                           **MODEL_FAMILY_BLOCKS[family])


def config_digest(cfg: PredictorConfig) -> str:
    """Stable fingerprint of a resolved :class:`PredictorConfig` — the
    architecture identity ``repro.uvm.predcache`` keys prediction arrays
    on, so two families (or two revisions of one family's block) can
    never share a cached ``predict_trace`` array."""
    doc = dataclasses.asdict(cfg)
    doc["features"] = list(doc["features"])
    blob = json.dumps(doc, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]
