"""The paper's core: deep-learning UVM page prediction.

Pipeline: GMMU trace -> clustering (SM / SM+warp) -> feature tokens -> delta
vocabulary -> sliding-window sequence dataset -> Transformer (or revised
HLSH) predictor -> per-access top-1 page predictions -> LearnedPrefetcher.

Attributes are resolved lazily (PEP 562): the config layer
(``repro.core.families`` — ``PredictorConfig``, the model-family registry)
is importable without paying the jax import that ``model``/``train``/
``service`` need, which keeps the sweep CLI and the scenario registry
jax-free at import time.
"""
from typing import Dict

# attribute -> owning submodule; resolved on first access so importing
# repro.core (or the jax-free repro.core.families directly) never eagerly
# pulls jax
_ATTR_MODULES: Dict[str, str] = {
    "cluster_trace": "features", "delta_convergence": "features",
    "ClusteredTrace": "features", "FEATURE_NAMES": "features",
    "CLUSTER_KEYS": "features",
    "DeltaVocab": "vocab", "encode_features": "vocab",
    "FEATURE_BUCKETS": "vocab",
    "build_dataset": "dataset", "SequenceDataset": "dataset",
    "SEQ_LEN": "dataset",
    # config layer: jax-free
    "PredictorConfig": "families", "revised_config": "families",
    "EMB_DIMS": "families", "REVISED_FEATURES": "families",
    "MODEL_FAMILIES": "families", "MODEL_FAMILY_BLOCKS": "families",
    "config_digest": "families", "family_config": "families",
    "validate_family": "families",
    "init_params": "model", "apply": "model",
    "train_predictor": "train", "evaluate": "train",
    "predict_logits": "train", "TrainResult": "train",
    "PredictorService": "service", "pretrain_corpus": "service",
}

__all__ = sorted(_ATTR_MODULES)


def __getattr__(name: str):
    mod = _ATTR_MODULES.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(f"{__name__}.{mod}"), name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
