"""The paper's core: deep-learning UVM page prediction.

Pipeline: GMMU trace -> clustering (SM / SM+warp) -> feature tokens -> delta
vocabulary -> sliding-window sequence dataset -> Transformer (or revised
HLSH) predictor -> per-access top-1 page predictions -> LearnedPrefetcher.
"""
from repro.core.features import (
    cluster_trace, delta_convergence, ClusteredTrace, FEATURE_NAMES,
    CLUSTER_KEYS,
)
from repro.core.vocab import DeltaVocab, encode_features, FEATURE_BUCKETS
from repro.core.dataset import build_dataset, SequenceDataset, SEQ_LEN
from repro.core.model import (
    PredictorConfig, revised_config, init_params, apply,
    EMB_DIMS, REVISED_FEATURES,
)
from repro.core.train import train_predictor, evaluate, predict_logits, TrainResult
from repro.core.service import PredictorService, pretrain_corpus

__all__ = [
    "cluster_trace", "delta_convergence", "ClusteredTrace", "FEATURE_NAMES",
    "CLUSTER_KEYS", "DeltaVocab", "encode_features", "FEATURE_BUCKETS",
    "build_dataset", "SequenceDataset", "SEQ_LEN",
    "PredictorConfig", "revised_config", "init_params", "apply",
    "EMB_DIMS", "REVISED_FEATURES",
    "train_predictor", "evaluate", "predict_logits", "TrainResult",
    "PredictorService", "pretrain_corpus",
]
