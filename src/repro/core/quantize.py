"""Quantization (paper §6): clamp weights and activations to [-8, +8] on a
4-bit integer grid, trained with straight-through estimation; plus int4
pack/unpack used by the quantized inference path (repro.kernels.int4_matmul)
and the memory-footprint estimator behind Tables 6-7.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

QMIN, QMAX = -8.0, 7.0   # 16 levels, step 1.0, representable in 4 bits


def fake_quant(x: jnp.ndarray, step: float = 1.0) -> jnp.ndarray:
    """Round to the 4-bit grid in [-8, +8] with a straight-through gradient."""
    q = jnp.clip(jnp.round(x / step), QMIN, QMAX) * step
    return x + jax.lax.stop_gradient(q - x)


def fake_quant_tensor(x: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor symmetric 4-bit fake quant: the grid step adapts to the
    tensor's dynamic range (weights are much smaller than 1; a unit grid
    would zero them out).  Activations, which normalization keeps O(1),
    use the paper's literal [-8, 8] unit grid via ``fake_quant``."""
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-6) / (-QMIN)
    q = jnp.clip(jnp.round(x / s), QMIN, QMAX) * s
    return x + jax.lax.stop_gradient(q - x)


def quantize_int4(x: np.ndarray) -> Tuple[np.ndarray, float]:
    """Real int4 quantization: returns packed uint8 (two nibbles each) and
    the per-tensor scale."""
    s = max(float(np.max(np.abs(x))), 1e-6) / (-QMIN)
    q = np.clip(np.round(x / s), QMIN, QMAX).astype(np.int8)
    u = (q - int(QMIN)).astype(np.uint8)           # 0..15
    flat = u.reshape(-1)
    if flat.size % 2:
        flat = np.concatenate([flat, np.zeros(1, np.uint8)])
    packed = (flat[0::2] << 4) | flat[1::2]
    return packed, s


def dequantize_int4(packed: np.ndarray, scale: float, size: int,
                    shape) -> np.ndarray:
    hi = (packed >> 4).astype(np.int8)
    lo = (packed & 0xF).astype(np.int8)
    flat = np.empty(packed.size * 2, np.int8)
    flat[0::2] = hi
    flat[1::2] = lo
    return ((flat[:size] + int(QMIN)) * scale).reshape(shape).astype(np.float32)


def param_bytes(params, bits: int = 32) -> int:
    leaves = jax.tree.leaves(params)
    n = sum(int(np.prod(x.shape)) for x in leaves)
    return n * bits // 8


def footprint_report(params, activation_elems: int, batch_size: int,
                     bits: int = 32) -> dict:
    """Tables 6-7 style footprint: parameter bytes + forward/backward
    activation bytes (activations are counted twice: stored for backward)."""
    p = param_bytes(params, bits)
    act = activation_elems * batch_size * 2 * bits // 8
    return {"params_bytes": p, "activations_bytes": act,
            "total_bytes": p + act}
