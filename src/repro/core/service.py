"""Predictor service: the bridge between the trained model and the UVM
runtime (paper §7.1).

The paper pretrains one model on a 5-benchmark corpus (different input data),
then fine-tunes per benchmark every 50 M instructions and serves predictions
from the UVM backend with ~1 us inference latency.  Here:

* ``fit`` trains (optionally starting from corpus-pretrained params),
* ``predict_trace`` produces the per-access top-1 predicted page array the
  ``LearnedPrefetcher`` consumes: for every access i, the page the model
  expects ``distance`` requests later within i's cluster stream,
* inference latency is modeled in the simulator (Fig 10), not here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import families
from repro.core import model as model_lib
from repro.core.dataset import SEQ_LEN, build_dataset
from repro.core.features import ClusteredTrace, cluster_trace, delta_convergence
from repro.core.train import TrainResult, predict_cls_conf, train_predictor
from repro.core.vocab import DeltaVocab, encode_features
from repro.traces.trace import Trace


@dataclasses.dataclass
class PredictorService:
    """Owns a (revised, by default) predictor for one benchmark."""

    # The paper's revised predictor clusters by SM+warp over 50M-instruction
    # windows; our traces are 10-100x shorter, so per-(SM,warp-slot) streams
    # are too short to window — the service defaults to SM clustering and
    # the SM+warp ablation lives in the Table 2 benchmark.
    cluster_key: str = "sm"
    # Prediction distance: the paper uses 30 for timeliness in its GMMU-rate
    # regime.  Our SM-cluster predictions interleave across 28 SMs, so a
    # distance-8 prediction already buys ~8*28 global requests of lead; 8
    # keeps labels within a CTA scheduling burst (far less label entropy).
    distance: int = 8
    min_prob: float = 0.35
    seq_len: int = SEQ_LEN
    steps: int = 300
    batch_size: int = 128
    quantize: bool = True
    bypass_threshold: float = 0.7
    seed: int = 0
    # which predictor family to assemble in fit() when no explicit cfg is
    # passed — "simplified" (§6 revised), "transformer" (the reference
    # encoder), or "transformer-local"; see repro.core.families
    model_family: str = "simplified"

    trace: Optional[Trace] = None
    ct: Optional[ClusteredTrace] = None
    vocab: Optional[DeltaVocab] = None
    result: Optional[TrainResult] = None
    convergence: float = 0.0

    @property
    def model_config(self) -> str:
        """Architecture digest of this service's family block, for cache
        keying (repro.uvm.predcache).  Trace-determined parts of the
        resolved config — n_classes and the convergence-driven bypass
        flip — are pinned to sentinels: the trace content is already part
        of every predcache key, so the digest only needs to capture the
        architecture the family + service knobs select."""
        cfg = families.family_config(self.model_family, n_classes=0,
                                     convergence=0.0,
                                     bypass_threshold=self.bypass_threshold,
                                     quantize=self.quantize)
        return families.config_digest(cfg)

    def fit(self, trace: Trace, init_params=None,
            cfg: model_lib.PredictorConfig | None = None,
            max_train: int = 16000) -> TrainResult:
        self.trace = trace
        self.ct = cluster_trace(trace, self.cluster_key)
        self.vocab = DeltaVocab.build(self.ct, distance=self.distance)
        self.convergence = delta_convergence(self.ct)
        if cfg is None:
            cfg = model_lib.family_config(
                self.model_family, self.vocab.n_classes, self.convergence,
                self.bypass_threshold, quantize=self.quantize)
        data = build_dataset(self.ct, self.vocab, features=list(cfg.features),
                             seq_len=self.seq_len, distance=self.distance,
                             max_train=max_train, seed=self.seed)
        self.result = train_predictor(cfg, data, steps=self.steps,
                                      batch_size=self.batch_size,
                                      seed=self.seed, params=init_params)
        return self.result

    def predict_trace(self, trace: Trace | None = None,
                      batch_size: int = 4096) -> np.ndarray:
        """Per-access predicted pages, aligned with GMMU trace order.
        Entry i is the top-1 page expected ``distance`` accesses after i in
        i's cluster, or -1 where no prediction is available (window warmup or
        UNK class).

        Windows from *all* clusters are concatenated into one stream and
        pushed through ``predict_cls_conf`` in large fixed-shape jitted
        batches (pad-and-mask): small clusters no longer each pay a mostly-
        padded device batch, jit compiles one shape for the whole trace, and
        only the (class, confidence) pair per window crosses back to the
        host instead of full logits rows."""
        assert self.result is not None and self.vocab is not None
        if trace is None:
            ct = self.ct
        else:
            ct = cluster_trace(trace, self.cluster_key)
        cfg, params = self.result.cfg, self.result.params
        out = np.full(max(g.max() for g in ct.global_index) + 1, -1,
                      dtype=np.int64)
        window = np.arange(self.seq_len)[None, :]
        # windows accumulate across clusters but are inferred in shared
        # flushes of at most flush_windows rows, so peak memory is bounded
        # by the flush size, not the trace length
        flush_windows = max(batch_size, 65536)
        pend_x: list = []
        pend_spans: list = []
        pend_n = 0

        def _flush() -> None:
            nonlocal pend_x, pend_spans, pend_n
            if not pend_x:
                return
            x = pend_x[0] if len(pend_x) == 1 else np.concatenate(pend_x)
            cls, conf = predict_cls_conf(cfg, params, x, batch_size)
            off = 0
            for pages, gidx, ends in pend_spans:
                m = len(ends)
                c, p = cls[off:off + m], conf[off:off + m]
                off += m
                deltas = self.vocab.decode(c)
                # confidence gate: don't prefetch on low-probability
                # predictions (useless prefetches cost bus bandwidth, §7.6)
                pred_pages = np.where((c == 0) | (p < self.min_prob),
                                      -1, pages[ends] + deltas)
                out[gidx[ends]] = pred_pages
            pend_x, pend_spans, pend_n = [], [], 0

        for cluster, pages, gidx in zip(ct.clusters, ct.pages,
                                        ct.global_index):
            n = len(pages)
            if n < self.seq_len:
                continue
            enc = encode_features(cluster, list(cfg.features))
            all_starts = np.arange(0, n - self.seq_len + 1)
            for s0 in range(0, len(all_starts), flush_windows):
                starts = all_starts[s0:s0 + flush_windows]
                pend_x.append(enc[starts[:, None] + window])
                pend_spans.append((pages, gidx, starts + self.seq_len - 1))
                pend_n += len(starts)
                if pend_n >= flush_windows:
                    _flush()
        _flush()
        return out


def pretrain_corpus(traces: List[Trace], cfg: model_lib.PredictorConfig,
                    vocab: DeltaVocab, cluster_key: str = "sm_warp",
                    distance: int = 30, steps: int = 300,
                    seed: int = 0):
    """Paper §7.1: build a corpus from several benchmarks (50% of each) and
    pretrain a single model on it.  The shared vocab must be built by the
    caller over the union of the traces."""
    import numpy as np
    xs, ys = [], []
    for tr in traces:
        half, _ = tr.split(0.5)
        ct = cluster_trace(half, cluster_key)
        data = build_dataset(ct, vocab, features=list(cfg.features),
                             distance=distance, max_train=8000, seed=seed)
        xs.append(data.x_train)
        ys.append(data.y_train)
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    # reuse the dataset container for the trainer
    ds = dataclasses.replace(  # type: ignore[arg-type]
        data, x_train=x, y_train=y, x_valid=x[:256], y_valid=y[:256],
        x_test=x[:256], y_test=y[:256])
    res = train_predictor(cfg, ds, steps=steps, seed=seed)
    return res.params
