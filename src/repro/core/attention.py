"""Attention variants for the predictor: full softmax attention, Reformer-style
LSH attention, and the paper's HLSH (Hamming-based LSH) attention
(Algorithm 1) in a TPU-friendly mask formulation.

The paper's algorithm erases rows (Hamming score >= HTOP: near-orthogonal to
everything -> negligible dot products) and lets near-duplicate rows
(score <= HBOT) share one representative's attention output.  Data-dependent
erase/copy is gather/scatter-heavy; on TPU we realize identical semantics
with a multiplicative *keep mask* on Q/K plus an output *share map* applied
as a take-along-axis — the Pallas kernel (repro.kernels.hlsh_attention)
additionally skips fully-masked blocks.

These jnp implementations are the reference oracles for the kernels and are
used directly by the (tiny) predictor models.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


def full_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   ) -> jnp.ndarray:
    """(B, N, D) softmax(QK^T/sqrt(D))V."""
    d = q.shape[-1]
    logits = jnp.einsum("bnd,bmd->bnm", q, k) / jnp.sqrt(jnp.float32(d))
    return jnp.einsum("bnm,bmd->bnd", jax.nn.softmax(logits, axis=-1), v)


def local_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    window: int) -> jnp.ndarray:
    """Windowed (banded) attention: each query attends only to keys within
    ``window`` positions (|i - j| <= window).  The paper's interpretability
    analysis finds the reference Transformer's mass concentrated on recent
    deltas — this is that observation as an architecture.  With
    window >= N-1 the band covers everything and this equals
    :func:`full_attention`."""
    d = q.shape[-1]
    n = q.shape[-2]
    idx = jnp.arange(n)
    band = jnp.abs(idx[:, None] - idx[None, :]) <= window     # (N, N)
    logits = jnp.einsum("bnd,bmd->bnm", q, k) / jnp.sqrt(jnp.float32(d))
    logits = jnp.where(band[None, :, :], logits, -1e9)
    return jnp.einsum("bnm,bmd->bnd", jax.nn.softmax(logits, axis=-1), v)


def lsh_hash(x: jnp.ndarray, n_hashes: int, n_buckets: int,
             key: jax.Array) -> jnp.ndarray:
    """Angular LSH (Reformer): random rotations + argmax over [xR; -xR].
    Returns (B, N, n_hashes) int32 bucket ids."""
    d = x.shape[-1]
    r = jax.random.normal(key, (d, n_hashes, n_buckets // 2), x.dtype)
    proj = jnp.einsum("bnd,dhr->bnhr", x, r)
    proj = jnp.concatenate([proj, -proj], axis=-1)
    return jnp.argmax(proj, axis=-1).astype(jnp.int32)


def lsh_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  key: jax.Array, n_hashes: int = 4, n_buckets: int = 8,
                  ) -> jnp.ndarray:
    """Reformer-flavored LSH attention (shared-QK): attention is restricted
    to pairs that collide in at least one hash round.  O(N^2) as written (the
    mask is materialized) — the semantics, not the complexity, is what the
    predictor needs at seq_len 30; the complexity story lives in the Pallas
    kernel's block skipping."""
    d = q.shape[-1]
    qn = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
    buckets = lsh_hash(qn, n_hashes, n_buckets, key)       # (B,N,H)
    same = (buckets[:, :, None, :] == buckets[:, None, :, :]).any(-1)
    logits = jnp.einsum("bnd,bmd->bnm", q, k) / jnp.sqrt(jnp.float32(d))
    logits = jnp.where(same, logits, -1e9)
    return jnp.einsum("bnm,bmd->bnd", jax.nn.softmax(logits, axis=-1), v)


class HLSHPlan(NamedTuple):
    """The data-dependent part of HLSH, computed once per sequence:
    keep mask (B, N) and output share map (B, N) of source indices."""
    keep: jnp.ndarray
    share_src: jnp.ndarray
    hscore: jnp.ndarray


def hlsh_plan(qk: jnp.ndarray, key: jax.Array, n_hashes: int = 8,
              n_buckets: int = 8, htop: float = 0.9, hbot: float = 0.1,
              ) -> HLSHPlan:
    """Algorithm 1, lines 1-3: LSH bucketing, Hamming scoring against a
    random half of the entries, geometric-mean reduction, and the
    erase/share decisions."""
    b, n, _ = qk.shape
    k_hash, k_sel = jax.random.split(key)
    qn = qk / (jnp.linalg.norm(qk, axis=-1, keepdims=True) + 1e-6)
    h = lsh_hash(qn, n_hashes, n_buckets, k_hash)          # (B,N,H)
    # random seq_len/2 sample of K^LSH entries (shared across batch: the
    # selection is data-independent, paper line 2 samples per batch)
    m = max(n // 2, 1)
    sel = jax.random.choice(k_sel, n, (m,), replace=False)
    h_sel = h[:, sel]                                       # (B,M,H)
    ham = (h[:, :, None, :] != h_sel[:, None, :, :]).sum(-1)  # (B,N,M)
    # geometric mean over the sampled entries (line 3)
    hscore = jnp.exp(jnp.mean(jnp.log(ham.astype(jnp.float32) + 1.0),
                              axis=2)) - 1.0               # (B,N)
    erase = hscore >= htop * n_hashes
    low = hscore <= hbot * n_hashes
    # first low entry is the representative (lines 9-16)
    any_low = low.any(axis=1, keepdims=True)
    base = jnp.argmax(low, axis=1)                          # (B,)
    is_base = jnp.arange(n)[None, :] == base[:, None]
    keep = (~erase) & (~low | is_base)
    idx = jnp.broadcast_to(jnp.arange(n)[None, :], (b, n))
    share_src = jnp.where(low & any_low, base[:, None], idx)
    return HLSHPlan(keep=keep, share_src=share_src, hscore=hscore)


def hlsh_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   key: jax.Array, n_hashes: int = 8, n_buckets: int = 8,
                   htop: float = 0.9, hbot: float = 0.1) -> jnp.ndarray:
    """Paper Algorithm 1 (mask formulation).  Shared-QK callers pass q=k."""
    plan = hlsh_plan(q, key, n_hashes, n_buckets, htop, hbot)
    return hlsh_apply(q, k, v, plan)


def hlsh_apply(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               plan: HLSHPlan) -> jnp.ndarray:
    d = q.shape[-1]
    keep = plan.keep[..., None].astype(q.dtype)
    qm = q * keep
    km = k * keep
    logits = jnp.einsum("bnd,bmd->bnm", qm, km) / jnp.sqrt(jnp.float32(d))
    out = jnp.einsum("bnm,bmd->bnd", jax.nn.softmax(logits, axis=-1), v)
    # copy the representative's output into the erased near-duplicates
    return jnp.take_along_axis(out, plan.share_src[..., None], axis=1)


def hlsh_erased_fraction(plan: HLSHPlan) -> jnp.ndarray:
    """Fraction of rows whose dot products were skipped — the work saving the
    Pallas kernel turns into skipped blocks."""
    return 1.0 - plan.keep.mean()
