"""Feature extraction and trace clustering (paper Fig 3, §5.1).

A feature token has 13 fields: PC, Hit/Miss, warp, SM, TPC, CTA ids, the
page / basic-block / 2MB-root addresses, the input-array base ('In'), and the
three address deltas.  Traces are clustered before windowing; the paper shows
SM-id clustering wins (Table 2) and the revised predictor uses SM+warp.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.traces.trace import BASIC_BLOCK_PAGES, ROOT_PAGES, Trace

FEATURE_NAMES = [
    "pc", "hit", "warp", "sm", "tpc", "cta", "kernel",
    "paddr", "bbaddr", "raddr", "inarr", "dp", "dbb", "dr",
]
# 13 trace features of Fig 3 (+ kernel id, which GPGPU-Sim exposes too).
N_FEATURES = len(FEATURE_NAMES)

CLUSTER_KEYS = ("sm", "pc", "cta", "warp", "kernel", "sm_warp", "none")


@dataclasses.dataclass
class ClusteredTrace:
    """Per-cluster raw feature columns, plus the global index of each access
    so per-access predictions can be scattered back into trace order."""

    name: str
    cluster_key: str
    clusters: List[Dict[str, np.ndarray]]   # feature name -> int64 column
    global_index: List[np.ndarray]          # trace positions per cluster
    pages: List[np.ndarray]                 # raw page numbers per cluster


def _columns(trace: Trace, resident_miss: np.ndarray | None) -> Dict[str, np.ndarray]:
    a = trace.accesses
    pages = a["page"].astype(np.int64)
    bb = pages // BASIC_BLOCK_PAGES
    rt = pages // ROOT_PAGES
    if resident_miss is None:
        # first touch of a page == far-fault under on-demand paging
        _, first = np.unique(pages, return_index=True)
        miss = np.zeros(len(pages), np.int64)
        miss[first] = 1
    else:
        miss = resident_miss.astype(np.int64)
    return {
        "pc": a["pc"].astype(np.int64),
        "hit": miss,
        "warp": a["warp"].astype(np.int64),
        "sm": a["sm"].astype(np.int64),
        "tpc": a["tpc"].astype(np.int64),
        "cta": a["cta"].astype(np.int64),
        "kernel": a["kernel"].astype(np.int64),
        "paddr": pages,
        "bbaddr": bb,
        "raddr": rt,
        "inarr": a["array"].astype(np.int64),
    }


def cluster_trace(trace: Trace, key: str = "sm",
                  resident_miss: np.ndarray | None = None) -> ClusteredTrace:
    """Split the GMMU trace into per-cluster streams and compute the delta
    features *within* each cluster (deltas across cluster boundaries are
    meaningless — that is the whole point of clustering)."""
    if key not in CLUSTER_KEYS:
        raise ValueError(f"cluster key {key!r} not in {CLUSTER_KEYS}")
    cols = _columns(trace, resident_miss)
    n = len(trace)
    if key == "none":
        group_ids = np.zeros(n, np.int64)
    elif key == "sm_warp":
        group_ids = cols["sm"] * (1 << 32) + cols["warp"]
    else:
        group_ids = cols[key]

    order = np.argsort(group_ids, kind="stable")
    sorted_ids = group_ids[order]
    boundaries = np.flatnonzero(np.diff(sorted_ids)) + 1
    splits = np.split(order, boundaries)

    clusters, gidx, pages = [], [], []
    for idx in splits:
        if len(idx) < 2:
            continue
        c = {k: v[idx] for k, v in cols.items()}
        p = c["paddr"]
        c["dp"] = np.diff(p, prepend=p[0])
        c["dbb"] = np.diff(c["bbaddr"], prepend=c["bbaddr"][0])
        c["dr"] = np.diff(c["raddr"], prepend=c["raddr"][0])
        clusters.append(c)
        gidx.append(idx)
        pages.append(p)
    return ClusteredTrace(trace.name, key, clusters, gidx, pages)


def delta_convergence(ct: ClusteredTrace) -> float:
    """Ratio of the most frequent page delta to all deltas (paper §5.4) —
    the attention-bypass indicator of the revised predictor."""
    all_d = np.concatenate([c["dp"][1:] for c in ct.clusters if len(c["dp"]) > 1])
    if all_d.size == 0:
        return 1.0
    _, counts = np.unique(all_d, return_counts=True)
    return float(counts.max() / counts.sum())
