"""Predictor models (paper §4, §6, §7.2).

* ``transformer`` — the unconstrained encoder-only predictor: 13-feature
  embedding concat (200 dims), sinusoidal positions, 2 encoder layers,
  multi-head full attention, last-token classification head.
* ``revised`` is the same architecture family configured per §6: 3 features
  (paddr, dp, pc; 12 embedding dims), 1 layer, 1 head, HLSH attention with a
  convergence-based bypass, optional 4-bit quantization-aware training.
* ``fc`` / ``mlp`` / ``cnn`` / ``lstm`` — the comparison predictors of
  Table 4 and Fig 9.

The config layer (``PredictorConfig``, the ``MODEL_FAMILIES`` registry,
``family_config``/``config_digest``) lives in the jax-free
``repro.core.families`` and is re-exported here.

Pure-functional: ``init_params(cfg, key)`` -> pytree;
``apply(cfg, params, x)`` -> logits.  x is (B, seq, n_features) int32.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention as attn_lib
# config layer lives in the jax-free repro.core.families; re-exported here
# so model-side callers keep one import surface
from repro.core.families import (  # noqa: F401  (re-exports)
    EMB_DIMS, MODEL_FAMILIES, MODEL_FAMILY_BLOCKS, PredictorConfig,
    REVISED_EMB_DIMS, REVISED_FEATURES, config_digest, family_config,
    revised_config, validate_family,
)
from repro.core.quantize import fake_quant, fake_quant_tensor
from repro.core.vocab import FEATURE_BUCKETS


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, jnp.float32) * s


def init_params(cfg: PredictorConfig, key: jax.Array):
    keys = iter(jax.random.split(key, 64))
    p: Dict = {"emb": {}}
    for f, dim in cfg.emb_dims.items():
        p["emb"][f] = jax.random.normal(next(keys),
                                        (FEATURE_BUCKETS[f], dim)) * 0.02
    d = cfg.d_model
    if cfg.arch == "transformer":
        p["layers"] = []
        for _ in range(cfg.n_layers):
            ff = d * cfg.d_ff_mult
            p["layers"].append({
                "wq": _dense_init(next(keys), (d, d)),
                "wk": _dense_init(next(keys), (d, d)),
                "wv": _dense_init(next(keys), (d, d)),
                "wo": _dense_init(next(keys), (d, d)),
                "ln1": {"g": jnp.ones(d), "b": jnp.zeros(d)},
                "w1": _dense_init(next(keys), (d, ff)),
                "b1": jnp.zeros(ff),
                "w2": _dense_init(next(keys), (ff, d)),
                "b2": jnp.zeros(d),
                "ln2": {"g": jnp.ones(d), "b": jnp.zeros(d)},
            })
        p["head"] = _dense_init(next(keys), (d, cfg.n_classes))
        p["head_b"] = jnp.zeros(cfg.n_classes)
    elif cfg.arch == "fc":
        p["head"] = _dense_init(next(keys), (cfg.seq_len * d, cfg.n_classes))
        p["head_b"] = jnp.zeros(cfg.n_classes)
    elif cfg.arch == "mlp":
        h = cfg.hidden
        p["w1"] = _dense_init(next(keys), (cfg.seq_len * d, h))
        p["b1"] = jnp.zeros(h)
        p["w2"] = _dense_init(next(keys), (h, h))
        p["b2"] = jnp.zeros(h)
        p["head"] = _dense_init(next(keys), (h, cfg.n_classes))
        p["head_b"] = jnp.zeros(cfg.n_classes)
    elif cfg.arch == "cnn":
        h = cfg.hidden
        p["c1"] = _dense_init(next(keys), (3, d, h), scale=0.1)
        p["c2"] = _dense_init(next(keys), (3, h, h), scale=0.1)
        p["head"] = _dense_init(next(keys), (h, cfg.n_classes))
        p["head_b"] = jnp.zeros(cfg.n_classes)
    elif cfg.arch == "lstm":
        h = cfg.hidden
        p["wx"] = _dense_init(next(keys), (d, 4 * h))
        p["wh"] = _dense_init(next(keys), (h, 4 * h))
        p["bh"] = jnp.zeros(4 * h)
        p["head"] = _dense_init(next(keys), (h, cfg.n_classes))
        p["head_b"] = jnp.zeros(cfg.n_classes)
    else:
        raise ValueError(f"unknown arch {cfg.arch}")
    return p


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _maybe_qw(cfg: PredictorConfig, w: jnp.ndarray) -> jnp.ndarray:
    return fake_quant_tensor(w) if cfg.quantize else w


def _maybe_qa(cfg: PredictorConfig, a: jnp.ndarray) -> jnp.ndarray:
    return fake_quant(a) if cfg.quantize else a


def _embed(cfg: PredictorConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    outs = []
    for j, f in enumerate(cfg.features):
        tab = _maybe_qw(cfg, params["emb"][f])
        outs.append(tab[x[:, :, j]])
    return jnp.concatenate(outs, axis=-1)          # (B, S, d_model)


def _positional(seq_len: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq_len)[:, None]
    i = np.arange(d)[None, :]
    angle = pos / np.power(10000.0, (2 * (i // 2)) / d)
    enc = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return jnp.asarray(enc, jnp.float32)


def _layernorm(x, g, b):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3) \
            .reshape(b * n_heads, s, d // n_heads)


def _unheads(x: jnp.ndarray, n_heads: int, b: int) -> jnp.ndarray:
    bh, s, dh = x.shape
    return x.reshape(b, n_heads, s, dh).transpose(0, 2, 1, 3) \
            .reshape(b, s, n_heads * dh)


def _attention(cfg: PredictorConfig, q, k, v) -> jnp.ndarray:
    if cfg.attention == "full":
        return attn_lib.full_attention(q, k, v)
    if cfg.attention == "local":
        return attn_lib.local_attention(q, k, v, cfg.local_window)
    key = jax.random.PRNGKey(cfg.lsh_seed)
    if cfg.attention == "lsh":
        return attn_lib.lsh_attention(q, k, v, key, cfg.n_hashes,
                                      cfg.n_buckets)
    if cfg.attention == "hlsh":
        return attn_lib.hlsh_attention(q, k, v, key, cfg.n_hashes,
                                       cfg.n_buckets, cfg.htop, cfg.hbot)
    raise ValueError(f"unknown attention {cfg.attention}")


def _encoder_layer(cfg: PredictorConfig, lp, h: jnp.ndarray) -> jnp.ndarray:
    b = h.shape[0]
    if cfg.attention != "bypass":
        if cfg.attention == "hlsh":
            # shared-QK structure (Reformer / paper Algorithm 1)
            q = k = h @ _maybe_qw(cfg, lp["wq"])
        else:
            q = h @ _maybe_qw(cfg, lp["wq"])
            k = h @ _maybe_qw(cfg, lp["wk"])
        v = h @ _maybe_qw(cfg, lp["wv"])
        qh, kh, vh = (_heads(t, cfg.n_heads) for t in (q, k, v))
        o = _unheads(_attention(cfg, qh, kh, vh), cfg.n_heads, b)
        o = o @ _maybe_qw(cfg, lp["wo"])
        h = _layernorm(_maybe_qa(cfg, h + o), lp["ln1"]["g"], lp["ln1"]["b"])
    ff = jax.nn.relu(h @ _maybe_qw(cfg, lp["w1"]) + lp["b1"])
    ff = _maybe_qa(cfg, ff)
    ff = ff @ _maybe_qw(cfg, lp["w2"]) + lp["b2"]
    return _layernorm(_maybe_qa(cfg, h + ff), lp["ln2"]["g"], lp["ln2"]["b"])


def apply(cfg: PredictorConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, seq, n_features) int32 -> logits (B, n_classes)."""
    h = _embed(cfg, params, x)
    b, s, d = h.shape
    if cfg.arch == "transformer":
        h = h + _positional(s, d)
        h = _maybe_qa(cfg, h)
        for lp in params["layers"]:
            h = _encoder_layer(cfg, lp, h)
        last = h[:, -1]
        return last @ _maybe_qw(cfg, params["head"]) + params["head_b"]
    if cfg.arch == "fc":
        flat = h.reshape(b, s * d)
        return flat @ _maybe_qw(cfg, params["head"]) + params["head_b"]
    if cfg.arch == "mlp":
        z = jax.nn.relu(h.reshape(b, s * d) @ params["w1"] + params["b1"])
        z = jax.nn.relu(z @ params["w2"] + params["b2"])
        return z @ params["head"] + params["head_b"]
    if cfg.arch == "cnn":
        z = jax.lax.conv_general_dilated(
            h, params["c1"], (1,), "SAME",
            dimension_numbers=("NWC", "WIO", "NWC"))
        z = jax.nn.relu(z)
        z = jax.lax.conv_general_dilated(
            z, params["c2"], (1,), "SAME",
            dimension_numbers=("NWC", "WIO", "NWC"))
        z = jax.nn.relu(z).max(axis=1)
        return z @ params["head"] + params["head_b"]
    if cfg.arch == "lstm":
        hdim = params["wh"].shape[0]

        def step(carry, xt):
            hprev, cprev = carry
            gates = xt @ params["wx"] + hprev @ params["wh"] + params["bh"]
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f) * cprev + jax.nn.sigmoid(i) * jnp.tanh(g)
            hnew = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (hnew, c), None

        init = (jnp.zeros((b, hdim)), jnp.zeros((b, hdim)))
        (hl, _), _ = jax.lax.scan(step, init, h.transpose(1, 0, 2))
        return hl @ params["head"] + params["head_b"]
    raise ValueError(f"unknown arch {cfg.arch}")


def count_activation_elems(cfg: PredictorConfig) -> int:
    """Per-example activation element count for the footprint report
    (Tables 6-7): embeddings + every encoder-layer intermediate."""
    s, d = cfg.seq_len, cfg.d_model
    total = s * d  # embeddings (+ positions in place)
    if cfg.arch == "transformer":
        per_layer = s * d * 4          # q,k,v,o
        if cfg.attention != "bypass":
            per_layer += s * s * cfg.n_heads   # attention matrix
        per_layer += s * d * cfg.d_ff_mult + s * d * 2  # ffn + norms
        total += cfg.n_layers * per_layer
    total += cfg.n_classes
    return total
