"""Training loop for the predictors (in-repo AdamW, jitted steps)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as model_lib
from repro.core.dataset import SequenceDataset, batches
from repro.core.metrics import topk_accuracy, weighted_f1
from repro.optimizer import AdamW, linear_warmup_cosine


@dataclasses.dataclass
class TrainResult:
    params: Dict
    cfg: model_lib.PredictorConfig
    metrics: Dict[str, float]
    steps: int
    train_seconds: float


def _loss_fn(cfg, params, x, y):
    logits = model_lib.apply(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


def train_predictor(cfg: model_lib.PredictorConfig, data: SequenceDataset,
                    *, steps: int = 400, batch_size: int = 128,
                    lr: float = 3e-3, seed: int = 0,
                    params=None, eval_topk: int = 10,
                    log_every: int = 0) -> TrainResult:
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model_lib.init_params(cfg, key)
    opt = AdamW(weight_decay=1e-4, clip_norm=1.0)
    opt_state = opt.init(params)
    sched = linear_warmup_cosine(lr, warmup_steps=min(50, steps // 10 + 1),
                                 total_steps=steps)

    @jax.jit
    def step_fn(params, opt_state, x, y, step):
        loss, grads = jax.value_and_grad(
            lambda p: _loss_fn(cfg, p, x, y))(params)
        params, opt_state = opt.update(grads, params, opt_state, sched(step))
        return params, opt_state, loss

    t0 = time.time()
    it = batches(data.x_train, data.y_train, batch_size, seed=seed,
                 epochs=max(1, steps * batch_size // max(len(data.x_train), 1) + 1))
    n_done = 0
    for x, y in it:
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.asarray(x), jnp.asarray(y),
                                          jnp.asarray(n_done))
        n_done += 1
        if log_every and n_done % log_every == 0:
            print(f"  step {n_done}/{steps} loss={float(loss):.4f}")
        if n_done >= steps:
            break
    train_seconds = time.time() - t0

    metrics = evaluate(cfg, params, data, topk=eval_topk)
    return TrainResult(params=params, cfg=cfg, metrics=metrics,
                       steps=n_done, train_seconds=train_seconds)


def evaluate(cfg, params, data: SequenceDataset, topk: int = 10,
             split: str = "test", batch_size: int = 512) -> Dict[str, float]:
    x = getattr(data, f"x_{split}")
    y = getattr(data, f"y_{split}")
    logits = predict_logits(cfg, params, x, batch_size)
    return {
        "top1": topk_accuracy(logits, y, 1),
        f"top{topk}": topk_accuracy(logits, y, topk),
        "f1": weighted_f1(logits, y),
        "n": float(len(y)),
    }


_APPLY_CACHE: dict = {}


def _jitted_apply(cfg):
    fn = _APPLY_CACHE.get(cfg)
    if fn is None:
        fn = jax.jit(lambda p, xb: model_lib.apply(cfg, p, xb))
        _APPLY_CACHE[cfg] = fn
    return fn


def _jitted_cls_conf(cfg):
    """Fused top-1 class + softmax confidence: argmax/normalization run on
    device and only two scalars per window cross back to the host, instead
    of a full ``n_classes``-wide logits row."""
    fn = _APPLY_CACHE.get((cfg, "cls_conf"))
    if fn is None:
        def _cls_conf(p, xb):
            logits = model_lib.apply(cfg, p, xb)
            cls = jnp.argmax(logits, axis=-1)
            conf = jnp.max(jax.nn.softmax(logits, axis=-1), axis=-1)
            return cls, conf
        fn = jax.jit(_cls_conf)
        _APPLY_CACHE[(cfg, "cls_conf")] = fn
    return fn


def _pad_batches(x: np.ndarray, batch_size: int):
    """Yield (batch, pad) pairs of fixed shape (pad-and-mask): every batch
    has exactly ``batch_size`` rows, so jit traces one shape no matter how
    ragged the caller's windows are."""
    for i in range(0, len(x), batch_size):
        xb = x[i:i + batch_size]
        pad = 0
        if len(xb) < batch_size:
            pad = batch_size - len(xb)
            xb = np.concatenate([xb, np.zeros((pad,) + xb.shape[1:],
                                              xb.dtype)])
        yield xb, pad


def predict_logits(cfg, params, x: np.ndarray,
                   batch_size: int = 512) -> np.ndarray:
    apply_j = _jitted_apply(cfg)
    outs = []
    for xb, pad in _pad_batches(x, batch_size):
        o = np.asarray(apply_j(params, jnp.asarray(xb)))
        outs.append(o[:batch_size - pad] if pad else o)
    return np.concatenate(outs)


def predict_cls_conf(cfg, params, x: np.ndarray,
                     batch_size: int = 4096):
    """Top-1 class ids + their softmax probabilities for every row of ``x``,
    evaluated in large fixed-shape jitted batches.

    This is the serving path for ``PredictorService.predict_trace``: one
    compile per (cfg, batch, seq) shape, device-side argmax/softmax, and a
    2-column host transfer — several-fold faster than materializing logits
    per cluster slice.
    """
    if len(x) == 0:
        return (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.float32))
    fn = _jitted_cls_conf(cfg)
    cls_out, conf_out = [], []
    for xb, pad in _pad_batches(x, batch_size):
        c, p = fn(params, jnp.asarray(xb))
        c, p = np.asarray(c), np.asarray(p)
        if pad:
            c, p = c[:-pad], p[:-pad]
        cls_out.append(c)
        conf_out.append(p)
    return (np.concatenate(cls_out).astype(np.int64),
            np.concatenate(conf_out))
