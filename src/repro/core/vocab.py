"""Vocabularies: delta classification categories + feature-id encoding.

The classifier's output classes are the unique page deltas of the training
split (Hashemi et al.'s insight: unique deltas are orders of magnitude fewer
than unique addresses).  Input features are encoded into bounded integer id
spaces so embedding tables stay small: id-like features are used modulo their
table size; address-like features are bucketed by hashing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.features import ClusteredTrace, FEATURE_NAMES

UNK = 0  # class / id 0 is reserved for "unseen"

# embedding-table sizes per feature (input id space)
FEATURE_BUCKETS: Dict[str, int] = {
    "pc": 512, "hit": 2, "warp": 256, "sm": 32, "tpc": 16, "cta": 1024,
    "kernel": 64, "paddr": 4096, "bbaddr": 2048, "raddr": 512, "inarr": 16,
    "dp": 2048, "dbb": 1024, "dr": 256,
}

_HASH_MULT = np.uint64(0x9E3779B97F4A7C15)


def _hash_bucket(x: np.ndarray, buckets: int) -> np.ndarray:
    with np.errstate(over="ignore"):
        h = x.astype(np.int64).view(np.uint64) * _HASH_MULT
        h = h ^ (h >> np.uint64(29))
    return (1 + (h % np.uint64(buckets - 1))).astype(np.int64)  # 0 = UNK


@dataclasses.dataclass
class DeltaVocab:
    """Maps page deltas <-> class ids; built on the training split."""

    deltas: np.ndarray           # class id -> delta value (class 0 = UNK)
    index: Dict[int, int]

    @classmethod
    def build(cls, ct: ClusteredTrace, train_frac: float = 0.8,
              max_classes: int = 20000, distance: int = 1) -> "DeltaVocab":
        """Classes are the unique *distance-d* page deltas of the training
        split: label(i) = page[i+d] - page[i] within a cluster (d=1 is the
        next-access delta of paper Tables 1-8; the deployed prefetcher uses
        d=30 per §5.2)."""
        ds: List[np.ndarray] = []
        for c in ct.clusters:
            p = c["paddr"]
            if len(p) <= distance:
                continue
            dd = p[distance:] - p[:-distance]
            k = max(int(len(dd) * train_frac), 1)
            ds.append(dd[:k])
        all_d = np.concatenate(ds) if ds else np.zeros(0, np.int64)
        vals, counts = np.unique(all_d, return_counts=True)
        if vals.size > max_classes - 1:
            keep = np.argsort(-counts)[: max_classes - 1]
            vals = vals[np.sort(keep)]
        deltas = np.concatenate([[np.iinfo(np.int64).min], vals])
        index = {int(d): i + 1 for i, d in enumerate(vals)}
        return cls(deltas=deltas, index=index)

    @property
    def n_classes(self) -> int:
        return int(len(self.deltas))

    def encode(self, dp: np.ndarray) -> np.ndarray:
        out = np.zeros(len(dp), np.int64)
        for i, d in enumerate(dp):
            out[i] = self.index.get(int(d), UNK)
        return out

    def encode_fast(self, dp: np.ndarray) -> np.ndarray:
        """Vectorized encode via searchsorted over the sorted delta list."""
        vals = self.deltas[1:]
        pos = np.searchsorted(vals, dp)
        pos = np.clip(pos, 0, len(vals) - 1)
        ok = vals[pos] == dp
        return np.where(ok, pos + 1, UNK).astype(np.int64)

    def decode(self, cls_ids: np.ndarray) -> np.ndarray:
        return self.deltas[cls_ids]

    @property
    def convergence(self) -> float:  # set externally when known
        return getattr(self, "_convergence", 0.0)


def encode_features(cluster: Dict[str, np.ndarray],
                    features: List[str] | None = None) -> np.ndarray:
    """Encode a cluster's raw feature columns into bounded int ids.
    Returns (n, len(features)) int32."""
    feats = features or FEATURE_NAMES
    n = len(cluster["paddr"])
    out = np.zeros((n, len(feats)), np.int32)
    for j, f in enumerate(feats):
        col = cluster[f]
        b = FEATURE_BUCKETS[f]
        if f in ("paddr", "bbaddr", "raddr", "dp", "dbb", "dr", "pc", "inarr"):
            out[:, j] = _hash_bucket(col, b)
        else:
            out[:, j] = 1 + (col % (b - 1))
    return out
