"""Serving driver: continuous batching decode loop with paged KV cache and
the learned offload prefetcher (the paper's technique as a framework
feature — see repro.offload).

Positions handed to the store are *cache* positions — prefix-inflated for
VLM archs, the same coordinate the KV cache is written at — so block and
HBM-capacity accounting agree with the cache layout (the store asserts
positions stay inside its ``max_len``).  The store's access log can be
dumped as a replay-core trace (``--dump-trace``) and replayed through
``repro.uvm.sweep`` like any serve scenario
(see ``repro.offload.serve_trace``).

Usage (single host, reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --requests 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build_model, init_params
from repro.models.builder import decode, prefill
from repro.offload.paged_store import PagedKVStore
from repro.offload.learned_prefetcher import OffloadPrefetcher


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--hbm-blocks", type=int, default=48,
                    help="HBM capacity of the paged KV store, in blocks")
    ap.add_argument("--dump-trace", default=None, metavar="PATH.npz",
                    help="write the KV store's access log as a replay-core "
                         "trace (repro.offload.serve_trace npz layout)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = init_params(model, jax.random.PRNGKey(0))

    b, s = args.requests, args.prompt_len
    max_len = s + args.gen
    rng = np.random.default_rng(0)
    batch: Dict[str, jnp.ndarray] = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, max(s // 8, 8), cfg.d_model)),
            jnp.dtype(cfg.dtype))

    # VLM caches include the patch prefix: decode indices are cache-relative
    prefix = cfg.frontend_seq if cfg.family == "vlm" else 0
    max_len += prefix
    prefill_j = jax.jit(lambda p, bb: prefill(model, p, bb, max_len=max_len))
    decode_j = jax.jit(lambda p, st, t, i: decode(model, p, st, t, i))

    t0 = time.time()
    logits, states = prefill_j(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # paged KV store + learned prefetcher drive host<->HBM block residency;
    # max_len is prefix-inflated, so the capacity accounting covers the
    # patch-prefix blocks a VLM decode sweeps through
    store = PagedKVStore(n_requests=b, max_len=max_len,
                         hbm_capacity_blocks=args.hbm_blocks)
    assert store.blocks_per_seq * 64 >= max_len, \
        "store capacity accounting must cover the prefix-inflated cache"
    pf = OffloadPrefetcher(store)

    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens: List[np.ndarray] = [np.asarray(toks)]
    step_ends: List[int] = []      # access-log length after each step
    t0 = time.time()
    for step in range(args.gen - 1):
        # cache position (prefix-inflated for VLMs): the store must sweep
        # the same coordinate the KV cache is written at, or block and
        # capacity accounting disagree about the prefix blocks
        pos = prefix + s + step
        store.on_decode_step(pos)
        pf.step(pos)
        step_ends.append(len(store.access_log))
        logits, states = decode_j(params, states, toks,
                                  jnp.asarray(pos, jnp.int32))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    st = store.stats()
    # the first token per request comes from prefill — only gen-1 decode
    # steps ran inside the timed window
    n_decoded = b * (args.gen - 1)
    print(f"served {b} requests: prefill {t_prefill*1e3:.0f} ms, "
          f"{args.gen} tokens/request; {n_decoded} tokens decoded in "
          f"{t_decode*1e3:.0f} ms "
          f"({n_decoded/max(t_decode,1e-9):.0f} tok/s)")
    print(f"kv-store: hit-rate={st['hit_rate']:.3f} "
          f"prefetch-acc={st['prefetch_accuracy']:.3f} "
          f"host-bytes={st['host_bytes']/1e6:.1f}MB")
    print("sample tokens:", gen[0, :16].tolist())

    if args.dump_trace:
        from repro.offload.serve_trace import (access_log_to_trace,
                                               save_trace_npz)
        trace = access_log_to_trace(
            store.access_log, n_requests=b,
            blocks_per_seq=store.blocks_per_seq,
            name=f"serve-{args.arch}", step_ends=step_ends)
        save_trace_npz(trace, args.dump_trace)
        print(f"dump-trace: {len(trace)} accesses over "
              f"{len(step_ends)} decode steps -> {args.dump_trace}")


if __name__ == "__main__":
    main()
