"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run launcher sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods (512 chips) on a
    leading pure-DP "pod" axis (DCN-connected)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Single-host debug mesh over however many devices exist."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))
