"""Roofline accounting from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs            / (chips * PEAK_FLOPS)
    memory     = HLO_bytes_accessed   / (chips * HBM_BW)
    collective = collective_bytes     / (chips * ICI_BW)

``cost_analysis`` reports whole-program FLOPs/bytes of the SPMD module
(per-partition); collective bytes are not reported there, so they are parsed
from the compiled HLO text: we sum result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (all-reduce
counted twice: reduce-scatter + all-gather phases of a ring).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.MULTILINE)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind result bytes (per partition) from HLO text.
    ``-start`` ops are counted, matching ``-done`` ops are not (async pairs
    would double count)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in s:
            continue
        kind = m.group(2)
        nbytes = _shape_bytes(m.group(1))
        factor = 2.0 if kind == "all-reduce" else 1.0
        out[kind] += nbytes * factor
        counts[kind] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # per-partition HLO flops
    hbm_bytes: float           # per-partition bytes accessed
    coll_bytes: float          # per-partition collective bytes
    chips: int
    coll_detail: Optional[Dict] = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms (perfect
        overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck, "step_s": self.step_s,
            "coll_detail": {k: v for k, v in (self.coll_detail or {}).items()
                            if k != "_counts"},
            "coll_counts": (self.coll_detail or {}).get("_counts"),
        }


def from_compiled(compiled, hlo_text: str, chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    total_coll = sum(v for k, v in coll.items() if k != "_counts")
    return Roofline(flops=flops, hbm_bytes=nbytes, coll_bytes=total_coll,
                    chips=chips, coll_detail=coll)


def model_flops(cfg, shape, n_params_active: float) -> float:
    """MODEL_FLOPS = 6*N*D for training (fwd+bwd), 2*N*D for inference, with
    N = active parameter count."""
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch
