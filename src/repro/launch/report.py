"""Generate the EXPERIMENTS.md §Dry-run / §Roofline / §Perf tables from the
recorded dry-run and hillclimb JSON artifacts.

    PYTHONPATH=src python -m repro.launch.report > experiments/report.md
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(dirpath: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*", "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def _ms(x) -> str:
    return f"{x*1e3:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | status | peak GB/chip | compile s | "
             "collectives (AG/AR/RS/A2A/CP) |",
             "|---|---|---|---|---|---|"]
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                     if r["shape"] in SHAPE_ORDER else 9)
    for r in sorted(recs, key=key):
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {r['status']}: "
                         f"{reason} | | | |")
            continue
        mem = r.get("memory", {})
        peak = mem.get("peak_bytes") or mem.get("temp_bytes") or 0
        cc = (r.get("roofline", {}).get("coll_counts") or {})
        counts = "/".join(str(cc.get(k, 0)) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{(peak or 0)/1e9:.2f} | {r.get('compile_s', 0):.0f} | "
            f"{counts} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | compute ms | memory ms | collective ms | "
             "bottleneck | MODEL_FLOPS/HLO | note |",
             "|---|---|---|---|---|---|---|---|"]
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                     if r["shape"] in SHAPE_ORDER else 9)
    for r in sorted(recs, key=key):
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | {r.get('reason','')[:48]} |")
            continue
        roof = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        note = _move_note(roof["bottleneck"], r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_ms(roof['compute_s'])} | "
            f"{_ms(roof['memory_s'])} | {_ms(roof['collective_s'])} | "
            f"{roof['bottleneck']} | "
            f"{ratio:.3f} | {note} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {_ms(roof['compute_s'])} | "
            f"{_ms(roof['memory_s'])} | {_ms(roof['collective_s'])} | "
            f"{roof['bottleneck']} | — | {note} |")
    return "\n".join(lines)


def _move_note(bottleneck: str, r: Dict) -> str:
    shape = r["shape"]
    if bottleneck == "compute":
        if "moe" in r["arch"]:
            return "cut capacity factor / drop remat recompute"
        return "drop remat recompute; bf16 accumulations"
    if bottleneck == "memory":
        if shape.startswith("prefill") or shape == "train_4k":
            return "fuse attention (flash kernel) to kill S^2 logit traffic"
        return "bf16 logits; shrink cache reads via windowing"
    return "de-FSDP hot weights / overlap collectives with compute"


def perf_table(perf_dir: str) -> str:
    lines = ["| cell | variant | hypothesis | compute ms | memory ms | "
             "collective ms | bottleneck | verdict |",
             "|---|---|---|---|---|---|---|---|"]
    by_cell: Dict[str, List[Dict]] = {}
    for path in sorted(glob.glob(os.path.join(perf_dir, "*.json"))):
        cell, variant = os.path.basename(path)[:-5].split(".", 1)
        with open(path) as f:
            r = json.load(f)
        r["_cell"], r["_variant"] = cell, variant
        by_cell.setdefault(cell, []).append(r)
    for cell, rs in by_cell.items():
        base = next((r for r in rs if r["_variant"] == "baseline"), None)
        bdom = (base or {}).get("roofline", {})
        for r in rs:
            if r.get("status") != "ok":
                lines.append(f"| {cell} | {r['_variant']} | "
                             f"{r.get('hypothesis','')[:60]} | — | — | — | "
                             f"failed | {r.get('error','')[:40]} |")
                continue
            roof = r["roofline"]
            verdict = ""
            if base and r is not base and bdom:
                deltas = {}
                for term in ("compute", "memory", "collective"):
                    before = bdom[f"{term}_s"]
                    after = roof[f"{term}_s"]
                    deltas[term] = ((before - after) / before * 100
                                    if before else 0.0)
                dom = bdom["bottleneck"]
                best = max(deltas, key=deltas.get)
                ok = deltas[dom] > 2 or deltas[best] > 10
                verdict = (f"{'confirmed' if ok else 'refuted'} "
                           f"({dom} {deltas[dom]:+.1f}%"
                           + (f"; {best} {deltas[best]:+.1f}%"
                              if best != dom else "") + ")")
            lines.append(
                f"| {cell} | {r['_variant']} | "
                f"{r.get('hypothesis', '')[:60]} | "
                f"{_ms(roof['compute_s'])} | {_ms(roof['memory_s'])} | "
                f"{_ms(roof['collective_s'])} | {roof['bottleneck']} | "
                f"{verdict} |")
    return "\n".join(lines)


def main() -> None:
    base = "experiments"
    print("# Generated dry-run / roofline / perf report\n")
    for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
        d = os.path.join(base, "dryrun", mesh)
        if not os.path.isdir(d):
            continue
        recs = _load(d)
        print(f"\n## Dry-run — {mesh} ({len(recs)} cells)\n")
        print(dryrun_table(recs))
        if mesh == "single_pod_16x16":
            print(f"\n## Roofline — {mesh}\n")
            print(roofline_table(recs))
    perf = os.path.join(base, "perf")
    if os.path.isdir(perf):
        print("\n## Perf iterations\n")
        print(perf_table(perf))


if __name__ == "__main__":
    main()
