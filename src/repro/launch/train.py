"""Distributed training driver.

Composes: model zoo + in-repo AdamW + sharding rules + checkpointing +
fault-tolerance hooks + optional gradient compression on the pod (DCN) axis.

Usage (single host, debug):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 50 --batch 8 --seq 256 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import TokenPipeline
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.distributed.sharding import (
    batch_shardings, make_mesh, mesh_context, opt_shardings,
    param_shardings_stacked)
from repro.models import build_model, init_params, train_loss
from repro.optimizer import (
    AdamW, ErrorFeedbackState, compress_with_error_feedback,
    init_error_feedback, linear_warmup_cosine)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 10
    grad_compress: Optional[str] = None    # None | "int8" | "topk"
    zero1: bool = False
    fsdp: bool = False
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None


def make_train_step(model, opt: AdamW, lr_fn, grad_compress: Optional[str]):
    """Returns step(params, opt_state, ef_state, batch, step) ->
    (params, opt_state, ef_state, metrics)."""

    def step_fn(params, opt_state, ef_state, batch, step):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: train_loss(model, p, batch), has_aux=True)(params)
        if grad_compress is not None:
            # compress the gradient that crosses the slow pod (DCN) axis;
            # error feedback keeps the scheme unbiased over time.
            grads, ef_state = compress_with_error_feedback(
                grads, ef_state, mode=grad_compress)
        params, opt_state = opt.update(grads, params, opt_state, lr_fn(step))
        metrics = {"loss": loss, **aux}
        return params, opt_state, ef_state, metrics

    return step_fn


def build_sharded_train(model, mesh, tc: TrainConfig, shape_batch):
    """Lower a fully-sharded train step; returns (jitted_fn, shardings)."""
    opt = AdamW(weight_decay=0.1, clip_norm=1.0)
    lr_fn = linear_warmup_cosine(tc.lr, tc.warmup, tc.steps)
    step_fn = make_train_step(model, opt, lr_fn, tc.grad_compress)

    params_shape = jax.eval_shape(
        lambda k: init_params(model, k), jax.random.PRNGKey(0))
    p_sh = param_shardings_stacked(params_shape, mesh, fsdp=tc.fsdp)
    opt_state_shape = jax.eval_shape(opt.init, params_shape)
    o_sh = type(opt_state_shape)(
        step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        mu=opt_shardings(p_sh, params_shape, mesh, zero1=tc.zero1),
        nu=opt_shardings(p_sh, params_shape, mesh, zero1=tc.zero1),
    )
    ef_sh = (opt_shardings(p_sh, params_shape, mesh, zero1=tc.zero1)
             if tc.grad_compress else None)
    b_sh = batch_shardings(shape_batch, mesh,
                           next(iter(shape_batch.values())).shape[0])
    scalar_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    fn = jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, ef_sh, b_sh, scalar_sh),
        out_shardings=(p_sh, o_sh, ef_sh, None),
        donate_argnums=(0, 1, 2),
    )
    return fn, dict(params=p_sh, opt=o_sh, ef=ef_sh, batch=b_sh, optd=opt)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) config of the arch")
    ap.add_argument("--grad-compress", choices=["int8", "topk"], default=None)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, name=cfg.name)
    model = build_model(cfg)
    tc = TrainConfig(steps=args.steps, lr=args.lr,
                     grad_compress=args.grad_compress, zero1=args.zero1,
                     checkpoint_dir=args.checkpoint_dir)

    n_dev = len(jax.devices())
    mesh = make_mesh((1, n_dev), ("data", "model"))

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         batch_size=args.batch)
    sample = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in pipe.next_batch().items()}
    pipe.restore({"step": 0, "seed": pipe.seed, "rank": 0, "world": 1})

    with mesh_context(mesh):
        fn, sh = build_sharded_train(model, mesh, tc, sample)
        params = init_params(model, jax.random.PRNGKey(0))
        opt_state = sh["optd"].init(params)
        ef_state = (init_error_feedback(params) if tc.grad_compress else None)

        ckpt = (CheckpointManager(tc.checkpoint_dir)
                if tc.checkpoint_dir else None)
        start = 0
        if ckpt and args.resume and ckpt.latest_step() is not None:
            params, extra = ckpt.restore(params)
            pipe.restore(extra["pipeline"])
            start = extra["step"]
            print(f"resumed from step {start}")

        hb = HeartbeatMonitor()
        for step in range(start, tc.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
            params, opt_state, ef_state, metrics = fn(
                params, opt_state, ef_state, batch,
                jnp.asarray(step, jnp.int32))
            dt = time.time() - t0
            hb.beat(host=0, step_time_s=dt)
            if step % 10 == 0 or step == tc.steps - 1:
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"({dt*1000:.0f} ms)")
            if ckpt and (step + 1) % tc.checkpoint_every == 0:
                ckpt.save_async(step + 1, params,
                                {"step": step + 1,
                                 "pipeline": pipe.state()})
        if ckpt:
            ckpt.wait()


if __name__ == "__main__":
    main()
