"""Multi-pod dry-run: prove the distribution config is coherent for every
(architecture x input shape x mesh) cell without real hardware.

For each cell this lowers + compiles the full sharded program (train_step for
train shapes; prefill/serve_step for inference shapes) against
ShapeDtypeStruct inputs — no array is ever materialized — and records
memory_analysis, cost_analysis, and the collective schedule for the roofline
report (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --arch all
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single \
        --arch llama3-8b --shape train_4k
"""
# The container has ONE CPU device; the production mesh needs 512 host
# placeholders.  Must run before ANY other import that touches jax.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse            # noqa: E402
import dataclasses         # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
import numpy as np         # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch      # noqa: E402
from repro.distributed.sharding import (               # noqa: E402
    batch_axes_for, batch_shardings, mesh_context, opt_shardings,
    param_shardings_stacked)
from repro.launch import roofline as rl                # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.models import build_model, init_params      # noqa: E402
from repro.models.builder import (                     # noqa: E402
    all_segments, decode, init_decode_state, prefill, train_loss,
    with_counts)
from repro.optimizer import AdamW                      # noqa: E402


def input_specs(cfg, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_seq, cfg.d_model), dt)
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, max(s // 8, 8), cfg.d_model), dt)
    return batch


def _active_params(params_shape, cfg) -> Tuple[float, float]:
    """(total_params, active_params): MoE experts count at top_k/E; embeddings
    excluded from active (6*N*D convention)."""
    total = active = 0.0
    frac = (cfg.top_k / cfg.n_experts) if cfg.n_experts else 1.0

    def walk(path, leaf):
        nonlocal total, active
        n = float(np.prod(leaf.shape))
        total += n
        name = str(getattr(path[-1], "key", ""))
        if name in ("embed", "head"):
            return
        is_expert = leaf.ndim >= 3 and name in ("wg", "wu", "wd") and \
            cfg.n_experts and leaf.shape[-3] == cfg.n_experts
        active += n * (frac if is_expert else 1.0)

    jax.tree_util.tree_map_with_path(walk, params_shape)
    return total, active


def decode_state_shardings(model, states_shape, mesh, global_batch):
    """Sharding for KV caches / SSM states / LRU states (see DESIGN §6):
    batch over (pod, data); heads over model if divisible, else the cache
    *sequence* dim over model (context parallelism), else replicate."""
    cfg = model.cfg
    baxes = batch_axes_for(global_batch, mesh) or None
    msize = mesh.shape.get("model", 1)

    def kv_spec(shape):  # (L, B, S, KV, hd)
        if shape[3] % msize == 0 and shape[3] >= msize:
            return P(None, baxes, None, "model", None)
        if shape[2] % msize == 0 and shape[2] >= msize:
            return P(None, baxes, "model", None, None)
        return P(None, baxes, None, None, None)

    out = []
    for seg_states in states_shape:
        if seg_states is None:
            out.append(None)
            continue
        d: Dict[str, Any] = {}
        for key, st in seg_states.items():
            if isinstance(st, tuple):
                d[key] = tuple(NamedSharding(mesh, kv_spec(x.shape))
                               for x in st)
            elif st.ndim == 5:   # ssd (L, B, H, P, N)
                spec = (P(None, baxes, "model", None, None)
                        if st.shape[2] % msize == 0 else
                        P(None, baxes, None, None, None))
                d[key] = NamedSharding(mesh, spec)
            else:                # lru (L, B, Dr)
                spec = (P(None, baxes, "model")
                        if st.shape[2] % msize == 0 else
                        P(None, baxes, None))
                d[key] = NamedSharding(mesh, spec)
        out.append(d)
    return out


def _lower_shape(model, cfg, shape, mesh, fsdp: bool, zero1: bool):
    """Lower + compile the appropriate step function for one shape; returns
    the compiled object."""
    params_shape = jax.eval_shape(
        lambda k: init_params(model, k), jax.random.PRNGKey(0))
    p_sh = param_shardings_stacked(params_shape, mesh, fsdp=fsdp)
    batch = input_specs(cfg, shape)
    b_sh = batch_shardings(batch, mesh, shape.global_batch)

    if shape.kind == "train":
        opt = AdamW(weight_decay=0.1, clip_norm=1.0)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        o_sh = type(opt_shape)(
            step=NamedSharding(mesh, P()),
            mu=opt_shardings(p_sh, params_shape, mesh, zero1=zero1),
            nu=opt_shardings(p_sh, params_shape, mesh, zero1=zero1),
        )

        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: train_loss(model, p, batch),
                has_aux=True)(params)
            params, opt_state = opt.update(grads, params, opt_state, 1e-4)
            return params, opt_state, loss

        lowered = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
        ).lower(params_shape, opt_shape, batch)
    elif shape.kind == "prefill":
        lowered = jax.jit(
            lambda p, b: prefill(model, p, b),
            in_shardings=(p_sh, b_sh),
        ).lower(params_shape, batch)
    else:  # decode
        cache_len = shape.seq_len
        enc_len = max(shape.seq_len // 8, 8) if cfg.family == "audio" else 0
        states_shape = jax.eval_shape(
            lambda: init_decode_state(model, None, shape.global_batch,
                                      cache_len, enc_len=enc_len))
        st_sh = decode_state_shardings(model, states_shape, mesh,
                                       shape.global_batch)
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        tok_sh = NamedSharding(
            mesh, P(batch_axes_for(shape.global_batch, mesh) or None, None))
        lowered = jax.jit(
            lambda p, st, t, i: decode(model, p, st, t, i),
            in_shardings=(p_sh, st_sh, tok_sh, NamedSharding(mesh, P())),
            out_shardings=(None, st_sh),
        ).lower(params_shape, states_shape, tok, idx)
    return lowered


def _measure(compiled, chips):
    hlo = compiled.as_text()
    roof = rl.from_compiled(compiled, hlo, chips)
    return np.array([roof.flops, roof.hbm_bytes, roof.coll_bytes]), roof


def lower_cell(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               zero1: bool = True, probes: bool = True,
               cfg_override: Optional[Dict[str, Any]] = None
               ) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) cell; return the record.

    XLA's cost_analysis counts a while-loop (scan-over-layers) body ONCE, so
    raw numbers undercount depth.  With ``probes=True`` we additionally
    compile unrolled 1-layer and 2-layer probe programs per segment and
    linearly extrapolate exact per-layer costs:
        total = outside + sum_seg count_seg * body_seg.
    """
    cfg = get_arch(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    chips = int(np.prod(list(mesh.shape.values())))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "chips": chips,
        "mesh": dict(mesh.shape), "status": "ok", "fsdp": fsdp,
        "zero1": zero1, "cfg_override": cfg_override or {},
    }

    if shape.kind == "decode" and shape_name == "long_500k" \
            and not cfg.subquadratic:
        rec["status"] = "skipped"
        rec["reason"] = ("full quadratic-attention family: 512k-token KV "
                        "decode excluded per assignment (sub-quadratic "
                        "archs only)")
        return rec

    params_shape = jax.eval_shape(
        lambda k: init_params(model, k), jax.random.PRNGKey(0))
    total, active = _active_params(params_shape, cfg)
    rec["params_total"] = total
    rec["params_active"] = active

    with mesh_context(mesh):
        t0 = time.time()
        lowered = _lower_shape(model, cfg, shape, mesh, fsdp, zero1)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1

        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}

        raw, roof = _measure(compiled, chips)
        rec["roofline_raw"] = roof.as_dict()

        counts = [s.count for s in all_segments(model)]
        corrected = None
        if probes and counts and max(counts) > 2:
            try:
                # two-point probe: all segment counts 1, then all 2; the
                # aggregate per-layer body cost extrapolates linearly to the
                # real depths (exact for single-segment archs; weighted
                # average across heterogeneous segments otherwise).
                t2 = time.time()
                base_c = _lower_shape(with_counts(model, [1] * len(counts)),
                                      cfg, shape, mesh, fsdp, zero1).compile()
                base, _ = _measure(base_c, chips)
                two_c = _lower_shape(with_counts(model, [2] * len(counts)),
                                     cfg, shape, mesh, fsdp, zero1).compile()
                two, _ = _measure(two_c, chips)
                body_sum = np.maximum(two - base, 0.0)   # sum of seg bodies
                outside = np.maximum(base - body_sum, 0.0)
                # per-segment bodies are ~proportional to pattern length, so
                # the effective trip count is the pattern-length-weighted
                # mean of segment counts (exact for single-segment archs)
                lens = [len(s.pattern) for s in all_segments(model)]
                eff = (sum(c * l for c, l in zip(counts, lens))
                       / max(sum(lens), 1))
                corrected = outside + eff * body_sum
                rec["probe_s"] = time.time() - t2
                rec["probe_body_sum"] = body_sum.tolist()
                rec["probe_outside"] = outside.tolist()
            except Exception as e:   # pragma: no cover
                rec["probe_error"] = str(e)

        if corrected is not None:
            roof = rl.Roofline(flops=float(corrected[0]),
                               hbm_bytes=float(corrected[1]),
                               coll_bytes=float(corrected[2]),
                               chips=chips,
                               coll_detail=roof.coll_detail)
        rec["roofline"] = roof.as_dict()
        mf = rl.model_flops(cfg, shape, active)
        rec["model_flops"] = mf
        # per-partition HLO flops x chips = whole-program flops
        hlo_total = roof.flops * chips
        rec["useful_flops_ratio"] = (mf / hlo_total) if hlo_total else None
    return rec


def run(args) -> int:
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                out_dir = os.path.join(args.out, mesh_name,
                                       arch.replace("/", "_"))
                os.makedirs(out_dir, exist_ok=True)
                out_path = os.path.join(out_dir, f"{shape_name}.json")
                if os.path.exists(out_path) and not args.force:
                    print(f"[cached] {mesh_name} {arch} {shape_name}")
                    continue
                print(f"[dryrun] {mesh_name} {arch} {shape_name} ...",
                      flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh)
                except Exception as e:
                    failures += 1
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": dict(mesh.shape), "status": "failed",
                           "error": str(e),
                           "traceback": traceback.format_exc()[-2000:]}
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=2, default=str)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"bottleneck={r['bottleneck']} "
                             f"step={r['step_s']*1e3:.2f}ms "
                             f"compile={rec['compile_s']:.0f}s")
                elif status == "skipped":
                    extra = rec["reason"][:60]
                else:
                    extra = rec["error"][:120]
                print(f"  -> {status} {extra}", flush=True)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    failures = run(args)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")
    print("dry-run complete: all cells ok")


if __name__ == "__main__":
    main()
