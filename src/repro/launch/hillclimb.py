"""Performance hillclimbing over the three selected dry-run cells
(EXPERIMENTS.md §Perf).

Each variant re-lowers + re-compiles the cell and records the probe-corrected
roofline terms; the log captures hypothesis -> change -> before -> after.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen3_train
    PYTHONPATH=src python -m repro.launch.hillclimb --cell all
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

from repro.launch.dryrun import lower_cell           # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402

# Each variant: (name, hypothesis, kwargs for lower_cell)
CELLS = {
    # Worst useful-flops ratio + compute-bound: the 235B MoE train step.
    "qwen3_train": {
        "arch": "qwen3-moe-235b-a22b", "shape": "train_4k",
        "why": ("worst roofline fraction of the 40-cell baseline "
                "(useful-flops ratio ~0.2, compute-bound)"),
        "variants": [
            ("einsum_dispatch", "iteration 0a: global one-hot einsum MoE "
             "dispatch costs O(T^2 K D) MXU flops — expected to be "
             "compute-catastrophic at 65k tokens/shard",
             {"cfg_override": {"moe_dispatch": "einsum"}}),
            ("scatter_dispatch", "iteration 0b: scatter-add dispatch has "
             "minimal flops but GSPMD lowers sharded scatter to replicated "
             "data movement — expected collective-catastrophic",
             {"cfg_override": {"moe_dispatch": "scatter"}}),
            ("baseline", "grouped (GShard-style) dispatch: token groups "
             "bound the quadratic dispatch term, einsum form keeps the "
             "all-to-all lowering; remat on, capacity 1.25, FSDP+ZeRO1",
             {}),
            ("no_remat", "remat recomputes every block in backward: "
             "dropping it should cut HLO flops ~25-30% at higher live "
             "memory", {"cfg_override": {"remat": False}}),
            ("cap_1.0", "MoE dispatch capacity 1.25->1.0 removes 20% of "
             "expert FLOPs (dropped tokens) and shrinks all-to-all "
             "payloads by the same factor",
             {"cfg_override": {"capacity_factor": 1.0}}),
            ("group_128", "the dispatch one-hot tensor is T*K*1.25*Tg*K "
             "elements — linear in group size; 512->128 should cut the "
             "dominant memory term ~4x at higher drop variance",
             {"cfg_override": {"moe_group_tokens": 128}}),
            ("group_64", "further halve the dispatch tensor (drop variance "
             "grows: 5 slots/expert/group)",
             {"cfg_override": {"moe_group_tokens": 64}}),
            ("combo", "no_remat + cap_1.0 + group_128",
             {"cfg_override": {"remat": False, "capacity_factor": 1.0,
                               "moe_group_tokens": 128}}),
        ],
    },
    # Most collective-bound cell of the baseline table.
    "mamba2_train": {
        "arch": "mamba2-780m", "shape": "train_4k",
        "why": "most collective-bound baseline cell",
        "variants": [
            ("baseline", "FSDP+ZeRO1 on a 780M model", {}),
            ("no_fsdp", "780M params fit per-chip even unsharded on data; "
             "FSDP's per-layer all-gathers are pure overhead at this scale "
             "-> collective term should collapse", {"fsdp": False}),
            ("no_fsdp_chunk256", "bigger SSD chunks halve the number of "
             "inter-chunk state exchanges and scan steps",
             {"fsdp": False, "cfg_override": {"ssd_chunk": 256}}),
            ("no_fsdp_no_remat", "also drop remat: fewer recomputed "
             "collectives in backward",
             {"fsdp": False, "cfg_override": {"remat": False}}),
        ],
    },
    # Most representative of the paper's technique: latency-bound decode
    # with a 32k KV cache (the page-paging serving regime).
    "llama3_decode": {
        "arch": "llama3-8b", "shape": "decode_32k",
        "why": ("serving/KV-cache regime the paper's prefetcher targets; "
                "decode latency is what page-miss stalls would add to"),
        "variants": [
            ("baseline", "training-style sharding reused for serving "
             "(FSDP weights)", {}),
            ("tp_resident", "FSDP weights must be all-gathered EVERY decode "
             "step; serving wants TP-resident weights -> collective term "
             "should drop by ~2x params/chips bytes", {"fsdp": False}),
            ("tp_bf16_logits", "TP-resident + bf16 attention logits over "
             "the 32k cache (halves decode attention bytes)",
             {"fsdp": False,
              "cfg_override": {"attn_f32_logits": False}}),
        ],
    },
}


def run_cell(name: str, out_dir: str) -> None:
    spec = CELLS[name]
    mesh = make_production_mesh()
    os.makedirs(out_dir, exist_ok=True)
    print(f"== hillclimb {name}: {spec['arch']} x {spec['shape']} ==")
    print(f"   rationale: {spec['why']}")
    results = []
    for vname, hypothesis, kw in spec["variants"]:
        path = os.path.join(out_dir, f"{name}.{vname}.json")
        if os.path.exists(path):
            rec = json.load(open(path))
            print(f"  [cached] {vname}")
        else:
            print(f"  [lower+compile] {vname}: {hypothesis[:70]}...",
                  flush=True)
            t0 = time.time()
            try:
                rec = lower_cell(spec["arch"], spec["shape"], mesh, **kw)
                rec["variant"] = vname
                rec["hypothesis"] = hypothesis
                rec["wall_s"] = time.time() - t0
            except Exception as e:
                rec = {"variant": vname, "status": "failed",
                       "error": str(e),
                       "traceback": traceback.format_exc()[-1500:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=2, default=str)
        results.append(rec)
        if rec.get("status") == "ok":
            r = rec["roofline"]
            print(f"    -> compute={r['compute_s']*1e3:9.2f}ms "
                  f"memory={r['memory_s']*1e3:9.2f}ms "
                  f"collective={r['collective_s']*1e3:9.2f}ms "
                  f"bottleneck={r['bottleneck']}", flush=True)
        else:
            print(f"    -> FAILED {rec.get('error', '')[:100]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=list(CELLS) + ["all"])
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    cells = list(CELLS) if args.cell == "all" else [args.cell]
    for c in cells:
        run_cell(c, args.out)


if __name__ == "__main__":
    main()
