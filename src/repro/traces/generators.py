"""Algorithmic page-access generators for the paper's 11 GPU benchmarks.

Each generator derives per-CTA page-level access streams from the benchmark's
actual algorithm (row/column streaming for the Polybench matrix-vector
kernels, stencils for Hotspot/Srad/2DCONV, wavefront for NW, DP rows for
Pathfinder, layered phases for Backprop, pure streams for AddVectors /
StreamTriad).  The GPU execution model (gpu_model.py) schedules these CTAs
onto SMs and merges them into GMMU arrival order.

Array allocations are 2 MB aligned, mirroring ``cudaMallocManaged``; all
addresses are 4 KB page indices.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.traces.trace import ROOT_PAGES

FLOAT = 4  # sizeof(float)
PAGE = 4096


@dataclasses.dataclass
class CTAStream:
    """Program-order page accesses issued by one CTA inside one kernel.

    `burst` is the mean number of consecutive GMMU requests this CTA issues
    before the SM scheduler switches away.  Streaming kernels with little
    compute per page (Polybench MV sweeps) issue long lockstep runs; stencil
    kernels with more compute per page are interrupted often.
    """

    kernel: int
    cta: int
    pcs: np.ndarray      # uint32, same length as pages
    arrays: np.ndarray   # uint16 array ids
    pages: np.ndarray    # int64 page indices
    burst: float = 24.0


@dataclasses.dataclass
class BenchmarkSpec:
    name: str
    streams: List[CTAStream]
    array_bases: Dict[str, int]   # array name -> base page
    array_pages: Dict[str, int]   # array name -> pages
    n_instructions: int

    @property
    def total_accesses(self) -> int:
        return sum(len(s.pages) for s in self.streams)


class _Alloc:
    """2MB-aligned bump allocator over virtual page space."""

    def __init__(self, seed: int) -> None:
        rng = np.random.default_rng(seed)
        # Random 2MB-aligned heap base, like a real VA layout.
        self.cursor = int(rng.integers(1 << 10, 1 << 20)) * ROOT_PAGES
        self.bases: Dict[str, int] = {}
        self.sizes: Dict[str, int] = {}
        self.ids: Dict[str, int] = {}

    def alloc(self, name: str, nbytes: int) -> int:
        pages = -(-nbytes // PAGE)
        base = self.cursor
        self.bases[name] = base
        self.sizes[name] = pages
        self.ids[name] = len(self.ids)
        # bump by whole 2MB chunks
        self.cursor += -(-pages // ROOT_PAGES) * ROOT_PAGES
        return base

    def aid(self, name: str) -> int:
        return self.ids[name]


def _pc(kernel: int, slot: int) -> int:
    """Deterministic PC for (kernel launch, static load/store slot)."""
    return 0x400000 + kernel * 0x1000 + slot * 0x20


def _stream(kernel: int, cta: int, parts: List[Tuple[int, int, np.ndarray]],
            burst: float = 24.0) -> CTAStream:
    """Build a CTAStream from (pc, array_id, pages) segments, interleaved in
    the given order element-wise when lengths match, else concatenated."""
    lens = {len(p[2]) for p in parts}
    if len(lens) == 1 and len(parts) > 1:
        n = lens.pop()
        k = len(parts)
        pcs = np.empty(n * k, np.uint32)
        arrs = np.empty(n * k, np.uint16)
        pages = np.empty(n * k, np.int64)
        for i, (pc, aid, pg) in enumerate(parts):
            pcs[i::k] = pc
            arrs[i::k] = aid
            pages[i::k] = pg
    else:
        pcs = np.concatenate([np.full(len(p[2]), p[0], np.uint32) for p in parts])
        arrs = np.concatenate([np.full(len(p[2]), p[1], np.uint16) for p in parts])
        pages = np.concatenate([p[2].astype(np.int64) for p in parts])
    return CTAStream(kernel, cta, pcs, arrs, pages, burst=burst)


def _row_pages(base: int, row: int, pages_per_row: int) -> np.ndarray:
    return base + row * pages_per_row + np.arange(pages_per_row, dtype=np.int64)


# ---------------------------------------------------------------------------
# Streaming kernels
# ---------------------------------------------------------------------------

def gen_addvectors(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """c[i] = a[i] + b[i]; CTAs own contiguous chunks of three streams."""
    n = int(8e6 * scale)  # elements
    al = _Alloc(seed)
    for name in ("a", "b", "c"):
        al.alloc(name, n * FLOAT)
    pages_per_cta = 16
    n_pages = al.sizes["a"]
    n_ctas = n_pages // pages_per_cta
    streams = []
    for cta in range(n_ctas):
        lo = cta * pages_per_cta
        idx = np.arange(lo, lo + pages_per_cta, dtype=np.int64)
        streams.append(_stream(0, cta, [
            (_pc(0, 0), al.aid("a"), al.bases["a"] + idx),
            (_pc(0, 1), al.aid("b"), al.bases["b"] + idx),
            (_pc(0, 2), al.aid("c"), al.bases["c"] + idx),
        ], burst=256.0))
    return BenchmarkSpec("AddVectors", streams, al.bases, al.sizes,
                         n_instructions=n * 3)


def gen_streamtriad(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """a[i] = b[i] + s * c[i] (STREAM triad)."""
    n = int(6e6 * scale)
    al = _Alloc(seed + 1)
    for name in ("a", "b", "c"):
        al.alloc(name, n * FLOAT)
    pages_per_cta = 8
    n_pages = al.sizes["a"]
    n_ctas = n_pages // pages_per_cta
    streams = []
    for cta in range(n_ctas):
        lo = cta * pages_per_cta
        idx = np.arange(lo, lo + pages_per_cta, dtype=np.int64)
        streams.append(_stream(0, cta, [
            (_pc(0, 0), al.aid("b"), al.bases["b"] + idx),
            (_pc(0, 1), al.aid("c"), al.bases["c"] + idx),
            (_pc(0, 2), al.aid("a"), al.bases["a"] + idx),
        ], burst=256.0))
    return BenchmarkSpec("StreamTriad", streams, al.bases, al.sizes,
                         n_instructions=n * 3)


# ---------------------------------------------------------------------------
# Polybench matrix-vector family (dominant-delta benchmarks)
# ---------------------------------------------------------------------------

def _mv_kernel(al: _Alloc, kernel: int, mat: str, n_rows: int,
               pages_per_row: int, rows_per_cta: int,
               col_block: int = 0) -> List[CTAStream]:
    """Polybench GPU matrix-vector kernels map one *thread per row*; a warp's
    coalesced lockstep sweep over the dot-product index therefore requests
    consecutive-row pages at a fixed column block — a constant page stride of
    +pages_per_row.  This single dominant delta (16384 B = 4 pages when rows
    are 16 KB) is exactly what the paper reports for ATAX/BICG/MVT (§5.3:
    99.26 % convergence).  Revisits of the same pages while the k-loop sweeps
    within a column block are absorbed by the SM TLB and never reach the
    GMMU.  Bursts are long: almost no compute per page."""
    streams = []
    n_ctas = n_rows // rows_per_cta
    for cta in range(n_ctas):
        r0 = cta * rows_per_cta
        rows = np.arange(r0, r0 + rows_per_cta, dtype=np.int64)
        pages = al.bases[mat] + rows * pages_per_row + col_block
        streams.append(_stream(kernel, cta,
                               [(_pc(kernel, col_block), al.aid(mat), pages)],
                               burst=512.0))
    return streams


def gen_atax(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """y = A^T (A x).  K0: tmp=Ax row-streams A; K1: y=A^T tmp column-sweeps A."""
    n = int(4096 * max(scale, 0.05))
    ppr = max(1, n * FLOAT // PAGE)           # pages per row (4 for n=4096)
    al = _Alloc(seed + 2)
    al.alloc("A", n * n * FLOAT)
    al.alloc("x", n * FLOAT)
    al.alloc("y", n * FLOAT)
    al.alloc("tmp", n * FLOAT)
    streams = []
    for kernel in (0, 1):  # tmp = A x; y = A^T tmp — both thread-per-row
        for blk in range(ppr):
            streams += _mv_kernel(al, kernel, "A", n, ppr, rows_per_cta=256,
                                  col_block=blk)
    return BenchmarkSpec("ATAX", streams, al.bases, al.sizes,
                         n_instructions=2 * n * n)


def gen_bicg(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """s = A^T r (column sweep); q = A p (row stream)."""
    n = int(4096 * max(scale, 0.05))
    ppr = max(1, n * FLOAT // PAGE)
    al = _Alloc(seed + 3)
    al.alloc("A", n * n * FLOAT)
    for v in ("r", "s", "p", "q"):
        al.alloc(v, n * FLOAT)
    streams = []
    for kernel in (0, 1):
        for blk in range(ppr):
            streams += _mv_kernel(al, kernel, "A", n, ppr, rows_per_cta=256,
                                  col_block=blk)
    return BenchmarkSpec("BICG", streams, al.bases, al.sizes,
                         n_instructions=2 * n * n)


def gen_mvt(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """x1 += A y1 (rows); x2 += A^T y2 (columns)."""
    n = int(4096 * max(scale, 0.05))
    ppr = max(1, n * FLOAT // PAGE)
    al = _Alloc(seed + 4)
    al.alloc("A", n * n * FLOAT)
    for v in ("x1", "x2", "y1", "y2"):
        al.alloc(v, n * FLOAT)
    streams = []
    for kernel in (0, 1):
        for blk in range(ppr):
            streams += _mv_kernel(al, kernel, "A", n, ppr, rows_per_cta=256,
                                  col_block=blk)
    return BenchmarkSpec("MVT", streams, al.bases, al.sizes,
                         n_instructions=2 * n * n)


# ---------------------------------------------------------------------------
# Rodinia kernels
# ---------------------------------------------------------------------------

def gen_backprop(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """Two-layer MLP (65536 -> 16): forward weight stream then backward
    adjust.  Phase change flips the array set and the stride pattern."""
    in_units = int(65536 * max(scale, 0.05))
    hid = 64
    epochs = 2
    al = _Alloc(seed + 5)
    al.alloc("input_units", in_units * FLOAT)
    al.alloc("w1", in_units * hid * FLOAT)
    al.alloc("delta_w1", in_units * hid * FLOAT)
    al.alloc("hidden_units", hid * FLOAT)
    w_pages = al.sizes["w1"]
    in_pages = al.sizes["input_units"]
    streams = []
    units_per_cta = 1024
    n_ctas = in_units // units_per_cta
    wpages_per_cta = w_pages // n_ctas
    ipages_per_cta = max(1, in_pages // n_ctas)
    for ep in range(epochs):
        kf, kb = ep * 2, ep * 2 + 1
        # forward: each CTA handles a block of input units, reading the
        # inputs and the corresponding hid-wide weight slab (row-major
        # in_units x hid).
        for cta in range(n_ctas):
            wp = al.bases["w1"] + cta * wpages_per_cta + np.arange(wpages_per_cta, dtype=np.int64)
            ip = al.bases["input_units"] + cta * ipages_per_cta + np.arange(ipages_per_cta, dtype=np.int64)
            streams.append(_stream(kf, cta, [
                (_pc(kf, 0), al.aid("input_units"), ip),
                (_pc(kf, 1), al.aid("w1"), wp),
            ]))
        # backward: adjust weights; w1 and delta_w1 interleaved.
        for cta in range(n_ctas):
            wp = al.bases["w1"] + cta * wpages_per_cta + np.arange(wpages_per_cta, dtype=np.int64)
            dp = al.bases["delta_w1"] + cta * wpages_per_cta + np.arange(wpages_per_cta, dtype=np.int64)
            streams.append(_stream(kb, cta, [
                (_pc(kb, 0), al.aid("w1"), wp),
                (_pc(kb, 1), al.aid("delta_w1"), dp),
            ]))
    return BenchmarkSpec("Backprop", streams, al.bases, al.sizes,
                         n_instructions=epochs * in_units * hid * 4)


def gen_hotspot(scale: float = 1.0, seed: int = 0, iters: int = 2) -> BenchmarkSpec:
    """2D 5-point stencil over temp/power grids; CTA tiles span 16 rows by one
    page-width of columns (1024 floats), ping-pong buffers across iterations."""
    n = int(2048 * max(scale, 0.1))
    ppr = max(1, n * FLOAT // PAGE)   # pages per grid row (2 for n=2048)
    tile = 16                         # rows per tile; cols per tile = 1 page
    al = _Alloc(seed + 6)
    al.alloc("temp_src", n * n * FLOAT)
    al.alloc("temp_dst", n * n * FLOAT)
    al.alloc("power", n * n * FLOAT)
    streams = []
    tiles_y = n // tile
    for it in range(iters):
        src, dst = ("temp_src", "temp_dst") if it % 2 == 0 else ("temp_dst", "temp_src")
        kernel = it
        for ty in range(tiles_y):
            for col_pg in range(ppr):
                cta = ty * ppr + col_pg
                r0 = ty * tile
                trows = np.arange(r0, r0 + tile, dtype=np.int64)
                # halo rows are touched first (shared-memory fill), then the
                # three arrays are read/written element-wise interleaved
                halo = np.array([max(r0 - 1, 0), min(r0 + tile, n - 1)],
                                dtype=np.int64)
                streams.append(_stream(kernel, cta, [
                    (_pc(kernel, 3), al.aid(src), al.bases[src] + halo * ppr + col_pg),
                ]))
                streams.append(_stream(kernel, cta, [
                    (_pc(kernel, 0), al.aid(src), al.bases[src] + trows * ppr + col_pg),
                    (_pc(kernel, 1), al.aid("power"), al.bases["power"] + trows * ppr + col_pg),
                    (_pc(kernel, 2), al.aid(dst), al.bases[dst] + trows * ppr + col_pg),
                ]))
    return BenchmarkSpec("Hotspot", streams, al.bases, al.sizes,
                         n_instructions=iters * n * n * 8)


def gen_nw(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """Needleman-Wunsch: anti-diagonal wavefront over the score matrix and the
    reference matrix; block (bi, bj) is processed at wave bi+bj."""
    n = int(1024 * max(scale, 0.1))
    tile = 16
    ppr = max(1, (n + 1) * FLOAT // PAGE)
    al = _Alloc(seed + 7)
    al.alloc("itemsets", (n + 1) * (n + 1) * FLOAT)
    al.alloc("reference", (n + 1) * (n + 1) * FLOAT)
    blocks = n // tile
    streams = []
    cta = 0
    for wave in range(2 * blocks - 1):
        kernel = 0 if wave < blocks else 1
        lo = max(0, wave - blocks + 1)
        hi = min(wave, blocks - 1)
        for bi in range(lo, hi + 1):
            bj = wave - bi
            r0 = bi * tile
            col_pg = (bj * tile * FLOAT) // PAGE
            rows = np.arange(r0, r0 + tile, dtype=np.int64)
            it_pages = al.bases["itemsets"] + rows * ppr + min(col_pg, ppr - 1)
            rf_pages = al.bases["reference"] + rows * ppr + min(col_pg, ppr - 1)
            streams.append(_stream(kernel, cta, [
                (_pc(kernel, 0), al.aid("itemsets"), it_pages),
                (_pc(kernel, 1), al.aid("reference"), rf_pages),
            ]))
            cta += 1
    return BenchmarkSpec("NW", streams, al.bases, al.sizes,
                         n_instructions=n * n * 6)


def gen_pathfinder(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """Row-by-row DP: each iteration reads the wall row and ping-pongs between
    two result buffers."""
    cols = int(200_000 * max(scale, 0.05))
    rows = 64
    al = _Alloc(seed + 8)
    al.alloc("wall", cols * rows * FLOAT)
    al.alloc("res_a", cols * FLOAT)
    al.alloc("res_b", cols * FLOAT)
    row_pages = max(1, cols * FLOAT // PAGE)
    pages_per_cta = 8
    n_ctas = row_pages // pages_per_cta
    streams = []
    for r in range(rows):
        src, dst = ("res_a", "res_b") if r % 2 == 0 else ("res_b", "res_a")
        for cta in range(n_ctas):
            off = cta * pages_per_cta + np.arange(pages_per_cta, dtype=np.int64)
            wall_pages = al.bases["wall"] + r * row_pages + off
            streams.append(_stream(r, cta, [
                (_pc(0, 0), al.aid("wall"), wall_pages),
                (_pc(0, 1), al.aid(src), al.bases[src] + off),
                (_pc(0, 2), al.aid(dst), al.bases[dst] + off),
            ], burst=64.0))
    return BenchmarkSpec("Pathfinder", streams, al.bases, al.sizes,
                         n_instructions=rows * cols * 3)


def gen_srad_v2(scale: float = 1.0, seed: int = 0, iters: int = 2) -> BenchmarkSpec:
    """SRAD v2: two stencil kernels per iteration over image J and the
    derivative/coefficient arrays; tiles span 16 rows by one page-width."""
    n = int(2048 * max(scale, 0.1))
    ppr = max(1, n * FLOAT // PAGE)
    tile = 16
    al = _Alloc(seed + 9)
    for name in ("J", "dN", "dS", "dW", "dE", "c"):
        al.alloc(name, n * n * FLOAT)
    tiles_y = n // tile
    streams = []
    for it in range(iters):
        for ty in range(tiles_y):
            for col_pg in range(ppr):
                cta = ty * ppr + col_pg
                r0 = ty * tile
                trows = np.arange(r0, r0 + tile, dtype=np.int64)
                halo = np.array([max(r0 - 1, 0), min(r0 + tile, n - 1)],
                                dtype=np.int64)
                k0 = it * 2
                streams.append(_stream(k0, cta, [
                    (_pc(k0, 3), al.aid("J"), al.bases["J"] + halo * ppr + col_pg),
                ]))
                streams.append(_stream(k0, cta, [
                    (_pc(k0, 0), al.aid("J"), al.bases["J"] + trows * ppr + col_pg),
                    (_pc(k0, 1), al.aid("dN"), al.bases["dN"] + trows * ppr + col_pg),
                    (_pc(k0, 2), al.aid("c"), al.bases["c"] + trows * ppr + col_pg),
                ]))
                k1 = it * 2 + 1
                streams.append(_stream(k1, cta, [
                    (_pc(k1, 0), al.aid("c"), al.bases["c"] + trows * ppr + col_pg),
                    (_pc(k1, 1), al.aid("dN"), al.bases["dN"] + trows * ppr + col_pg),
                    (_pc(k1, 2), al.aid("J"), al.bases["J"] + trows * ppr + col_pg),
                ]))
    return BenchmarkSpec("Srad-v2", streams, al.bases, al.sizes,
                         n_instructions=iters * n * n * 16)


def gen_2dconv(scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    """3x3 convolution: read rows r-1..r+1 of A, write row r of B."""
    n = int(2048 * max(scale, 0.1))
    ppr = max(1, n * FLOAT // PAGE)
    al = _Alloc(seed + 10)
    al.alloc("A", n * n * FLOAT)
    al.alloc("B", n * n * FLOAT)
    rows_per_cta = 4
    n_ctas = (n - 2) // rows_per_cta
    streams = []
    pg = np.arange(ppr, dtype=np.int64)
    for cta in range(n_ctas):
        r0 = 1 + cta * rows_per_cta
        for r in range(r0, r0 + rows_per_cta):
            streams.append(_stream(0, cta, [
                (_pc(0, 0), al.aid("A"), al.bases["A"] + (r - 1) * ppr + pg),
                (_pc(0, 1), al.aid("A"), al.bases["A"] + r * ppr + pg),
                (_pc(0, 2), al.aid("A"), al.bases["A"] + (r + 1) * ppr + pg),
                (_pc(0, 3), al.aid("B"), al.bases["B"] + r * ppr + pg),
            ], burst=64.0))
    return BenchmarkSpec("2DCONV", streams, al.bases, al.sizes,
                         n_instructions=n * n * 9)


BENCHMARKS: Dict[str, Callable[..., BenchmarkSpec]] = {
    "AddVectors": gen_addvectors,
    "ATAX": gen_atax,
    "Backprop": gen_backprop,
    "BICG": gen_bicg,
    "Hotspot": gen_hotspot,
    "MVT": gen_mvt,
    "NW": gen_nw,
    "Pathfinder": gen_pathfinder,
    "Srad-v2": gen_srad_v2,
    "StreamTriad": gen_streamtriad,
    "2DCONV": gen_2dconv,
}

# The 9 benchmarks used for predictor training tables (paper Tables 1-8).
PREDICTOR_BENCHMARKS = [
    "AddVectors", "ATAX", "Backprop", "BICG", "Hotspot",
    "MVT", "NW", "Pathfinder", "Srad-v2",
]


def generate_benchmark(name: str, scale: float = 1.0, seed: int = 0) -> BenchmarkSpec:
    if name not in BENCHMARKS:
        raise KeyError(f"unknown benchmark {name!r}; have {sorted(BENCHMARKS)}")
    return BENCHMARKS[name](scale=scale, seed=seed)
