"""Trace container shared by the generators, the UVM simulator and the core
predictor pipeline.

Addresses are kept at 4 KB *page* granularity (the GMMU in the paper's
simulator coalesces warp accesses; far-faults are page-level events).  The
64 KB basic block and 2 MB root chunk of the tree prefetcher are expressed in
pages.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

PAGE_SIZE = 4096                 # bytes per page (paper Table 9)
BASIC_BLOCK_PAGES = 16           # 64 KB prefetch unit
ROOT_PAGES = 512                 # 2 MB tree root

# Structured record for one coalesced GMMU access.
ACCESS_DTYPE = np.dtype([
    ("pc", np.uint32),       # instruction address
    ("sm", np.uint16),       # streaming multiprocessor id
    ("tpc", np.uint16),      # texture processing cluster id (= sm // 2)
    ("cta", np.uint32),      # cooperative thread array id
    ("warp", np.uint32),     # warp id (global)
    ("kernel", np.uint16),   # kernel launch index
    ("array", np.uint16),    # which input array ('In' feature)
    ("page", np.int64),      # 4KB virtual page index
])


@dataclasses.dataclass
class Trace:
    """A GMMU-order memory access trace for one benchmark run."""

    name: str
    accesses: np.ndarray                  # ACCESS_DTYPE records, GMMU order
    array_bases: Dict[str, int]           # array name -> base page
    array_pages: Dict[str, int]           # array name -> size in pages
    n_instructions: int                   # modeled instruction count
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.accesses.dtype != ACCESS_DTYPE:
            raise TypeError(f"bad access dtype {self.accesses.dtype}")

    def __len__(self) -> int:
        return int(self.accesses.shape[0])

    @property
    def pages(self) -> np.ndarray:
        return self.accesses["page"]

    @property
    def working_set_pages(self) -> int:
        return int(np.unique(self.accesses["page"]).size)

    def split(self, frac: float) -> "tuple[Trace, Trace]":
        """Chronological split (train/validation)."""
        k = int(len(self) * frac)
        a = dataclasses.replace(self, accesses=self.accesses[:k])
        b = dataclasses.replace(self, accesses=self.accesses[k:])
        return a, b


def concat_streams(streams: List[np.ndarray]) -> np.ndarray:
    if not streams:
        return np.empty(0, dtype=ACCESS_DTYPE)
    return np.concatenate(streams)


def make_records(n: int) -> np.ndarray:
    return np.zeros(n, dtype=ACCESS_DTYPE)
