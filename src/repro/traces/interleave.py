"""Multi-tenant trace interleaver: two benchmarks sharing one device.

Shared-virtual-memory studies (arXiv 2405.06811) show that interference
between diverse co-resident applications dominates paging behavior — an
axis the paper's one-benchmark-at-a-time evaluation never exercises.
This module zips two benchmark traces into ONE access stream so the UVM
replay sees what a multi-tenant deployment sees: two working sets
contending for a single device memory.

A multi-tenant bench is named ``"<A>+<B>"`` (e.g. ``"ATAX+Pathfinder"``);
:func:`is_mt_bench` is the routing predicate (mirroring
``repro.offload.serve_trace.is_serve_bench``) and :func:`build_mt_trace`
the pure builder the sweep's ``load_trace`` dispatches to.

Construction:

* **Disjoint page regions** — each component trace is rebased (root-window
  aligned, so the tree prefetcher's 2 MB root structure is preserved) into
  its own region: tenant 0 at a seeded 2 MB-aligned base, tenant 1
  immediately above tenant 0's span plus one guard root window.  The
  region *boundary* page is the whole tenancy encoding: the tenant of any
  access is simply ``page >= boundary``, which stays correct through
  window splits, npz cache round-trips, and dense-span rebasing inside
  the replay engines.
* **Clock-proportional interleave** — accesses merge in the order of
  their per-tenant progress fractions (access ``i`` of an ``n_a``-long
  trace sorts at key ``(i+1)*n_b`` against ``(j+1)*n_a``), so a long
  tenant dribbles between a short tenant's accesses the way two
  concurrently running kernels would, with tenant 0 winning exact ties.
  The merge is deterministic: no RNG beyond the seeded base placement.

The ``trace.meta["mt"]`` sidecar carries only JSON-safe scalars
(component names + the boundary) so cached npz traces round-trip it
losslessly.  Per-tenant access counts and streams are always *derived*
from pages vs. the boundary — never stored — so they remain correct on
any slice of the trace.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.traces.trace import ROOT_PAGES, Trace, concat_streams

#: separator of a multi-tenant bench name ("ATAX+Pathfinder")
MT_SEPARATOR = "+"

#: number of tenants an interleaved trace carries (the replay engines
#: support exactly two; a deeper mix is future work)
N_TENANTS = 2


def split_mt_bench(name: str) -> Optional[Tuple[str, str]]:
    """``"A+B"`` -> ``("A", "B")`` when both halves are known GPU
    benchmarks, else None (serve workloads and nested mixes excluded)."""
    if not isinstance(name, str) or MT_SEPARATOR not in name:
        return None
    parts = name.split(MT_SEPARATOR)
    if len(parts) != 2 or not all(parts):
        return None
    from repro.traces.generators import BENCHMARKS
    if not all(p in BENCHMARKS for p in parts):
        return None
    return parts[0], parts[1]


def is_mt_bench(name: str) -> bool:
    """True for multi-tenant bench-pair names like ``"ATAX+Pathfinder"``."""
    return split_mt_bench(name) is not None


def _rebase(pages: np.ndarray, base: int) -> Tuple[np.ndarray, int]:
    """Shift a page stream so its root-aligned floor lands on ``base``
    (itself root-aligned), preserving every in-root-window offset; returns
    the shifted stream and its exclusive root-aligned span end."""
    lo = (int(pages.min()) // ROOT_PAGES) * ROOT_PAGES
    shifted = pages.astype(np.int64) + (base - lo)
    end = int(shifted.max()) + 1
    end = ((end + ROOT_PAGES - 1) // ROOT_PAGES) * ROOT_PAGES
    return shifted, end


def build_mt_trace(bench: str, scale: float = 1.0, seed: int = 0) -> Trace:
    """Build one interleaved multi-tenant trace for ``"<A>+<B>"``.

    Pure function of (bench, scale, seed) — the sweep's npz trace cache
    and the golden fixtures rely on that determinism.
    """
    parts = split_mt_bench(bench)
    if parts is None:
        raise ValueError(f"not a multi-tenant bench name: {bench!r} "
                         f"(expected '<A>{MT_SEPARATOR}<B>' with both "
                         "halves GPU benchmarks)")
    from repro.traces import GPUModel, generate_benchmark
    from repro.traces.gpu_model import GPUModelConfig
    traces = [GPUModel(GPUModelConfig(seed=seed)).run(
        generate_benchmark(p, scale=scale, seed=seed)) for p in parts]

    # seeded 2MB-aligned base for tenant 0 (same idiom as serve_trace);
    # tenant 1 starts one guard root window above tenant 0's span
    base_rng = np.random.default_rng([seed, 0x17E2])
    base0 = int(base_rng.integers(1 << 10, 1 << 18)) * ROOT_PAGES
    pages0, end0 = _rebase(np.asarray(traces[0].pages), base0)
    boundary = end0 + ROOT_PAGES
    pages1, _ = _rebase(np.asarray(traces[1].pages), boundary)

    rec0 = traces[0].accesses.copy()
    rec1 = traces[1].accesses.copy()
    rec0["page"] = pages0
    rec1["page"] = pages1

    # clock-proportional merge: sort by per-tenant progress fraction
    # (i+1)/n_a vs (j+1)/n_b on a common integer grid; the stable sort
    # over [tenant0 block, tenant1 block] breaks exact ties tenant0-first
    na, nb = len(rec0), len(rec1)
    keys = np.concatenate([
        (np.arange(1, na + 1, dtype=np.int64)) * nb,
        (np.arange(1, nb + 1, dtype=np.int64)) * na,
    ])
    order = np.argsort(keys, kind="stable")
    accesses = concat_streams([rec0, rec1])[order]

    array_bases: Dict[str, int] = {}
    array_pages: Dict[str, int] = {}
    for t, (part, tr, shifted) in enumerate(
            zip(parts, traces, (pages0, pages1))):
        delta = int(shifted[0]) - int(np.asarray(tr.pages)[0])
        for aname, abase in tr.array_bases.items():
            array_bases[f"t{t}/{part}/{aname}"] = int(abase) + delta
            array_pages[f"t{t}/{part}/{aname}"] = \
                int(tr.array_pages[aname])

    return Trace(
        name=bench,
        accesses=accesses,
        array_bases=array_bases,
        array_pages=array_pages,
        n_instructions=sum(t.n_instructions for t in traces),
        meta={"mt": {"benches": list(parts), "tenants": N_TENANTS,
                     "boundary": int(boundary)}},
    )


# ---------------------------------------------------------------------------
# derived tenancy views (always computed from pages vs. the boundary, so
# they stay correct on window-split or otherwise sliced traces)
# ---------------------------------------------------------------------------

def mt_meta(trace: Trace) -> Optional[Dict]:
    """The ``meta["mt"]`` sidecar, or None for single-tenant traces."""
    if trace.meta and isinstance(trace.meta.get("mt"), dict):
        return trace.meta["mt"]
    return None


def tenant_boundary(trace: Trace) -> Optional[int]:
    """Absolute page index where tenant 1's region begins (None when the
    trace is single-tenant)."""
    mt = mt_meta(trace)
    return int(mt["boundary"]) if mt else None


def tenant_stream(trace: Trace) -> Optional[np.ndarray]:
    """Per-access tenant ids as int8 (the pallas lanes feed this stream
    into the kernel verbatim), or None for single-tenant traces."""
    boundary = tenant_boundary(trace)
    if boundary is None:
        return None
    return (np.asarray(trace.pages) >= boundary).astype(np.int8)


def tenant_counts(trace: Trace) -> Optional[Tuple[int, int]]:
    """Per-tenant access counts of (this slice of) the trace."""
    stream = tenant_stream(trace)
    if stream is None:
        return None
    n1 = int(stream.sum())
    return len(stream) - n1, n1


def tenant_last_index(trace: Trace) -> Optional[Tuple[int, int]]:
    """Index of each tenant's last access (-1 when a tenant has none)."""
    stream = tenant_stream(trace)
    if stream is None:
        return None
    out = []
    for t in range(N_TENANTS):
        idx = np.nonzero(stream == t)[0]
        out.append(int(idx[-1]) if idx.size else -1)
    return out[0], out[1]


def mt_component_trace(trace: Trace, tenant: int) -> Trace:
    """One tenant's accesses extracted as a standalone trace (pages kept
    in the tenant's rebased region) — the *solo replay* the sweep's
    interference-slowdown column compares against."""
    stream = tenant_stream(trace)
    if stream is None:
        raise ValueError(f"{trace.name!r} is not a multi-tenant trace")
    mt = mt_meta(trace)
    mask = stream == tenant
    prefix = f"t{tenant}/"
    meta = {k: v for k, v in trace.meta.items() if k != "mt"}
    return dataclasses.replace(
        trace,
        name=f"{mt['benches'][tenant]}@t{tenant}",
        accesses=trace.accesses[mask],
        array_bases={k: v for k, v in trace.array_bases.items()
                     if k.startswith(prefix)},
        array_pages={k: v for k, v in trace.array_pages.items()
                     if k.startswith(prefix)},
        n_instructions=max(1, int(trace.n_instructions
                                  * mask.sum() / max(len(stream), 1))),
        meta=meta,
    )
