"""GPU execution model: CTA dispatch, per-SM interleaving, TLB filtering, and
GMMU stream merge.

This stands in for GPGPU-Sim as the paper's trace source.  It models the two
properties the paper's insights depend on:

1.  Per-SM access streams are near-program-order (a CTA runs to completion on
    one SM, fine-grained multithreading interleaves the resident CTAs), while
    the *merged* GMMU stream interleaves 28 SMs — which destroys PC-sequence
    order.  This is exactly why SM-id clustering wins the paper's Table 2.
2.  Hot, small arrays (the `x` vector of ATAX, DP buffers, ...) are absorbed
    by the SM's TLB and rarely reach the GMMU, so the GMMU trace of the
    streaming Polybench kernels is dominated by one large address delta
    (paper §5.3: 99.26 % convergence for ATAX).

The merge uses per-access virtual timestamps (exponential gaps with per-SM
rate jitter) so scheduling noise is reproducible under a seed.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List

import numpy as np

from repro.traces.generators import BenchmarkSpec, CTAStream
from repro.traces.trace import ACCESS_DTYPE, Trace


@dataclasses.dataclass
class GPUModelConfig:
    """Paper Table 9: GTX 1080 Ti (Pascal), 28 SMs, 64 warps / 32 CTAs max."""

    n_sms: int = 28
    max_cta_per_sm: int = 16
    warps_per_cta: int = 8
    tlb_window: int = 1024     # per-SM TLB reuse window (accesses)
    sm_rate_sigma: float = 0.35  # log-normal jitter of per-SM progress rates
    burst_len: float = 24.0    # mean GMMU-request burst length per CTA; a
    # warp that faulted on a page computes on it for a while, so page-level
    # requests from one CTA arrive in runs before the scheduler switches.
    seed: int = 0


class GPUModel:
    """Schedules BenchmarkSpec CTA streams onto SMs and emits the GMMU trace."""

    def __init__(self, config: GPUModelConfig | None = None) -> None:
        self.config = config or GPUModelConfig()

    # ------------------------------------------------------------------
    def run(self, spec: BenchmarkSpec) -> Trace:
        cfg = self.config
        # crc32, not hash(): str hashing is salted per process, which would
        # make traces (and every downstream golden fixture) irreproducible
        rng = np.random.default_rng(
            cfg.seed ^ (zlib.crc32(spec.name.encode()) & 0xFFFF))
        kernels = sorted({s.kernel for s in spec.streams})
        per_kernel: Dict[int, List[CTAStream]] = {k: [] for k in kernels}
        for s in spec.streams:
            per_kernel[s.kernel].append(s)

        out_chunks: List[np.ndarray] = []
        t_base = 0.0
        for k in kernels:
            chunk, t_base = self._run_kernel(per_kernel[k], rng, t_base)
            out_chunks.append(chunk)
        accesses = np.concatenate(out_chunks) if out_chunks else np.empty(0, ACCESS_DTYPE)
        return Trace(
            name=spec.name,
            accesses=accesses,
            array_bases=dict(spec.array_bases),
            array_pages=dict(spec.array_pages),
            n_instructions=spec.n_instructions,
            meta={"generated_accesses": float(spec.total_accesses)},
        )

    # ------------------------------------------------------------------
    def _run_kernel(self, streams: List[CTAStream], rng: np.random.Generator,
                    t_base: float):
        cfg = self.config
        # Round-robin CTA dispatch over SMs, in waves of max_cta_per_sm.
        streams = sorted(streams, key=lambda s: s.cta)
        sm_events: List[np.ndarray] = []
        sm_times: List[np.ndarray] = []
        slot_capacity = cfg.n_sms * cfg.max_cta_per_sm
        for sm in range(cfg.n_sms):
            mine = streams[sm::cfg.n_sms]
            if not mine:
                continue
            recs, times = self._sm_schedule(sm, mine, rng, slot_capacity, t_base)
            recs, times = self._tlb_filter(recs, times)
            sm_events.append(recs)
            sm_times.append(times)
        if not sm_events:
            return np.empty(0, ACCESS_DTYPE), t_base
        all_recs = np.concatenate(sm_events)
        all_times = np.concatenate(sm_times)
        order = np.argsort(all_times, kind="stable")
        t_end = float(all_times.max()) if all_times.size else t_base
        return all_recs[order], t_end

    def _sm_schedule(self, sm: int, mine: List[CTAStream],
                     rng: np.random.Generator, slot_capacity: int,
                     t_base: float):
        """Interleave the CTAs resident on one SM; later waves start after
        earlier ones retire.

        The schedule is *deterministic round-robin over bursts* with small
        timing jitter — GPGPU-Sim's GTO warp scheduler is deterministic, and
        that determinism is what makes per-SM access patterns learnable
        (the paper's premise).  A CTA issues ``burst`` page requests, then
        the scheduler rotates to the next resident CTA.
        """
        cfg = self.config
        n_total = sum(len(s.pages) for s in mine)
        recs = np.zeros(n_total, dtype=ACCESS_DTYPE)
        times = np.empty(n_total, dtype=np.float64)
        pos = 0
        # per-SM progress rate (stragglers / fast SMs)
        rate = float(np.exp(rng.normal(0.0, cfg.sm_rate_sigma)))
        wave_len = cfg.max_cta_per_sm
        wave_t = t_base
        for w0 in range(0, len(mine), wave_len):
            wave = mine[w0:w0 + wave_len]
            wave_end = wave_t
            n_resident = len(wave)
            for slot, s in enumerate(wave):
                n = len(s.pages)
                burst_len = max(int(s.burst), 1)
                idx = np.arange(n)
                burst_id = idx // burst_len
                within = idx % burst_len
                # round-robin: burst b of slot k starts after every resident
                # CTA finished its burst b-1
                ts = (wave_t
                      + burst_id * (burst_len * n_resident) / rate
                      + slot * burst_len / rate
                      + within / rate
                      + rng.normal(0.0, 0.05, size=n))
                sl = slice(pos, pos + n)
                recs["pc"][sl] = s.pcs
                recs["sm"][sl] = sm
                recs["tpc"][sl] = sm // 2
                recs["cta"][sl] = s.cta
                # hardware warp *slot* within the SM (64 slots, reused as
                # CTAs retire) — the id GPGPU-Sim exposes to the GMMU
                warp_base = (s.cta * cfg.warps_per_cta) % 64
                recs["warp"][sl] = (warp_base + (np.arange(n) % cfg.warps_per_cta)) % 64
                recs["kernel"][sl] = s.kernel
                recs["array"][sl] = s.arrays
                recs["page"][sl] = s.pages
                times[sl] = ts
                wave_end = max(wave_end, float(ts[-1]) if n else wave_t)
                pos += n
            wave_t = wave_end
        return recs[:pos], times[:pos]

    def _tlb_filter(self, recs: np.ndarray, times: np.ndarray):
        """Drop accesses whose page was touched by this SM within the last
        `tlb_window` accesses (they hit the SM-side TLB and never reach the
        GMMU).  Window-based approximation of an LRU TLB."""
        w = self.config.tlb_window
        if w <= 0 or recs.size == 0:
            return recs, times
        last_seen: Dict[int, int] = {}
        keep = np.ones(recs.size, dtype=bool)
        pages = recs["page"]
        for i in range(pages.size):
            p = int(pages[i])
            j = last_seen.get(p)
            if j is not None and i - j <= w:
                keep[i] = False
            last_seen[p] = i
        return recs[keep], times[keep]
