"""Trace substrate: benchmark access-pattern generators + GPU execution model.

Replaces the paper's GPGPU-Sim trace source.  Each benchmark generator emits
per-CTA page-level access streams derived from the benchmark's actual
algorithmic access pattern; the GPU model schedules CTAs onto SMs and merges
per-SM streams into the GMMU-arrival-order trace the predictor trains on.
"""
from repro.traces.trace import Trace, PAGE_SIZE, BASIC_BLOCK_PAGES, ROOT_PAGES
from repro.traces.generators import BENCHMARKS, generate_benchmark
from repro.traces.gpu_model import GPUModel, GPUModelConfig

__all__ = [
    "Trace", "PAGE_SIZE", "BASIC_BLOCK_PAGES", "ROOT_PAGES",
    "BENCHMARKS", "generate_benchmark", "GPUModel", "GPUModelConfig",
]
