"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family]."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, d_ff=1536,
    vocab=151936, n_experts=128, top_k=8, head_dim=64,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-30B-A3B",
)
