"""smollm-360m [hf:HuggingFaceTB/SmolLM-360M]."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv=5, d_ff=2560,
    vocab=49152, head_dim=64, tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M (360M sibling)",
)
