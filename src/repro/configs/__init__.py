"""Architecture configs: one module per assigned architecture plus the
paper's own predictor config.  ``get_arch(id)`` / ``--arch <id>``."""
from repro.configs.arch import ArchConfig, SHAPES, ShapeSpec
from repro.configs.registry import ARCHS, get_arch, list_archs

__all__ = ["ArchConfig", "ARCHS", "get_arch", "list_archs", "SHAPES",
           "ShapeSpec"]
