"""ArchConfig: declarative description of every supported architecture, and
the assigned input-shape suite (train_4k / prefill_32k / decode_32k /
long_500k)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    # hybrid (recurrentgemma): local-attention window + block pattern period
    window: Optional[int] = None
    pattern: Tuple[str, ...] = ()
    # encoder-decoder
    enc_layers: int = 0
    # modality frontend stub: precomputed embeddings of this length are a
    # model input (vlm: patches; audio: frames = seq/8)
    frontend: Optional[str] = None
    frontend_seq: int = 0
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: bool = True
    source: str = ""
    # ---- performance knobs (hillclimb levers, EXPERIMENTS.md §Perf) ----
    capacity_factor: float = 1.25   # MoE dispatch capacity
    attn_f32_logits: bool = True    # accumulate attention logits in f32
    ssd_chunk: int = 128            # SSD intra-chunk length
    # MoE dispatch algorithm: "grouped" (GShard-style token groups, the
    # default), "einsum" (global one-hot einsum: O(T^2) dispatch flops),
    # "scatter" (scatter-add: minimal flops but GSPMD-hostile collectives).
    # The three are the measured §Perf iterations of the MoE cells.
    moe_dispatch: str = "grouped"
    moe_group_tokens: int = 512

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k (bounded attention state)?"""
        return self.family in ("ssm", "hybrid")

    def reduced(self, n_layers: int = 2, d_model: int = 64,
                vocab: int = 512) -> "ArchConfig":
        """Same-family smoke-test config: tiny widths, few experts."""
        hd = 16
        n_heads = max(2, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv, n_heads))
        while n_heads % n_kv:       # GQA requires n_heads % n_kv == 0
            n_kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv=n_kv,
            head_dim=hd,
            d_ff=d_model * 2,
            vocab=vocab,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            window=min(self.window, 32) if self.window else None,
            enc_layers=min(self.enc_layers, 2),
            frontend_seq=min(self.frontend_seq, 8) if self.frontend_seq else 0,
            dtype="float32",
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
