"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv=3, d_ff=1536,
    vocab=49152, head_dim=64, tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
