"""mamba2-780m — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
