"""--arch <id> registry."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.arch import ArchConfig
from repro.configs import (
    phi35_moe, qwen3_moe, llama3_8b, granite_20b, smollm_135m, smollm_360m,
    recurrentgemma_9b, mamba2_780m, internvl2_1b, seamless_m4t,
)

ARCHS: Dict[str, ArchConfig] = {
    "phi3.5-moe-42b-a6.6b": phi35_moe.CONFIG,
    "qwen3-moe-235b-a22b": qwen3_moe.CONFIG,
    "llama3-8b": llama3_8b.CONFIG,
    "granite-20b": granite_20b.CONFIG,
    "smollm-135m": smollm_135m.CONFIG,
    "smollm-360m": smollm_360m.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
    "internvl2-1b": internvl2_1b.CONFIG,
    "seamless-m4t-medium": seamless_m4t.CONFIG,
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> List[str]:
    return sorted(ARCHS)
