"""granite-20b (code) — llama-arch with MQA [arXiv:2405.04324]."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_ff=24576,
    vocab=49152, head_dim=128, tie_embeddings=False,
    source="arXiv:2405.04324",
)
