"""recurrentgemma-9b — RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427]."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288,
    vocab=256000, head_dim=256, window=2048,
    pattern=("lru", "lru", "lattn"),
    tie_embeddings=True,
    source="arXiv:2402.19427",
)
