"""internvl2-1b — InternViT frontend (stub) + Qwen2-0.5B-class LM backbone
[arXiv:2404.16821].  ``input_specs`` supplies precomputed patch embeddings."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, d_ff=4864,
    vocab=151655, head_dim=64,
    frontend="vision", frontend_seq=256,
    tie_embeddings=True,
    source="arXiv:2404.16821",
)
