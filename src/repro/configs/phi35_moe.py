"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=6400,
    vocab=32064, n_experts=16, top_k=2, head_dim=128,
    tie_embeddings=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
