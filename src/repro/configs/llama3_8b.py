"""llama3-8b [arXiv:2407.21783]."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=128256, head_dim=128, tie_embeddings=False,
    source="arXiv:2407.21783",
)
