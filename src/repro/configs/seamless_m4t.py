"""seamless-m4t-medium — encoder-decoder multimodal backbone
[arXiv:2308.11596].  Audio frontend is a stub: ``input_specs`` supplies
precomputed frame embeddings at seq/8 (conformer downsampling)."""
from repro.configs.arch import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv=16, d_ff=4096,
    vocab=256206, head_dim=64,
    enc_layers=12, frontend="audio", frontend_seq=0,  # frames = seq // 8
    tie_embeddings=False,
    source="arXiv:2308.11596",
)
