"""Fault tolerance & elasticity planning for 1000+-node fleets.

What runs *in-band* in this repo:
* atomic/async checkpointing + exact data-pipeline resume
  (repro.checkpoint) — restart-from-preemption works end to end;
* elastic re-mesh on restore (checkpoints are mesh-agnostic);
* gradient compression for the slow DCN pod axis (repro.optimizer);
* **file leases** (below): expiring, atomically-acquired claim files the
  UVM sweep's lease-based cell execution (``repro.uvm.sweep``) and the
  prediction-cache training lock (``repro.uvm.predcache``) both build on.
  A lease is advisory: correctness never depends on mutual exclusion
  (cell results and prediction arrays are deterministic and written with
  atomic rename, so a benign double-execution produces identical bytes) —
  the lease exists so crashed or stalled owners are *reclaimed* instead
  of wedging the grid.

What is *planned* here (policy objects a cluster controller would drive —
they are pure logic, unit-tested, and wired into launch.train's loop):
* heartbeat-based failure detection with grace windows (the
  :class:`HeartbeatMonitor` below also drives the sweep's lease-pool
  parent loop: silent-but-alive workers are terminated so their leases
  free up via the dead-pid check),
* straggler mitigation by deadline: micro-batches of the slowest k hosts are
  re-dispatched to spares; persistent stragglers are excluded at the next
  elastic re-mesh point,
* re-mesh planning: given surviving hosts, pick the largest (pod, data,
  model) mesh that preserves model-axis divisibility.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-host heartbeats; hosts silent past ``timeout_s`` are dead,
    hosts slower than ``straggler_factor`` x median step time are stragglers."""

    timeout_s: float = 60.0
    straggler_factor: float = 1.5

    def __post_init__(self) -> None:
        self.last_seen: Dict[int, float] = {}
        self.step_times: Dict[int, float] = {}

    def beat(self, host: int, step_time_s: float,
             now: Optional[float] = None) -> None:
        self.last_seen[host] = time.monotonic() if now is None else now
        # EWMA of step time
        prev = self.step_times.get(host, step_time_s)
        self.step_times[host] = 0.8 * prev + 0.2 * step_time_s

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]

    def stragglers(self) -> List[int]:
        if len(self.step_times) < 2:
            return []
        times = sorted(self.step_times.values())
        median = times[len(times) // 2]
        return [h for h, t in self.step_times.items()
                if t > self.straggler_factor * median]


# ---------------------------------------------------------------------------
# file leases: crash-reclaimable claim files
# ---------------------------------------------------------------------------

def pid_alive(pid: int) -> bool:
    """True when ``pid`` names a live process on *this* host (signal-0
    probe; EPERM counts as alive — the process exists, we just cannot
    signal it)."""
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:  # pragma: no cover - unprivileged probe
        return True
    except OSError:  # pragma: no cover - exotic platforms
        return True
    return True


def lease_doc(extra: Optional[Dict] = None) -> Dict:
    """The owner record a lease file carries: pid + host for the liveness
    check, a wall-clock timestamp for the TTL."""
    doc = {"pid": os.getpid(), "host": socket.gethostname(),
           "ts": time.time()}
    if extra:
        doc.update(extra)
    return doc


def read_lease(path: str) -> Optional[Dict]:
    """Parse a lease/lock file's owner record.  Returns None when the file
    is missing; a malformed or legacy (bare-pid) payload degrades to a
    partial record so staleness checks still work."""
    try:
        with open(path) as f:
            raw = f.read()
    except OSError:
        return None
    try:
        doc = json.loads(raw)
        if isinstance(doc, dict):
            return doc
    except ValueError:
        pass
    try:                                     # legacy: bare pid, no ts
        return {"pid": int(raw.strip()), "host": socket.gethostname(),
                "ts": None}
    except ValueError:
        return {"pid": -1, "host": None, "ts": None}   # garbage: stale


def lease_is_stale(doc: Optional[Dict], ttl_s: float,
                   now: Optional[float] = None) -> bool:
    """A lease is stale when its TTL expired, or when the owner is a dead
    process on this host (SIGKILLed workers reclaim immediately instead
    of waiting out the TTL).  Unreadable records are stale."""
    if doc is None:
        return True
    ts = doc.get("ts")
    if ts is None or not isinstance(ts, (int, float)):
        return True
    now = time.time() if now is None else now
    if now - float(ts) > ttl_s:
        return True
    if doc.get("host") == socket.gethostname():
        pid = doc.get("pid")
        if not isinstance(pid, int) or not pid_alive(pid):
            return True
    return False


def try_acquire_lease(path: str, ttl_s: float,
                      extra: Optional[Dict] = None) -> bool:
    """Atomically claim a lease file (``O_CREAT|O_EXCL``); a stale
    holder's file is removed and the claim retried once.

    The steal has a benign race: two claimants can both observe the stale
    lease, both unlink, and one re-creates — in the worst interleaving a
    *fresh* lease is unlinked and two owners run concurrently.  Lease
    consumers must therefore be idempotent (deterministic work + atomic
    result rename), which every user in this repo is; the lease bounds
    duplicated work, it does not guarantee exclusion.
    """
    for _ in range(2):
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            if not lease_is_stale(read_lease(path), ttl_s):
                return False
            try:
                os.unlink(path)
            except OSError:
                pass
            continue
        except OSError:                       # dir vanished mid-claim
            return False
        with os.fdopen(fd, "w") as f:
            json.dump(lease_doc(extra), f)
        return True
    return False


def renew_lease(path: str, extra: Optional[Dict] = None) -> None:
    """Refresh the TTL of a lease this process holds (atomic rewrite)."""
    tmp = path + f".renew.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(lease_doc(extra), f)
        os.replace(tmp, path)
    except OSError:  # pragma: no cover - dir vanished
        try:
            os.unlink(tmp)
        except OSError:
            pass


def release_lease(path: str) -> None:
    try:
        os.unlink(path)
    except OSError:
        pass


def plan_backup_dispatch(stragglers: List[int], spares: List[int]
                         ) -> Dict[int, int]:
    """Deadline-based straggler mitigation: map each straggler's micro-batch
    onto a spare host (first-finisher wins, loser's result is dropped)."""
    return {s: spare for s, spare in zip(stragglers, spares)}


def plan_remesh(n_hosts_alive: int, chips_per_host: int,
                model_parallel: int,
                pods: Tuple[int, ...] = (4, 2, 1)) -> Optional[Tuple[int, int, int]]:
    """Pick the largest (pod, data, model) mesh the surviving chips support,
    preserving the model axis (weight layouts stay valid on restore)."""
    chips = n_hosts_alive * chips_per_host
    for pod in pods:
        if chips % pod:
            continue
        per_pod = chips // pod
        if per_pod % model_parallel:
            continue
        data = per_pod // model_parallel
        if data >= 1:
            return (pod, data, model_parallel)
    return None
