"""Fault tolerance & elasticity planning for 1000+-node fleets.

What runs *in-band* in this repo:
* atomic/async checkpointing + exact data-pipeline resume
  (repro.checkpoint) — restart-from-preemption works end to end;
* elastic re-mesh on restore (checkpoints are mesh-agnostic);
* gradient compression for the slow DCN pod axis (repro.optimizer).

What is *planned* here (policy objects a cluster controller would drive —
they are pure logic, unit-tested, and wired into launch.train's loop):
* heartbeat-based failure detection with grace windows,
* straggler mitigation by deadline: micro-batches of the slowest k hosts are
  re-dispatched to spares; persistent stragglers are excluded at the next
  elastic re-mesh point,
* re-mesh planning: given surviving hosts, pick the largest (pod, data,
  model) mesh that preserves model-axis divisibility.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-host heartbeats; hosts silent past ``timeout_s`` are dead,
    hosts slower than ``straggler_factor`` x median step time are stragglers."""

    timeout_s: float = 60.0
    straggler_factor: float = 1.5

    def __post_init__(self) -> None:
        self.last_seen: Dict[int, float] = {}
        self.step_times: Dict[int, float] = {}

    def beat(self, host: int, step_time_s: float,
             now: Optional[float] = None) -> None:
        self.last_seen[host] = time.monotonic() if now is None else now
        # EWMA of step time
        prev = self.step_times.get(host, step_time_s)
        self.step_times[host] = 0.8 * prev + 0.2 * step_time_s

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]

    def stragglers(self) -> List[int]:
        if len(self.step_times) < 2:
            return []
        times = sorted(self.step_times.values())
        median = times[len(times) // 2]
        return [h for h, t in self.step_times.items()
                if t > self.straggler_factor * median]


def plan_backup_dispatch(stragglers: List[int], spares: List[int]
                         ) -> Dict[int, int]:
    """Deadline-based straggler mitigation: map each straggler's micro-batch
    onto a spare host (first-finisher wins, loser's result is dropped)."""
    return {s: spare for s, spare in zip(stragglers, spares)}


def plan_remesh(n_hosts_alive: int, chips_per_host: int,
                model_parallel: int,
                pods: Tuple[int, ...] = (4, 2, 1)) -> Optional[Tuple[int, int, int]]:
    """Pick the largest (pod, data, model) mesh the surviving chips support,
    preserving the model axis (weight layouts stay valid on restore)."""
    chips = n_hosts_alive * chips_per_host
    for pod in pods:
        if chips % pod:
            continue
        per_pod = chips // pod
        if per_pod % model_parallel:
            continue
        data = per_pod // model_parallel
        if data >= 1:
            return (pod, data, model_parallel)
    return None
