"""Sharding rules: logical parameter/activation layouts -> PartitionSpecs.

Mesh axes:
* ``pod``   — pure data parallelism across ICI-disconnected pods (DCN).
* ``data``  — intra-pod data parallelism (and ZeRO-1 optimizer sharding).
* ``model`` — tensor parallelism: attention heads, FFN hidden, MoE experts,
              vocab, SSM inner channels.

Every rule is divisibility-checked against the mesh: a dimension that does
not divide (e.g. smollm's 9 heads on a 16-way model axis) falls back to
replication for that axis — the framework logs the decision instead of
failing, which is what lets one sharding config serve 10 heterogeneous
architectures.
"""
from __future__ import annotations

import logging
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

log = logging.getLogger(__name__)

MESH_AXES = ("pod", "data", "model")


def _axis_size(mesh: Mesh, name: str) -> int:
    return int(mesh.shape[name]) if name in mesh.shape else 1


def _fit(dim: int, mesh: Mesh, axis: str) -> str | None:
    """Return the axis if dim divides its size, else None (replicate)."""
    if dim % _axis_size(mesh, axis) == 0:
        return axis
    log.info("sharding fallback: dim %d !%% %s=%d -> replicated",
             dim, axis, _axis_size(mesh, axis))
    return None


# rules: param leaf name -> function(shape, mesh) -> PartitionSpec
def _spec_for(name: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    m = "model"
    if name in ("embed",):                       # (V, d)
        return P(_fit(shape[0], mesh, m), None)
    if name in ("head",):                        # (d, V)
        return P(None, _fit(shape[1], mesh, m))
    if name == "wq":                             # (d, H, hd)
        return P(None, _fit(shape[1], mesh, m), None)
    if name in ("wk", "wv"):                     # (d, KV, hd)
        return P(None, _fit(shape[1], mesh, m), None)
    if name == "wo":                             # (H, hd, d)
        return P(_fit(shape[0], mesh, m), None, None)
    if name in ("wg", "wu"):
        if len(shape) == 3:                      # MoE experts (E, d, f)
            return P(_fit(shape[0], mesh, m), None, None)
        return P(None, _fit(shape[1], mesh, m))  # dense (d, f)
    if name == "wd":
        if len(shape) == 3:                      # (E, f, d)
            return P(_fit(shape[0], mesh, m), None, None)
        return P(_fit(shape[0], mesh, m), None)  # (f, d)
    if name == "router":                         # (d, E)
        return P(None, _fit(shape[1], mesh, m))
    if name in ("wx",):                          # ssd (d, 2*din)
        return P(None, _fit(shape[1], mesh, m))
    if name in ("wdt",):                         # (d, H)
        return P(None, _fit(shape[1], mesh, m))
    if name in ("dt_bias", "a_log"):             # (H,)
        return P(_fit(shape[0], mesh, m))
    if name in ("wbc",):                         # (d, 2N) — small, replicate
        return P(None, None)
    if name in ("w_in", "w_gate", "w_r", "w_i"):  # lru (d|dr, dr)
        return P(None, _fit(shape[1], mesh, m))
    if name in ("b_r", "b_i", "lam"):            # (dr,)
        return P(_fit(shape[0], mesh, m))
    if name in ("w_out", "wo2"):                 # (dr|din, d)
        return P(_fit(shape[0], mesh, m), None)
    # norms, biases, everything else: replicate
    return P(*([None] * len(shape)))


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    """Map a pytree of ShapeDtypeStructs (or arrays) to NamedShardings.
    Stacked layer dims (from scan-over-layers) are detected by rank: specs
    are right-aligned to the trailing dims the rule describes."""

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        # segment params carry a leading layer-stack dim; rules address the
        # block-local shape.  Detect by trying the rule on the trailing dims.
        spec = _spec_for(name, shape, mesh)
        if len(spec) < len(shape):
            spec = P(*([None] * (len(shape) - len(spec)) + list(spec)))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def _rule_rank(name: str) -> int | None:
    ranks = {
        "embed": 2, "head": 2, "wq": 3, "wk": 3, "wv": 3, "wo": 3,
        "router": 2, "wx": 2, "wdt": 2, "dt_bias": 1, "a_log": 1, "wbc": 2,
        "w_in": 2, "w_gate": 2, "w_r": 2, "w_i": 2, "b_r": 1, "b_i": 1,
        "lam": 1, "w_out": 2,
    }
    return ranks.get(name)


def param_shardings_stacked(params_shape: Any, mesh: Mesh,
                            fsdp: bool = False,
                            fsdp_min_elems: int = 1 << 20) -> Any:
    """Like param_shardings but resolves the rule on the trailing
    ``rule_rank`` dims (robust for stacked MoE/dense ambiguity).

    ``fsdp=True`` additionally shards the first still-replicated divisible
    dim of every large tensor over "data" (FSDP / ZeRO-3 weight sharding via
    GSPMD — XLA inserts the per-layer all-gathers).  Required to fit
    235B-class MoE params + moments on 16 GB/chip hardware.
    """
    d = _axis_size(mesh, "data")

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        rr = _rule_rank(name)
        if name in ("wg", "wu", "wd"):
            # disambiguate dense (2) vs moe (3) by the segment kind in path
            kinds = [str(getattr(e, "key", "")) for e in path]
            rr = 3 if any("moe" in k for k in kinds) else 2
        if rr is None or rr > len(shape):
            rr = len(shape)
        spec = list(_spec_for(name, shape[len(shape) - rr:], mesh))
        spec = [None] * (len(shape) - rr) + spec
        if fsdp and int(np.prod(shape)) >= fsdp_min_elems and d > 1:
            for i in range(len(shape) - rr, len(shape)):
                if spec[i] is None and shape[i] % d == 0 and shape[i] >= d:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_axes_for(global_batch: int, mesh: Mesh) -> Tuple[str, ...]:
    """Best batch sharding: ("pod","data") -> ("data",) -> () by
    divisibility."""
    pd = _axis_size(mesh, "pod") * _axis_size(mesh, "data")
    if global_batch % pd == 0:
        return tuple(a for a in ("pod", "data") if a in mesh.shape)
    d = _axis_size(mesh, "data")
    if global_batch % d == 0 and "data" in mesh.shape:
        return ("data",)
    return ()


def batch_shardings(batch_shape: Any, mesh: Mesh, global_batch: int) -> Any:
    axes = batch_axes_for(global_batch, mesh)
    spec_axes = axes if axes else None

    def one(leaf):
        spec = [spec_axes] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shape)


def opt_shardings(param_sharding: Any, params_shape: Any, mesh: Mesh,
                  zero1: bool = False) -> Any:
    """Optimizer-moment shardings.  With ``zero1``, moments additionally
    shard their first still-replicated, divisible dim over "data"
    (ZeRO-1-style optimizer-state partitioning)."""
    if not zero1:
        return param_sharding
    d = _axis_size(mesh, "data")

    def one(sh, leaf):
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        if "data" in spec:      # already data-sharded (e.g. FSDP weights)
            return NamedSharding(mesh, P(*spec))
        for i, (s, dim) in enumerate(zip(spec, leaf.shape)):
            if s is None and dim % d == 0 and dim >= d:
                spec[i] = "data"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, param_sharding, params_shape)


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` across jax versions.

    jax >= 0.5 wants explicit ``axis_types`` (Auto) for the sharding-in-types
    machinery; jax 0.4.x does not accept the keyword at all.
    """
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes),
                **kwargs)
        except TypeError:  # pragma: no cover - axis_types not accepted
            pass
    return jax.make_mesh(shape, axes, **kwargs)


def mesh_context(mesh):
    """``jax.sharding.set_mesh`` where available, else the plain ``with
    mesh:`` physical-mesh context (jax 0.4.x)."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def _context_mesh():
    """Mesh of the current sharding context, across jax versions.

    jax >= 0.5 exposes ``jax.sharding.get_abstract_mesh()``; on older
    releases the ``with mesh:`` context lives in the thread-resources env.
    Returns ``None`` when no mesh context is active (or none is detectable).
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            return get_abstract()
        except Exception:  # pragma: no cover - defensive
            pass
    try:  # jax < 0.5: physical mesh from the `with mesh:` context
        from jax._src import mesh as mesh_lib
        return mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - defensive
        return None


def constrain(x, *axes):
    """Activation sharding constraint by logical axes; no-op without a mesh
    context.  ``axes`` entries are mesh axis names, tuples, or None."""
    mesh = _context_mesh()
    if mesh is None or getattr(mesh, "empty", True):
        return x
    names = set(mesh.axis_names)

    def ok(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            sub = tuple(x_ for x_ in a if x_ in names)
            return sub if sub else None
        return a if a in names else None

    spec = P(*[ok(a) for a in axes])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # pragma: no cover - defensive
        return x
