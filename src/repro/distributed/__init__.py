"""Distributed runtime: mesh axes, sharding rules, activation constraints,
fault tolerance, and collective-overlap configuration."""
from repro.distributed.sharding import (
    param_shardings, batch_shardings, constrain, opt_shardings,
    MESH_AXES, batch_axes_for,
)

__all__ = ["param_shardings", "batch_shardings", "constrain",
           "opt_shardings", "MESH_AXES", "batch_axes_for"]
