"""Fault-tolerant checkpointing: sharded npz, atomic commit, async writes,
elastic restore."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
