"""Checkpoint manager built for preemptible fleets.

* **Atomic**: checkpoints are written to ``step_<n>.tmp/`` and committed via
  a single directory rename — a killed writer never corrupts the latest
  checkpoint.
* **Async**: ``save_async`` snapshots device arrays to host (blocking only on
  the copy) and writes in a background thread; the train loop never waits on
  the filesystem.
* **Elastic restore**: arrays are stored unsharded (gathered); ``restore``
  re-shards onto whatever mesh the new job runs with — N pods can restart as
  M pods.
* **Integrity**: a manifest with per-array checksums validates restores.
* **Pipeline state**: the data-iterator state dict rides along, so resume is
  exact, not approximate.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._errors: list = []

    # ------------------------------------------------------------------
    def save(self, step: int, params: Any,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Synchronous atomic save."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, params: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot to host memory now; write in the background."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), params)
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        self._q.put((step, host, extra or {}))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise RuntimeError(f"async checkpoint failures: {self._errors}")

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            try:
                self._write(*item)
            except Exception as e:  # pragma: no cover
                self._errors.append(e)
            finally:
                self._q.task_done()

    # ------------------------------------------------------------------
    def _write(self, step: int, host_params: Any,
               extra: Dict[str, Any]) -> str:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host_params)
        manifest = {"step": step, "n_leaves": len(leaves),
                    "treedef": str(treedef), "extra": extra,
                    "checksums": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            path = os.path.join(tmp, f"leaf_{i:05d}.npy")
            np.save(path, arr)
            manifest["checksums"].append(_checksum(arr))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``template``; if ``shardings`` is
        given (possibly for a *different* mesh than the writer's), arrays are
        placed with those shardings — elastic re-mesh on load."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(template)
        if len(leaves) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, template "
                f"has {len(leaves)}")
        loaded = []
        for i in range(len(leaves)):
            arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            if _checksum(arr) != manifest["checksums"][i]:
                raise IOError(f"checksum mismatch on leaf {i} (step {step})")
            loaded.append(arr)
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            loaded = [jax.device_put(a, s) for a, s in zip(loaded, sh_leaves)]
        restored = jax.tree.unflatten(treedef, loaded)
        return restored, manifest["extra"]


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]
