#!/usr/bin/env bash
# CI gate: tier-1 tests + the UVM golden-equivalence and sweep suites.
#
#   bash scripts/ci_check.sh
#
# Installs the test dependencies (hypothesis enables the property-based
# suites; without it they degrade to skips, so an offline install failure is
# tolerated but surfaced).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    echo "[ci] installing test dependencies (hypothesis, pytest)"
    python -m pip install -q "hypothesis>=6" "pytest>=7" \
        || echo "[ci] WARNING: could not install hypothesis (offline?);" \
                "property-based suites will run as skips"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[ci] tier-1: full test suite (golden/sweep/predcache gated separately)"
python -m pytest -x -q --ignore=tests/test_uvm_golden.py \
    --ignore=tests/test_sweep.py --ignore=tests/test_predcache.py

echo "[ci] golden equivalence + sweep + prediction cache"
python -m pytest -q tests/test_uvm_golden.py tests/test_sweep.py \
    tests/test_predcache.py

echo "[ci] sim_throughput smoke: engines must stay counter-identical"
python -m benchmarks.sim_throughput --n 60000 \
    --json "${TMPDIR:-/tmp}/ci_sim_throughput.json"

echo "[ci] OK"
