#!/usr/bin/env bash
# CI gate: tier-1 tests + the UVM golden-equivalence and sweep suites.
#
#   bash scripts/ci_check.sh
#
# Installs the test dependencies (hypothesis enables the property-based
# suites; without it they degrade to skips, so an offline install failure is
# tolerated but surfaced).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    echo "[ci] installing test dependencies (hypothesis, pytest)"
    python -m pip install -q "hypothesis>=6" "pytest>=7" \
        || echo "[ci] WARNING: could not install hypothesis (offline?);" \
                "property-based suites will run as skips"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "[ci] tier-1: full test suite (golden/sweep/backends/predcache/faults gated separately)"
python -m pytest -x -q --ignore=tests/test_uvm_golden.py \
    --ignore=tests/test_sweep.py --ignore=tests/test_predcache.py \
    --ignore=tests/test_backends.py --ignore=tests/test_faults.py

echo "[ci] replay backends: golden suite against numpy AND pallas lanes"
echo "[ci] (all five prefetcher families x all eviction policies),"
echo "[ci] backend contract + lane-packing property suite, cross-backend"
echo "[ci] differential fuzzer (policy axis included), sweep, scenarios,"
echo "[ci] predcache + fault plane (pallas runs in interpret mode,"
echo "[ci] CPU platform pinned)"
JAX_PLATFORMS=cpu python -m pytest -q tests/test_uvm_golden.py \
    tests/test_backends.py tests/test_differential.py \
    tests/test_scenarios.py tests/test_sweep.py tests/test_predcache.py \
    tests/test_faults.py

echo "[ci] sim_throughput smoke: engines must stay counter-identical"
# the 60k smoke is warmup-dominated, so the default wall-clock floors
# (tree >=8x, geomean >=7.5x) auto-disable below 500k accesses; operators
# can still pin floors for this machine via REPRO_SIM_MIN_TREE /
# REPRO_SIM_MIN_GEOMEAN — counter drift fails the run regardless
python -m benchmarks.sim_throughput --n 60000 \
    --json "${TMPDIR:-/tmp}/ci_sim_throughput.json"

echo "[ci] pallas lane smoke: tree/learned/oracle cells through the"
echo "[ci] multi-lane kernels (interpret mode, sub-500k so wall-clock"
echo "[ci] floors stay off; cross-backend counter drift fails the run)"
JAX_PLATFORMS=cpu python -m benchmarks.sim_throughput --n 24000 \
    --backends numpy,pallas \
    --json "${TMPDIR:-/tmp}/ci_sim_throughput_pallas.json"

echo "[ci] scenario-matrix smoke: oversub-smoke (2 benchmarks x 2 ratios"
echo "[ci] x all eviction policies, < 100k total accesses) through the"
echo "[ci] pallas lanes in interpret mode; every row must record"
echo "[ci] backend=pallas and its eviction policy"
SCN_OUT="$(mktemp -d "${TMPDIR:-/tmp}/ci_scenario_smoke.XXXXXX")"
JAX_PLATFORMS=cpu python -m repro.uvm.sweep --scenario oversub-smoke \
    --backend pallas --out "$SCN_OUT"
python - "$SCN_OUT" <<'PYEOF'
import json, sys
rows = json.load(open(sys.argv[1] + "/results.json"))["rows"]
assert len(rows) == 24, f"scenario smoke expanded {len(rows)} cells, not 24"
bad = [r for r in rows if r["backend"] != "pallas"]
assert not bad, f"{len(bad)} smoke cells fell off the pallas lanes"
policies = {r["eviction"] for r in rows}
assert policies == {"lru", "random", "hotcold"}, policies
assert all(r["scenario"] == "oversub-smoke" for r in rows)
assert all(r["pages_evicted"] > 0 for r in rows
           if r["device_frac"] == 0.5 and r["prefetcher"] == "none")
print(f"[ci] scenario smoke ok: {len(rows)} rows, policies {sorted(policies)}")
PYEOF
rm -rf "$SCN_OUT"

echo "[ci] serve-traffic smoke: serve-smoke (PagedKVStore-derived traces:"
echo "[ci] 2 serve workloads x 2 ratios x all eviction policies x"
echo "[ci] none/block) through the pallas lanes in interpret mode; every"
echo "[ci] row must record its backend, policy, and ordered latency"
echo "[ci] percentiles (decode p50/p95/p99 + TTFT p50/p95/p99)"
SRV_OUT="$(mktemp -d "${TMPDIR:-/tmp}/ci_serve_smoke.XXXXXX")"
JAX_PLATFORMS=cpu python -m repro.uvm.sweep --scenario serve-smoke \
    --backend pallas --out "$SRV_OUT"
python - "$SRV_OUT" <<'PYEOF'
import json, sys
rows = json.load(open(sys.argv[1] + "/results.json"))["rows"]
assert len(rows) == 24, f"serve smoke expanded {len(rows)} cells, not 24"
bad = [r for r in rows if r["backend"] != "pallas"]
assert not bad, f"{len(bad)} serve cells fell off the pallas lanes"
policies = {r["eviction"] for r in rows}
assert policies == {"lru", "random", "hotcold"}, policies
assert all(r["scenario"] == "serve-smoke" for r in rows)
lat = ("decode_lat_p50_us", "decode_lat_p95_us", "decode_lat_p99_us",
       "ttft_p50_us", "ttft_p95_us", "ttft_p99_us")
for r in rows:
    for f in lat:
        assert isinstance(r[f], float) and r[f] > 0.0, (f, r[f], r["bench"])
    assert (r["decode_lat_p50_us"] <= r["decode_lat_p95_us"]
            <= r["decode_lat_p99_us"]), r["bench"]
    assert r["ttft_p50_us"] <= r["ttft_p95_us"] <= r["ttft_p99_us"], r["bench"]
# lane rows must take the in-kernel step-clock path: a "side-pass" here
# means the pre-PR-8 double replay (lane kernel + full NumPy shadow pass
# per serve row) silently came back
side = [r["bench"] for r in rows if r.get("slo_source") != "kernel"]
assert not side, f"lane rows fell back to the side-pass SLO path: {side}"
print(f"[ci] serve smoke ok: {len(rows)} rows, policies {sorted(policies)}, "
      f"all SLO columns from in-kernel step clocks")
PYEOF
rm -rf "$SRV_OUT"

echo "[ci] multi-tenant smoke: mt-smoke (1 interleaved bench pair x 2"
echo "[ci] oversubscribed ratios x 3 capacity splits (shared / hard 50-50"
echo "[ci] / 40-40 + spill) x all eviction policies x none/tree) through"
echo "[ci] the pallas lanes in interpret mode; every row must record"
echo "[ci] tenants, its capacity split, both per-tenant hit rates, and"
echo "[ci] the interference slowdown vs the tenants' solo replays"
MT_OUT="$(mktemp -d "${TMPDIR:-/tmp}/ci_mt_smoke.XXXXXX")"
JAX_PLATFORMS=cpu python -m repro.uvm.sweep --scenario mt-smoke \
    --backend pallas --out "$MT_OUT"
python - "$MT_OUT" <<'PYEOF'
import json, sys
rows = json.load(open(sys.argv[1] + "/results.json"))["rows"]
assert len(rows) == 36, f"mt smoke expanded {len(rows)} cells, not 36"
bad = [r for r in rows if r["backend"] != "pallas"]
assert not bad, f"{len(bad)} mt cells fell off the pallas lanes"
policies = {r["eviction"] for r in rows}
assert policies == {"lru", "random", "hotcold"}, policies
splits = {r["capacity_split"] for r in rows}
assert splits == {"shared", "0.5/0.5", "0.4/0.4"}, splits
for r in rows:
    assert r["scenario"] == "mt-smoke"
    assert r["tenants"] == 2, r["tenants"]
    # hit rates may legitimately hit 0.0 (streaming tenant under demand
    # paging); slowdowns are ratios of positive cycle counts, never 0
    for f in ("hit_rate_t0", "hit_rate_t1"):
        assert isinstance(r[f], float) and r[f] >= 0.0, (f, r.get(f))
    for f in ("slowdown_t0", "slowdown_t1", "interference_slowdown"):
        assert isinstance(r[f], float) and r[f] > 0.0, (f, r.get(f))
    assert abs(r["interference_slowdown"]
               - max(r["slowdown_t0"], r["slowdown_t1"])) < 1e-12
# the quota must do visible work: under pressure, the hard 50/50 split
# lifts tenant 0's hit rate over shared contention for the same cell
key = lambda r: (r["device_frac"], r["eviction"], r["prefetcher"])
shared = {key(r): r for r in rows if r["capacity_split"] == "shared"}
lifted = sum(1 for r in rows if r["capacity_split"] == "0.5/0.5"
             and r["hit_rate_t0"] > shared[key(r)]["hit_rate_t0"])
assert lifted > 0, "no quota cell improved tenant 0 over shared capacity"
print(f"[ci] mt smoke ok: {len(rows)} rows, splits {sorted(splits)}, "
      f"{lifted} quota cells lifted the protected tenant")
PYEOF
rm -rf "$MT_OUT"

echo "[ci] chaos-smoke: the chaos-smoke scenario fault-free and under the"
echo "[ci] bounded kill+corrupt+raise plan (SIGKILLed drivers restarted,"
echo "[ci] torn/corrupted artifacts quarantined + regenerated); the final"
echo "[ci] grid must be byte-identical to the baseline with an empty"
echo "[ci] quarantine manifest"
CHAOS_OUT="$(mktemp -d "${TMPDIR:-/tmp}/ci_chaos_smoke.XXXXXX")"
JAX_PLATFORMS=cpu python -m repro.uvm.faults --scenario chaos-smoke \
    --backend pallas --out "$CHAOS_OUT" > "$CHAOS_OUT/report.json"
python - "$CHAOS_OUT" <<'PYEOF'
import json, sys
report = json.loads(open(sys.argv[1] + "/report.json").read()
                    .strip().splitlines()[-1])
assert report["cells"] == 8, report
assert report["faults_fired"] > 0, \
    f"chaos smoke injected no faults - the check is vacuous: {report}"
manifest = json.load(open(sys.argv[1] + "/chaos/quarantine.json"))
assert manifest["cells"] == [], manifest
print(f"[ci] chaos smoke ok: {report['cells']} cells byte-identical after "
      f"{report['faults_fired']} faults, {report['restarts']} restarts, "
      f"{report['retries']} retries")
PYEOF
rm -rf "$CHAOS_OUT"

echo "[ci] transformer-smoke: the model_family axis (simplified vs the"
echo "[ci] reference Transformer learned cells) x the adaptive eviction"
echo "[ci] pseudo-policy through the pallas lanes in interpret mode; every"
echo "[ci] row must record its model_family and the concrete policy the"
echo "[ci] adaptive selector resolved to (pinned via ADAPTIVE_selector.json)"
TF_OUT="$(mktemp -d "${TMPDIR:-/tmp}/ci_tf_smoke.XXXXXX")"
REPRO_ADAPTIVE_TABLE=ADAPTIVE_selector.json JAX_PLATFORMS=cpu \
    python -m repro.uvm.sweep --scenario transformer-smoke \
    --backend pallas --out "$TF_OUT"
python - "$TF_OUT" ADAPTIVE_selector.json <<'PYEOF'
import json, sys
rows = json.load(open(sys.argv[1] + "/results.json"))["rows"]
assert len(rows) == 4, f"transformer smoke expanded {len(rows)} cells, not 4"
bad = [r for r in rows if r["backend"] != "pallas"]
assert not bad, f"{len(bad)} transformer cells fell off the pallas lanes"
fams = {r["model_family"] for r in rows}
assert fams == {"simplified", "transformer"}, fams
# the adaptive pseudo-policy may never leak into result rows: each cell
# records the concrete policy the selector resolved to for its benchmark
leaked = [r["bench"] for r in rows if r["eviction"] == "adaptive"]
assert not leaked, f"rows recorded the adaptive literal: {leaked}"
# data-driven against the committed selector table (re-recorded via
# scripts/record_adaptive_selector.py): each bench must resolve to
# exactly its table entry
selector = json.load(open(sys.argv[2]))["selector"]
by_bench = {}
for r in rows:
    by_bench.setdefault(r["bench"], set()).add(r["eviction"])
want = {b: {selector[b]} for b in by_bench}
assert by_bench == want, f"{by_bench} != selector picks {want}"
print(f"[ci] transformer smoke ok: {len(rows)} rows, families {sorted(fams)}, "
      f"adaptive resolved " + str({b: sorted(p) for b, p in by_bench.items()}))
PYEOF
rm -rf "$TF_OUT"

echo "[ci] perf trajectory: lane_bench + benchmarks.run smoke scenarios vs"
echo "[ci] the committed BENCH_lanes.json / BENCH_sweep.json baselines"
echo "[ci] (REPRO_BENCH_TOL fractional timing slack, 0 disables the"
echo "[ci] timing gate; row-key schema drift and counter drift always fail)"
# CI boxes are noisier than the dev host the baselines were recorded on:
# default to 2x slack here unless the operator pins a tighter gate
export REPRO_BENCH_TOL="${REPRO_BENCH_TOL:-1.0}"
BENCH_TMP="$(mktemp -d "${TMPDIR:-/tmp}/ci_bench.XXXXXX")"
JAX_PLATFORMS=cpu python -m benchmarks.lane_bench \
    --emit-json "$BENCH_TMP/lanes.json"
python scripts/check_bench.py BENCH_lanes.json "$BENCH_TMP/lanes.json"
# fresh sweep-cell cache so the timings measure real replays, not resume
REPRO_SWEEP_CACHE_DIR="$BENCH_TMP/sweep_cache" JAX_PLATFORMS=cpu \
    python -m benchmarks.run --scenario serve-smoke,oversub-smoke \
    --emit-json "$BENCH_TMP/sweep.json"
python scripts/check_bench.py BENCH_sweep.json "$BENCH_TMP/sweep.json"
# multi-tenant trajectory: the per-tenant hit rates and interference
# slowdowns are counter_* fields, so any accounting drift fails exactly
# (backend-agnostic — the backends are bit-equal on mt cells)
REPRO_SWEEP_CACHE_DIR="$BENCH_TMP/mt_cache" JAX_PLATFORMS=cpu \
    python -m benchmarks.mt_bench --emit-json "$BENCH_TMP/mt.json"
python scripts/check_bench.py BENCH_mt.json "$BENCH_TMP/mt.json"

echo "[ci] predictor families: simplified-vs-Transformer accuracy benchmark"
echo "[ci] (quick smoke set, trained fresh: benchmarks/cache is gitignored)"
echo "[ci] vs the committed BENCH_families.json schema; the reference"
echo "[ci] Transformer must reach the simplified predictor's accuracy on"
echo "[ci] every smoke bench"
REPRO_BENCH_QUICK=1 JAX_PLATFORMS=cpu python -m benchmarks.family_accuracy \
    --emit-json "$BENCH_TMP/families.json"
python scripts/check_bench.py BENCH_families.json "$BENCH_TMP/families.json"
python - "$BENCH_TMP/families.json" <<'PYEOF'
import json, sys
rows = json.load(open(sys.argv[1]))["rows"]
by = {(r["bench"], r["model_family"]): r for r in rows}
benches = sorted({r["bench"] for r in rows})
for b in benches:
    tf, simp = by[(b, "transformer")], by[(b, "simplified")]
    assert tf["top1"] >= simp["top1"] - 1e-9, \
        f"transformer under the simplified bar on {b}: " \
        f"{tf['top1']:.4f} < {simp['top1']:.4f}"
print("[ci] family accuracy ok: transformer >= simplified on "
      + ",".join(benches))
PYEOF
rm -rf "$BENCH_TMP"

echo "[ci] OK"
