"""Assemble EXPERIMENTS.md: inject generated tables into the markers.

    PYTHONPATH=src python scripts/assemble_experiments.py
"""
import io
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(mod):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-m", mod], cwd=ROOT, env=env,
                         capture_output=True, text=True, timeout=3600)
    if out.returncode != 0:
        print(out.stderr[-2000:], file=sys.stderr)
        raise SystemExit(f"{mod} failed")
    return out.stdout


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    paper = run("benchmarks.summarize")
    report_path = os.path.join(ROOT, "experiments", "report.md")
    report = open(report_path).read() if os.path.exists(report_path) else ""
    # split the report: dry-run+roofline vs perf
    perf_idx = report.find("## Perf iterations")
    dry = report[:perf_idx] if perf_idx >= 0 else report
    perf = report[perf_idx:] if perf_idx >= 0 else ""

    text = text.replace("<!-- PAPER_RESULTS -->",
                        "# §Results — paper reproduction\n\n" + paper)
    text = text.replace("<!-- DRYRUN -->",
                        "# §Dry-run and §Roofline\n\n" + dry)
    text = text.replace("<!-- PERF -->", perf)
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md assembled:", len(text), "chars")


if __name__ == "__main__":
    main()
