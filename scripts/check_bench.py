"""Gate a fresh BENCH_*.json emission against its committed baseline.

    python scripts/check_bench.py BENCH_lanes.json /tmp/new_lanes.json
    REPRO_BENCH_TOL=0.75 python scripts/check_bench.py BENCH_sweep.json ...

Three rule classes, applied per row (rows are keyed by their ``name`` —
or ``suite`` for ``benchmarks.run`` docs):

* **Schema** — the set of row names and the key set of every row must
  match the baseline exactly.  Missing or extra rows/keys fail the run
  unconditionally: schema drift in a trajectory file silently breaks
  every later diff, so it is never tolerated.
* **Counters** — ``counter_*`` fields (and the exact-match fields
  ``rows``/``lanes``/``accesses``/``status``) must be identical.  Counter
  drift is a correctness bug, not a perf regression; no tolerance applies.
* **Timing** — ``seconds`` and ``*_s`` fields may regress by at most
  ``REPRO_BENCH_TOL`` (fractional slack over the baseline, default
  %(tol)s; ``0`` disables the timing gate entirely, e.g. on a host class
  the baselines were not recorded on).  Speedups always pass — rerecord
  the baseline to ratchet them in.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

DEFAULT_TOL = 0.5
EXACT_FIELDS = ("rows", "lanes", "accesses", "status")
TIMING_FIELDS = ("seconds",)


def _row_key_field(rows: List[Dict]) -> str:
    if rows and "name" in rows[0]:
        return "name"
    return "suite"


def _is_timing(field: str) -> bool:
    return field in TIMING_FIELDS or field.endswith("_s")


def check(baseline_path: str, current_path: str, tol: float) -> List[str]:
    with open(baseline_path) as f:
        base_doc = json.load(f)
    with open(current_path) as f:
        cur_doc = json.load(f)
    errors: List[str] = []
    key = _row_key_field(base_doc["rows"])
    base = {r[key]: r for r in base_doc["rows"]}
    cur = {r.get(key): r for r in cur_doc["rows"]}

    missing = sorted(set(base) - set(cur))
    extra = sorted(set(cur) - set(base))
    if missing:
        errors.append(f"missing rows (in baseline, not in emission): "
                      f"{missing}")
    if extra:
        errors.append(f"extra rows (in emission, not in baseline): {extra}")

    for name in sorted(set(base) & set(cur)):
        b, c = base[name], cur[name]
        if set(b) != set(c):
            errors.append(f"{name}: row key drift — missing "
                          f"{sorted(set(b) - set(c))}, extra "
                          f"{sorted(set(c) - set(b))}")
            continue
        for field in sorted(b):
            bv, cv = b[field], c[field]
            if field.startswith("counter_") or field in EXACT_FIELDS:
                if bv != cv:
                    errors.append(f"{name}: {field} drifted "
                                  f"{bv!r} -> {cv!r} (always fatal)")
            elif _is_timing(field) and tol > 0:
                if cv > bv * (1.0 + tol):
                    errors.append(
                        f"{name}: {field} regressed {bv:.3f}s -> "
                        f"{cv:.3f}s (> {tol:.0%} over baseline)")
    return errors


def main(argv: List[str]) -> int:
    if len(argv) != 3 or argv[1] in ("-h", "--help"):
        print(__doc__ % {"tol": DEFAULT_TOL})
        return 2
    tol = float(os.environ.get("REPRO_BENCH_TOL", str(DEFAULT_TOL)))
    errors = check(argv[1], argv[2], tol)
    tag = os.path.basename(argv[1])
    if errors:
        for e in errors:
            print(f"[check_bench] {tag}: FAIL: {e}")
        return 1
    gate = "disabled" if tol <= 0 else f"tol {tol:.0%}"
    print(f"[check_bench] {tag}: ok (timing gate {gate})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
