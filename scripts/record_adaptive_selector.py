"""Record ADAPTIVE_selector.json from the full oversub-full matrix.

    PYTHONPATH=src python scripts/record_adaptive_selector.py \
        [--out ADAPTIVE_selector.json] [--results-dir DIR] [--workers N]

Expands the ``oversub-full`` scenario minus its learned cells (training
11 predictors to record an eviction selector would dwarf the matrix
itself, and the oracle rows bound learned behavior), replays it on the
NumPy backend (resumable via ``--results-dir``), distills the rows into
the ``{bench: cheapest mean-cycles policy}`` table
(``repro.uvm.adaptive.selector_from_rows``), and writes the JSON that
``REPRO_ADAPTIVE_TABLE`` consumes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.uvm.adaptive import selector_from_rows          # noqa: E402
from repro.uvm.scenarios import expand_scenario            # noqa: E402
from repro.uvm.sweep import run_sweep                      # noqa: E402

NOTE = (
    "bench -> cheapest mean-cycles eviction policy, distilled from the "
    "full oversub-full scenario matrix (11 benchmarks x 4 capacity "
    "ratios x all policies x none/block/tree/oracle prefetchers at "
    "scale 1.0; learned cells excluded - training 11 predictors to "
    "record a selector would dwarf the matrix, and the oracle rows "
    "bound learned behavior). Consumed via REPRO_ADAPTIVE_TABLE by the "
    "adaptive pseudo-policy (repro.uvm.adaptive); the transformer-smoke "
    "CI block reads it so adaptive cells resolve to these per-benchmark "
    "picks. Rerecord with: PYTHONPATH=src python "
    "scripts/record_adaptive_selector.py"
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="ADAPTIVE_selector.json")
    ap.add_argument("--results-dir", default=None,
                    help="resumable sweep store (default: a temp dir)")
    ap.add_argument("--workers", type=int, default=1)
    args = ap.parse_args(argv)

    cells = [c for c in expand_scenario("oversub-full", backend="numpy")
             if c.prefetcher != "learned"]
    out_dir = args.results_dir or tempfile.mkdtemp(prefix="adaptive_rec_")
    print(f"[selector] {len(cells)} cells -> {out_dir}", flush=True)
    rows = run_sweep(cells, out_dir=out_dir, workers=args.workers,
                     verbose=True)
    table = selector_from_rows(rows)
    with open(args.out, "w") as f:
        json.dump({"note": NOTE, "selector": table}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
    print(f"[selector] wrote {args.out}: {table}")


if __name__ == "__main__":
    main()
