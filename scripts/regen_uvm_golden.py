#!/usr/bin/env python
"""Regenerate the UVM golden-equivalence fixtures.

Runs the *legacy* per-access simulator over the golden matrix defined in
``repro.uvm.golden`` and records its stats to ``tests/golden/uvm_golden.json``.
Only rerun this after an intentional change to the UVM timing model — the
fixtures exist to catch unintentional drift in either engine.

    PYTHONPATH=src python scripts/regen_uvm_golden.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.uvm.golden import iter_golden_cells, stats_to_dict  # noqa: E402
from repro.uvm.simulator import UVMSimulator  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "golden",
                   "uvm_golden.json")


def audit_pallas_eligibility(requests) -> None:
    """Report which (lane family, eviction policy) bucket replays each
    golden cell in-kernel.

    The golden suite pins every (family, policy) bucket's cells as ONE
    pallas lane batch
    (``tests/test_uvm_golden.py::test_pallas_lane_batch_matches_legacy``);
    this audit fails regeneration loudly if any cell stops being
    pallas-eligible — or any eviction policy loses all its eligible cells
    — so the fixtures can never quietly outgrow the kernel's equivalence
    coverage.  ``requests`` are the (cell_id, ReplayRequest) pairs main()
    already materialized.
    """
    from repro.uvm.backends.pallas_backend import lane_family
    from repro.uvm.eviction import EVICTION_POLICIES
    from repro.uvm.replay_core import get_backend

    backend = get_backend("pallas")
    buckets = {}
    declined = []
    for cell_id, req in requests:
        bucket = (lane_family(req.prefetcher), req.config.eviction)
        buckets.setdefault(bucket, []).append(cell_id)
        if not backend.can_replay(req):
            declined.append(cell_id)
    for family, policy in sorted(buckets):
        print(f"pallas lane bucket {family}/{policy}: "
              f"{len(buckets[(family, policy)])} cells")
    if declined:
        raise SystemExit(
            f"pallas backend declines golden cells {declined}; the lane "
            "equivalence batches would silently shrink — fix eligibility "
            "before regenerating")
    missing = set(EVICTION_POLICIES) - {pol for _, pol in buckets}
    if missing:
        raise SystemExit(
            f"eviction policies {sorted(missing)} have no pallas-eligible "
            "golden cells; their lane equivalence would be vacuous — add "
            "per-policy cases to repro.uvm.golden before regenerating")


def main() -> None:
    from repro.uvm.replay_core import ReplayRequest

    cells = {}
    requests = []
    for cell_id, trace, config, factory in iter_golden_cells():
        stats = UVMSimulator(config).run(trace, factory())
        cells[cell_id] = stats_to_dict(stats)
        # a fresh prefetcher for the audit — the legacy run consumed its own
        requests.append((cell_id, ReplayRequest(trace, factory(), config)))
        print(f"{cell_id}: faults={stats.faults} hits={stats.hits} "
              f"late={stats.late} cycles={stats.cycles:.1f}")
    audit_pallas_eligibility(requests)
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    doc = {
        "_regenerate": "PYTHONPATH=src python scripts/regen_uvm_golden.py",
        "_engine": "legacy UVMSimulator (reference)",
        "cells": cells,
    }
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(cells)} cells -> {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
