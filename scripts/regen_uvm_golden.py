#!/usr/bin/env python
"""Regenerate the UVM golden-equivalence fixtures.

Runs the *legacy* per-access simulator over the golden matrix defined in
``repro.uvm.golden`` and records its stats to ``tests/golden/uvm_golden.json``.
Only rerun this after an intentional change to the UVM timing model — the
fixtures exist to catch unintentional drift in either engine.

    PYTHONPATH=src python scripts/regen_uvm_golden.py
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.uvm.golden import iter_golden_cells, stats_to_dict  # noqa: E402
from repro.uvm.simulator import UVMSimulator  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "golden",
                   "uvm_golden.json")


def main() -> None:
    cells = {}
    for cell_id, trace, config, factory in iter_golden_cells():
        stats = UVMSimulator(config).run(trace, factory())
        cells[cell_id] = stats_to_dict(stats)
        print(f"{cell_id}: faults={stats.faults} hits={stats.hits} "
              f"late={stats.late} cycles={stats.cycles:.1f}")
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    doc = {
        "_regenerate": "PYTHONPATH=src python scripts/regen_uvm_golden.py",
        "_engine": "legacy UVMSimulator (reference)",
        "cells": cells,
    }
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(cells)} cells -> {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
