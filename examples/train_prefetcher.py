"""Train and compare UVM page predictors on one benchmark:
the unconstrained Transformer (paper §4) vs the revised HLSH predictor
(paper §6), reporting Table-1/Table-8-style metrics + memory footprints.

    PYTHONPATH=src python examples/train_prefetcher.py --bench NW --steps 200
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.core import (DeltaVocab, PredictorConfig, build_dataset,
                        cluster_trace, delta_convergence, init_params,
                        revised_config, train_predictor)
from repro.core.model import REVISED_FEATURES, EMB_DIMS, count_activation_elems
from repro.core.quantize import footprint_report
from repro.traces import GPUModel, generate_benchmark


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="NW")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    trace = GPUModel().run(generate_benchmark(args.bench))
    ct = cluster_trace(trace, "sm")
    vocab = DeltaVocab.build(ct)
    conv = delta_convergence(ct)
    print(f"{args.bench}: {len(trace)} requests, {vocab.n_classes} delta "
          f"classes, convergence {conv:.3f}")

    results = {}
    for name, cfg, feats in [
        ("transformer", PredictorConfig(n_classes=vocab.n_classes),
         tuple(EMB_DIMS)),
        ("revised", revised_config(vocab.n_classes, conv, quantize=True),
         REVISED_FEATURES),
    ]:
        data = build_dataset(ct, vocab, features=list(feats))
        res = train_predictor(cfg, data, steps=args.steps)
        params = init_params(cfg, jax.random.PRNGKey(0))
        bits = 4 if cfg.quantize else 32
        fp = footprint_report(params, count_activation_elems(cfg), 128,
                              bits=bits)
        results[name] = (res, fp, cfg)
        print(f"  {name:12s} top1={res.metrics['top1']:.4f} "
              f"f1={res.metrics['f1']:.4f} "
              f"params={fp['params_bytes']/1e6:.2f}MB "
              f"total={fp['total_bytes']/1e6:.2f}MB "
              f"attention={cfg.attention}")

    t, r = results["transformer"], results["revised"]
    print(f"\nrevised predictor keeps "
          f"{r[0].metrics['top1']/max(t[0].metrics['top1'],1e-9)*100:.1f}% "
          f"of top-1 accuracy at "
          f"{t[1]['total_bytes']/max(r[1]['total_bytes'],1):.0f}x less memory")


if __name__ == "__main__":
    main()
