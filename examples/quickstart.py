"""Quickstart: the paper's pipeline end to end in ~2 minutes on CPU.

1. Generate a GPU UVM memory-access trace (ATAX, Polybench).
2. Train the *revised* predictor (3 features, 1 layer, HLSH/bypass, 4-bit).
3. Drive the UVM simulator with the learned prefetcher vs the CUDA-driver
   tree prefetcher (the UVMSmart baseline).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import PredictorService
from repro.traces import GPUModel, generate_benchmark
from repro.uvm import (LearnedPrefetcher, TreePrefetcher, UVMConfig,
                       UVMSimulator)


def main() -> None:
    print("generating ATAX UVM trace ...")
    trace = GPUModel().run(generate_benchmark("ATAX"))
    print(f"  {len(trace)} GMMU requests, "
          f"{trace.working_set_pages} pages working set")

    print("training the revised predictor (paper §6) ...")
    svc = PredictorService(steps=150)
    res = svc.fit(trace)
    print(f"  top-1 {res.metrics['top1']:.3f}  f1 {res.metrics['f1']:.3f}  "
          f"delta-convergence {svc.convergence:.3f}")

    print("simulating UVM ...")
    preds = svc.predict_trace()
    cfg = UVMConfig()
    sim = UVMSimulator(cfg)
    tree = sim.run(trace, TreePrefetcher())
    ours = sim.run(trace, LearnedPrefetcher(
        preds, extra_latency_cycles=cfg.prediction_overhead_cycles))

    print(f"\n{'':16s}{'tree (UVMSmart)':>18s}{'learned (ours)':>18s}")
    for label, f in [("IPC", lambda s: f"{s.ipc:.2f}"),
                     ("page hit rate", lambda s: f"{s.hit_rate:.3f}"),
                     ("pf accuracy", lambda s: f"{s.accuracy:.3f}"),
                     ("pf coverage", lambda s: f"{s.coverage:.3f}"),
                     ("unity", lambda s: f"{s.unity:.3f}"),
                     ("PCIe MB", lambda s: f"{s.pcie_bytes/1e6:.1f}")]:
        print(f"{label:16s}{f(tree):>18s}{f(ours):>18s}")
    print(f"\nIPC vs UVMSmart: {ours.ipc/tree.ipc:.3f}x")


if __name__ == "__main__":
    main()
