"""Beyond-paper ablation: prefetching under GPU memory *oversubscription*
(the paper evaluates without oversubscription; aggressive prefetch then
risks thrashing — §2.3).  Sweeps device capacity from 2x down to 0.5x the
working set.

    PYTHONPATH=src python examples/uvm_oversubscription.py --bench Hotspot
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import PredictorService
from repro.traces import GPUModel, generate_benchmark
from repro.uvm import (LearnedPrefetcher, NoPrefetcher, TreePrefetcher,
                       UVMConfig, UVMSimulator)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="Hotspot")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    trace = GPUModel().run(generate_benchmark(args.bench))
    ws = trace.working_set_pages
    svc = PredictorService(steps=args.steps)
    svc.fit(trace)
    preds = svc.predict_trace()

    print(f"{args.bench}: working set {ws} pages")
    print(f"{'capacity':>10s} {'policy':>10s} {'ipc':>8s} {'hit':>7s} "
          f"{'evicted':>8s} {'pcie MB':>8s}")
    for frac in (2.0, 1.0, 0.75, 0.5):
        cap = int(ws * frac)
        cfg = UVMConfig(device_pages=cap)
        sim = UVMSimulator(cfg)
        for name, pf in [
            ("on-demand", NoPrefetcher()),
            ("tree", TreePrefetcher()),
            ("learned", LearnedPrefetcher(
                preds, extra_latency_cycles=cfg.prediction_overhead_cycles)),
        ]:
            st = sim.run(trace, pf)
            print(f"{frac:>9.2f}x {name:>10s} {st.ipc:8.2f} "
                  f"{st.hit_rate:7.3f} {st.pages_evicted:8d} "
                  f"{st.pcie_bytes/1e6:8.1f}")


if __name__ == "__main__":
    main()
