"""End-to-end training driver: train a zoo model on the synthetic pipeline
with checkpoint/resume, ZeRO-1 optimizer sharding and int8 gradient
compression enabled — the full fault-tolerant loop on one host.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-135m --steps 60
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) config — slow on CPU")
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "4", "--seq", "128", "--checkpoint-dir", ckpt,
            "--zero1", "--grad-compress", "int8"]
    if not args.full:
        argv.append("--reduced")
    train.main(argv)
    print(f"checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
