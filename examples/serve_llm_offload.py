"""End-to-end serving driver: decode batched requests against a model from
the zoo with the paged KV store + learned offload prefetcher (the paper's
technique as a first-class framework feature).

    PYTHONPATH=src python examples/serve_llm_offload.py --arch smollm-135m
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()
    serve.main(["--arch", args.arch, "--reduced",
                "--requests", str(args.requests),
                "--prompt-len", "64", "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
