"""Paper Table 3: prediction accuracy at different prediction distances."""
from __future__ import annotations

from benchmarks.common import print_table, train_cell

BENCHES = ["Backprop", "Srad-v2", "ATAX", "NW"]


def run():
    rows = []
    for dist in (1, 30):
        for b in BENCHES:
            r = train_cell(b, cluster="sm", distance=dist)
            rows.append({"bench": b, "distance": dist,
                         "f1": r["f1"], "top1": r["top1"]})
    return rows


def main():
    print_table("Table 3: prediction distance", run(),
                ["bench", "distance", "f1", "top1"])


if __name__ == "__main__":
    main()
