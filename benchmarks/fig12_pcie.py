"""Paper Figs 11-12: CPU-GPU interconnect usage.  Fig 11's BICG timeline is
emitted as a CSV sidecar; Fig 12 is the normalized per-benchmark usage."""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import (ALL_BENCHMARKS, CACHE_DIR, geomean,
                               print_table, uvm_cell)
from repro.uvm.metrics import pcie_gbs_timeline


def run():
    rows = []
    ratios = []
    for b in ALL_BENCHMARKS:
        tree = uvm_cell(b, "tree")
        ours = uvm_cell(b, "learned")
        ratio = ours["pcie_bytes"] / max(tree["pcie_bytes"], 1)
        ratios.append(ratio)
        rows.append({"bench": b, "pcie_U_mb": tree["pcie_bytes"] / 1e6,
                     "pcie_R_mb": ours["pcie_bytes"] / 1e6,
                     "normalized": ratio})
    rows.append({"bench": "GEOMEAN", "pcie_U_mb": float("nan"),
                 "pcie_R_mb": float("nan"), "normalized": geomean(ratios)})
    return rows


def bicg_timeline():
    """Fig 11: PCIe GB/s over time for BICG under both runtimes."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    out = os.path.join(CACHE_DIR, "fig11_bicg_timeline.csv")
    lines = ["prefetcher,cycle,gbs"]
    for pf in ("tree", "learned"):
        r = uvm_cell("BICG", pf, timeline=True)
        tl = pcie_gbs_timeline(np.asarray(r["timeline"]), core_mhz=1481.0)
        for cyc, gbs in tl[:2000]:
            lines.append(f"{pf},{cyc:.0f},{gbs:.3f}")
    with open(out, "w") as f:
        f.write("\n".join(lines))
    return out


def main():
    print_table("Fig 12: normalized PCIe usage", run(),
                ["bench", "pcie_U_mb", "pcie_R_mb", "normalized"])
    print("Fig 11 timeline ->", bicg_timeline())


if __name__ == "__main__":
    main()
