"""Beyond-paper study: prefetching under GPU memory *oversubscription*.

The paper evaluates without oversubscription (§7.1) and warns that
aggressive prefetching risks thrashing when memory is scarce (§2.3).  This
suite measures exactly that: device capacity swept from 1.5x down to 0.5x
the working set, for on-demand / tree / learned prefetching — and, per
arXiv 2204.02974, across every eviction policy (lru / random / hotcold),
since policy choice swings oversubscribed results by double digits.

CLI::

    PYTHONPATH=src python -m benchmarks.oversub_bench
    PYTHONPATH=src python -m benchmarks.oversub_bench \
        --emit-json BENCH_oversub.json          # rows carry the policy
    PYTHONPATH=src python -m benchmarks.oversub_bench \
        --scenario oversub-smoke                # registry-routed matrix

``--scenario`` routes through the declarative registry in
``repro.uvm.scenarios`` instead of the local grid, with the same sweep
engine (shared trace/prediction caches, resume, ``--workers`` via
``benchmarks.run``).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from benchmarks.common import (QUICK, _eval_cell, get_eval_trace,
                               print_table, uvm_sweep)
from repro.uvm.eviction import EVICTION_POLICIES

BENCHES = ["Hotspot", "Backprop"]
FRACTIONS = [1.5, 0.75, 0.5]
PREFETCHERS = ("none", "tree", "learned")
#: the quick pass keeps the historical single-policy grid; the full run
#: sweeps every eviction policy (3x the cells, same traces/predictions)
EVICTIONS = ("lru",) if QUICK else EVICTION_POLICIES

COLS = ["bench", "capacity_x", "eviction", "prefetcher", "hit_rate",
        "pcie_mb", "ipc_vs_tree"]


def run(evictions=EVICTIONS) -> List[Dict]:
    # one batched (bench × capacity × eviction × prefetcher) grid through
    # the sweep API
    cells, tags = [], []
    for b in BENCHES:
        ws = get_eval_trace(b).working_set_pages
        for frac in FRACTIONS:
            for ev in evictions:
                for pf in PREFETCHERS:
                    cells.append(_eval_cell(b, pf,
                                            device_pages=int(ws * frac),
                                            eviction=ev))
                    tags.append((b, frac, ev, pf))
    rows = []
    for (b, frac, ev, pf), r in zip(tags, uvm_sweep(cells)):
        rows.append({
            "bench": b, "capacity_x": frac, "eviction": ev,
            "prefetcher": pf, "backend": r.get("backend"),
            "hit_rate": r["hit_rate"],
            "pcie_mb": r["pcie_bytes"] / 1e6,
            "ipc": r["ipc"],
        })
    _normalize_ipc(rows)
    return rows


def _normalize_ipc(rows: List[Dict]) -> None:
    """Normalize IPC within (bench, fraction, eviction) to tree runtime."""
    by = {}
    for r in rows:
        by.setdefault((r["bench"], r["capacity_x"], r["eviction"]),
                      {})[r["prefetcher"]] = r
    for d in by.values():
        tree_ipc = d.get("tree", {}).get("ipc", 1.0)
        for r in d.values():
            r["ipc_vs_tree"] = r["ipc"] / max(tree_ipc, 1e-9)


def run_scenario(name: str) -> List[Dict]:
    """Replay a registry scenario (``repro.uvm.scenarios``) through the
    shared benchmark sweep caches; returns the raw sweep rows (each one
    carries ``scenario``/``eviction``/``backend`` columns)."""
    from benchmarks import common
    from repro.uvm.scenarios import expand_scenario

    cells = expand_scenario(name, engine="vectorized",
                            backend=common.SWEEP_BACKEND)
    return uvm_sweep(cells)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Oversubscription capacity x eviction-policy sweep")
    ap.add_argument("--emit-json", default=None, metavar="PATH",
                    help="write result rows (policy column included) as "
                         "JSON for BENCH_* trajectory tracking")
    ap.add_argument("--scenario", default=None,
                    help="route a named repro.uvm.scenarios matrix "
                         "through the sweep instead of the local grid")
    args = ap.parse_args(argv)

    if args.scenario:
        rows = run_scenario(args.scenario)
        print_table(f"Scenario matrix: {args.scenario}", rows,
                    ["bench", "device_frac", "eviction", "prefetcher",
                     "backend", "hit_rate", "ipc", "unity"])
    else:
        rows = run()
        print_table("Oversubscription: capacity x eviction-policy sweep "
                    "(beyond paper)", rows, COLS)
    if args.emit_json:
        # derive the policy list from the rows themselves: on the
        # --scenario path the module-level grid does not describe them
        doc = {"version": 2, "scenario": args.scenario,
               "evictions": sorted({r["eviction"] for r in rows}),
               "rows": rows}
        with open(args.emit_json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=float)
            f.write("\n")
        print(f"wrote {args.emit_json}")


if __name__ == "__main__":
    main()
