"""Beyond-paper study: prefetching under GPU memory *oversubscription*.

The paper evaluates without oversubscription (§7.1) and warns that
aggressive prefetching risks thrashing when memory is scarce (§2.3).  This
suite measures exactly that: device capacity swept from 1.5x down to 0.5x
the working set, for on-demand / tree / learned prefetching."""
from __future__ import annotations

from benchmarks.common import (_eval_cell, get_eval_trace, print_table,
                               uvm_sweep)


BENCHES = ["Hotspot", "Backprop"]
FRACTIONS = [1.5, 0.75, 0.5]
PREFETCHERS = ("none", "tree", "learned")


def run():
    # one batched (bench × capacity × prefetcher) grid through the sweep API
    cells, tags = [], []
    for b in BENCHES:
        ws = get_eval_trace(b).working_set_pages
        for frac in FRACTIONS:
            for pf in PREFETCHERS:
                cells.append(_eval_cell(b, pf, device_pages=int(ws * frac)))
                tags.append((b, frac, pf))
    rows = []
    for (b, frac, pf), r in zip(tags, uvm_sweep(cells)):
        rows.append({
            "bench": b, "capacity_x": frac, "prefetcher": pf,
            "hit_rate": r["hit_rate"],
            "pcie_mb": r["pcie_bytes"] / 1e6,
            "ipc": r["ipc"],
        })
    # normalize IPC within (bench, fraction) to the tree runtime
    by = {}
    for r in rows:
        by.setdefault((r["bench"], r["capacity_x"]), {})[r["prefetcher"]] = r
    for (bench, frac), d in by.items():
        tree_ipc = d.get("tree", {}).get("ipc", 1.0)
        for r in d.values():
            r["ipc_vs_tree"] = r["ipc"] / max(tree_ipc, 1e-9)
    return rows


def main():
    print_table("Oversubscription: capacity sweep (beyond paper)", run(),
                ["bench", "capacity_x", "prefetcher", "hit_rate", "pcie_mb",
                 "ipc_vs_tree"])


if __name__ == "__main__":
    main()
