"""Serving-traffic SLO study: request-rate sweep over PagedKVStore-derived
replay traces (beyond paper).

The paper measures throughput-style UVM metrics on HPC kernels; serving
workloads care about *tail latency*.  This suite replays the serve trace
family (``repro.offload.serve_trace``: continuous-batching decode,
multi-tenant mixes, bursty open-loop arrivals) through the UVM replay
backends, sweeping request rate (``ServeBursty@r<rate>``) against
capacity ratio, eviction policy and prefetcher, and reports
p50/p95/p99 per-decode-step latency plus TTFT for every cell — the
latency columns the sweep derives from per-step replay clocks
(``ReplayRequest.step_bounds``).

CLI::

    PYTHONPATH=src python -m benchmarks.serve_bench
    PYTHONPATH=src python -m benchmarks.serve_bench \
        --emit-json BENCH_serve.json            # SLO trajectory rows
    PYTHONPATH=src python -m benchmarks.serve_bench \
        --scenario serve-smoke                  # registry-routed matrix

``--scenario`` routes a named ``repro.uvm.scenarios`` matrix through the
same sweep engine (shared trace caches, resume, ``--workers`` via
``benchmarks.run``) instead of the local rate grid.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from benchmarks import common
from benchmarks.common import QUICK, print_table, uvm_sweep
from repro.uvm.eviction import EVICTION_POLICIES
from repro.uvm.sweep import SWEEP_VERSION, SweepCell

#: rate-independent baselines + the open-loop rate sweep
BENCHES = (["ServeDecode", "ServeBursty@r64"] if QUICK else
           ["ServeDecode", "ServeTenantMix",
            "ServeBursty@r32", "ServeBursty@r64", "ServeBursty@r128"])
RATIOS = [0.5] if QUICK else [0.75, 0.5]
EVICTIONS = ("lru",) if QUICK else EVICTION_POLICIES
PREFETCHERS = ("none", "block") if QUICK else ("none", "block", "tree")
#: decode lengths scale; arrivals don't — rate pressure is preserved
SCALE = 0.25

COLS = ["bench", "rate_rps", "capacity_x", "eviction", "prefetcher",
        "backend", "hit_rate", "decode_lat_p50_us", "decode_lat_p95_us",
        "decode_lat_p99_us", "ttft_p50_us", "ttft_p95_us", "ttft_p99_us"]


def _rate(bench: str) -> Optional[float]:
    """The open-loop request rate of a bench name, None for closed-loop."""
    from repro.offload.serve_trace import get_serve_workload
    wl = get_serve_workload(bench)
    return wl.rate_rps if wl.arrival == "open" else None


def run() -> List[Dict]:
    cells, tags = [], []
    for bench in BENCHES:
        for ratio in RATIOS:
            for ev in EVICTIONS:
                for pf in PREFETCHERS:
                    # serve traces are never window-split: the decode-step
                    # bounds behind the latency columns must stay aligned
                    # common.SWEEP_BACKEND read at call time, not import
                    # time, so run.py --backend reaches scenario suites
                    cells.append(SweepCell(
                        bench=bench, prefetcher=pf, scale=SCALE,
                        window=None, device_frac=ratio, eviction=ev,
                        engine="vectorized",
                        backend=common.SWEEP_BACKEND))
                    tags.append((bench, ratio, ev, pf))
    rows = []
    for (bench, ratio, ev, pf), r in zip(tags, uvm_sweep(cells)):
        rows.append({
            "bench": bench, "rate_rps": _rate(bench), "capacity_x": ratio,
            "eviction": ev, "prefetcher": pf, "backend": r.get("backend"),
            "hit_rate": r["hit_rate"],
            "decode_lat_p50_us": r["decode_lat_p50_us"],
            "decode_lat_p95_us": r["decode_lat_p95_us"],
            "decode_lat_p99_us": r["decode_lat_p99_us"],
            "ttft_p50_us": r["ttft_p50_us"],
            "ttft_p95_us": r["ttft_p95_us"],
            "ttft_p99_us": r["ttft_p99_us"],
        })
    return rows


def run_scenario(name: str) -> List[Dict]:
    """Replay a registry scenario through the shared benchmark sweep
    caches; returns the raw sweep rows (scenario/eviction/backend and the
    serve latency columns included)."""
    from repro.uvm.scenarios import expand_scenario

    cells = expand_scenario(name, engine="vectorized",
                            backend=common.SWEEP_BACKEND)
    return uvm_sweep(cells)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Serving-traffic SLO sweep: request rate x capacity "
                    "x eviction x prefetcher")
    ap.add_argument("--emit-json", default=None, metavar="PATH",
                    help="write result rows (latency percentile columns "
                         "included) as JSON for BENCH_* tracking")
    ap.add_argument("--scenario", default=None,
                    help="route a named repro.uvm.scenarios matrix "
                         "through the sweep instead of the local grid")
    args = ap.parse_args(argv)

    if args.scenario:
        rows = run_scenario(args.scenario)
        print_table(f"Scenario matrix: {args.scenario}", rows,
                    ["bench", "device_frac", "eviction", "prefetcher",
                     "backend", "hit_rate", "decode_lat_p99_us",
                     "ttft_p99_us"])
    else:
        rows = run()
        print_table("Serving traffic: request rate x capacity x "
                    "eviction x prefetcher (beyond paper)", rows, COLS)
    if args.emit_json:
        doc = {"version": 1, "sweep_version": SWEEP_VERSION,
               "scenario": args.scenario, "scale": SCALE, "rows": rows}
        with open(args.emit_json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=float)
            f.write("\n")
        print(f"wrote {args.emit_json}")


if __name__ == "__main__":
    main()
