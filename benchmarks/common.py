"""Shared benchmark infrastructure: cached traces, cached training cells,
cached UVM simulations."""
from __future__ import annotations

import functools
import hashlib
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import (
    DeltaVocab, PredictorConfig, PredictorService, build_dataset,
    cluster_trace, delta_convergence, revised_config, train_predictor,
)
from repro.traces import GPUModel, generate_benchmark
from repro.uvm import (
    LearnedPrefetcher, NoPrefetcher, TreePrefetcher, UVMConfig, UVMSimulator,
)

CACHE_DIR = os.path.join(os.path.dirname(__file__), "cache")
QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

ALL_BENCHMARKS = ["AddVectors", "ATAX", "Backprop", "BICG", "Hotspot", "MVT",
                  "NW", "Pathfinder", "Srad-v2", "StreamTriad", "2DCONV"]
PREDICTOR_BENCHMARKS = ["AddVectors", "ATAX", "Backprop", "BICG", "Hotspot",
                        "MVT", "NW", "Pathfinder", "Srad-v2"]

STEPS = 60 if QUICK else 150
SERVICE_STEPS = 60 if QUICK else 150


def _cache_path(key: str) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    h = hashlib.sha256(key.encode()).hexdigest()[:20]
    return os.path.join(CACHE_DIR, f"{h}.json")


def cached(key: str, fn):
    path = _cache_path(key)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    result = fn()
    result["_seconds"] = time.time() - t0
    result["_key"] = key
    with open(path, "w") as f:
        json.dump(result, f, default=float)
    return result


@functools.lru_cache(maxsize=16)
def get_trace(name: str):
    return GPUModel().run(generate_benchmark(name))


# The paper simulates a fixed instruction budget per benchmark (Table 10),
# not whole-workload completion: arrays are only partially touched within
# the window, which is exactly what exposes the tree prefetcher's
# over-fetching (its accuracy is 0.79 there, not ~1.0).  UVM evaluation
# therefore runs on the leading 60% window of each trace.
EVAL_WINDOW = 0.6


@functools.lru_cache(maxsize=16)
def get_eval_trace(name: str):
    tr, _ = get_trace(name).split(EVAL_WINDOW)
    return tr


def train_cell(bench: str, *, cluster: str = "sm", distance: int = 1,
               arch: str = "transformer", attention: str = "full",
               revised: bool = False, quantize: bool = False,
               shuffle: bool = False, features: Optional[tuple] = None,
               n_layers: int = 2, n_heads: int = 4, steps: int = None,
               drop_feature: Optional[str] = None,
               single_feature: Optional[str] = None) -> Dict:
    """Train one predictor configuration on one benchmark; cached."""
    steps = steps or STEPS
    if revised:
        # the 12-dim revised model is ~100x cheaper per step than the
        # 200-dim transformer but needs more steps to converge
        steps = max(steps, 400)
    key = json.dumps(dict(
        v=8, bench=bench, cluster=cluster, distance=distance, arch=arch,
        attention=attention, revised=revised, quantize=quantize,
        shuffle=shuffle, features=features, n_layers=n_layers,
        n_heads=n_heads, steps=steps, drop=drop_feature,
        single=single_feature), sort_keys=True)

    def compute():
        from repro.core.model import EMB_DIMS, REVISED_FEATURES
        trace = get_trace(bench)
        ct = cluster_trace(trace, cluster)
        vocab = DeltaVocab.build(ct, distance=distance)
        conv = delta_convergence(ct)
        feats = features
        if feats is None:
            feats = REVISED_FEATURES if revised else tuple(EMB_DIMS)
        if drop_feature:
            feats = tuple(f for f in feats if f != drop_feature)
        if single_feature:
            feats = (single_feature,)
        if revised:
            import dataclasses as _dc
            cfg = revised_config(vocab.n_classes, conv, quantize=quantize)
            if attention != "hlsh":
                # explicit attention override (ablations)
                cfg = _dc.replace(cfg, attention=attention)
        else:
            cfg = PredictorConfig(
                n_classes=vocab.n_classes, arch=arch, attention=attention,
                features=feats, n_layers=n_layers, n_heads=n_heads,
                quantize=quantize)
        data = build_dataset(ct, vocab, features=list(cfg.features),
                             distance=distance, shuffle_tokens=shuffle,
                             max_train=10000, max_eval=3000)
        res = train_predictor(cfg, data, steps=steps)
        return {"bench": bench, "convergence": conv,
                "n_classes": vocab.n_classes,
                "f1": res.metrics["f1"], "top1": res.metrics["top1"],
                "top10": res.metrics.get("top10"),
                "train_seconds": res.train_seconds,
                "d_model": cfg.d_model}

    return cached(key, compute)


@functools.lru_cache(maxsize=32)
def _service_predictions(bench: str, steps: int):
    trace = get_eval_trace(bench)
    svc = PredictorService(steps=steps)
    res = svc.fit(trace)
    preds = svc.predict_trace()
    return trace, preds, svc, res


def uvm_cell(bench: str, prefetcher: str, *,
             prediction_us: float = 1.0,
             device_pages: Optional[int] = None,
             timeline: bool = False) -> Dict:
    """Run the UVM simulator for (benchmark, prefetcher); cached (except when
    a timeline is requested)."""
    key = json.dumps(dict(v=8, bench=bench, pf=prefetcher,
                          us=prediction_us, dp=device_pages,
                          steps=SERVICE_STEPS), sort_keys=True)

    def compute():
        trace = get_eval_trace(bench)
        cfg = UVMConfig(prediction_overhead_us=prediction_us,
                        device_pages=device_pages)
        sim = UVMSimulator(cfg, record_timeline=timeline)
        if prefetcher == "tree":
            pf = TreePrefetcher()
        elif prefetcher == "none":
            pf = NoPrefetcher()
        elif prefetcher == "learned":
            _, preds, _, _ = _service_predictions(bench, SERVICE_STEPS)
            pf = LearnedPrefetcher(
                preds,
                extra_latency_cycles=prediction_us * cfg.cycles_per_us)
        else:
            raise ValueError(prefetcher)
        st = sim.run(trace, pf)
        out = {
            "bench": bench, "prefetcher": prefetcher,
            "ipc": st.ipc, "hit_rate": st.hit_rate,
            "accuracy": st.accuracy, "coverage": st.coverage,
            "unity": st.unity, "pcie_bytes": st.pcie_bytes,
            "cycles": st.cycles, "faults": st.faults, "late": st.late,
            "n_accesses": st.n_accesses,
            "simulated_instructions": st.n_instructions,
        }
        if timeline and st.timeline is not None:
            out["timeline"] = st.timeline.tolist()
        return out

    if timeline:
        return compute()
    return cached(key, compute)


def geomean(xs: List[float]) -> float:
    xs = np.asarray(xs, dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))


def print_table(title: str, rows: List[Dict], cols: List[str]) -> None:
    print(f"\n== {title} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)
