"""Shared benchmark infrastructure: cached traces, cached training cells,
cached UVM simulations."""
from __future__ import annotations

import functools
import hashlib
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import (
    DeltaVocab, PredictorConfig, build_dataset, cluster_trace,
    delta_convergence, revised_config, train_predictor,
)
from repro.traces import GPUModel, generate_benchmark
from repro.uvm import LearnedPrefetcher, UVMConfig
from repro.uvm.sweep import (SWEEP_VERSION, SweepCell, run_sweep,
                             simulate_cell)

CACHE_DIR = os.path.join(os.path.dirname(__file__), "cache")
# one trace/prediction cache for every suite: sweep workers and in-process
# uvm_cell paths hit the same content-addressed prediction arrays, so a
# benchmark's predictor trains exactly once per (trace, model) pair across
# the whole `benchmarks.run` session (and across sessions).
# REPRO_SWEEP_CACHE_DIR redirects the sweep-cell store — the perf gate
# points it at a throwaway dir so timed runs measure real work, never
# resume hits
SWEEP_DIR = os.environ.get("REPRO_SWEEP_CACHE_DIR",
                           os.path.join(CACHE_DIR, "sweep"))
TRACE_CACHE_DIR = os.path.join(SWEEP_DIR, "trace_cache")
QUICK = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"

# process fan-out for every sweep cell, learned included: each worker
# imports jax and either trains a benchmark's predictor or reuses it from
# the shared prediction cache.  Two in-flight cells sharing one cache key
# make the later worker wait on the training lock rather than retrain;
# grids order variants of the same benchmark far apart so that rarely
# costs a busy slot.  (run.py --workers overrides.)
SWEEP_WORKERS = int(os.environ.get("REPRO_SWEEP_WORKERS", "1"))

# replay backend for every UVM sweep cell (run.py --backend overrides):
# "auto" = pallas multi-lane kernels only where they compile natively
# (TPU, or REPRO_PALLAS_COMPILE=1 on other accelerators), the NumPy
# engine everywhere else; cells record the backend that actually ran in
# their rows, so fallbacks stay visible in the emitted results.
# Validated here so a typo fails at import, not mid-sweep after the
# training suites already burned their wall-clock.
SWEEP_BACKEND = os.environ.get("REPRO_SWEEP_BACKEND", "auto")
if SWEEP_BACKEND not in ("auto", "numpy", "pallas"):
    raise ValueError(
        f"REPRO_SWEEP_BACKEND={SWEEP_BACKEND!r}: choose auto, numpy or "
        "pallas")

ALL_BENCHMARKS = ["AddVectors", "ATAX", "Backprop", "BICG", "Hotspot", "MVT",
                  "NW", "Pathfinder", "Srad-v2", "StreamTriad", "2DCONV"]
PREDICTOR_BENCHMARKS = ["AddVectors", "ATAX", "Backprop", "BICG", "Hotspot",
                        "MVT", "NW", "Pathfinder", "Srad-v2"]

STEPS = 60 if QUICK else 150
SERVICE_STEPS = 60 if QUICK else 150


def _cache_path(key: str) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    h = hashlib.sha256(key.encode()).hexdigest()[:20]
    return os.path.join(CACHE_DIR, f"{h}.json")


def cached(key: str, fn):
    path = _cache_path(key)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    result = fn()
    result["_seconds"] = time.time() - t0
    result["_key"] = key
    with open(path, "w") as f:
        json.dump(result, f, default=float)
    return result


@functools.lru_cache(maxsize=16)
def get_trace(name: str):
    return GPUModel().run(generate_benchmark(name))


# The paper simulates a fixed instruction budget per benchmark (Table 10),
# not whole-workload completion: arrays are only partially touched within
# the window, which is exactly what exposes the tree prefetcher's
# over-fetching (its accuracy is 0.79 there, not ~1.0).  UVM evaluation
# therefore runs on the leading 60% window of each trace.
EVAL_WINDOW = 0.6


@functools.lru_cache(maxsize=16)
def get_eval_trace(name: str):
    tr, _ = get_trace(name).split(EVAL_WINDOW)
    return tr


def train_cell(bench: str, *, cluster: str = "sm", distance: int = 1,
               arch: str = "transformer", attention: str = "full",
               revised: bool = False, quantize: bool = False,
               shuffle: bool = False, features: Optional[tuple] = None,
               n_layers: int = 2, n_heads: int = 4, steps: int = None,
               drop_feature: Optional[str] = None,
               single_feature: Optional[str] = None) -> Dict:
    """Train one predictor configuration on one benchmark; cached."""
    steps = steps or STEPS
    if revised:
        # the 12-dim revised model is ~100x cheaper per step than the
        # 200-dim transformer but needs more steps to converge
        steps = max(steps, 400)
    # v bumped 8 -> 9 with the deterministic (crc32) trace seeding: cached
    # rows trained on old salted-hash traces must not be served
    key = json.dumps(dict(
        v=9, bench=bench, cluster=cluster, distance=distance, arch=arch,
        attention=attention, revised=revised, quantize=quantize,
        shuffle=shuffle, features=features, n_layers=n_layers,
        n_heads=n_heads, steps=steps, drop=drop_feature,
        single=single_feature), sort_keys=True)

    def compute():
        from repro.core.model import EMB_DIMS, REVISED_FEATURES
        trace = get_trace(bench)
        ct = cluster_trace(trace, cluster)
        vocab = DeltaVocab.build(ct, distance=distance)
        conv = delta_convergence(ct)
        feats = features
        if feats is None:
            feats = REVISED_FEATURES if revised else tuple(EMB_DIMS)
        if drop_feature:
            feats = tuple(f for f in feats if f != drop_feature)
        if single_feature:
            feats = (single_feature,)
        if revised:
            import dataclasses as _dc
            cfg = revised_config(vocab.n_classes, conv, quantize=quantize)
            if attention != "hlsh":
                # explicit attention override (ablations)
                cfg = _dc.replace(cfg, attention=attention)
        else:
            cfg = PredictorConfig(
                n_classes=vocab.n_classes, arch=arch, attention=attention,
                features=feats, n_layers=n_layers, n_heads=n_heads,
                quantize=quantize)
        data = build_dataset(ct, vocab, features=list(cfg.features),
                             distance=distance, shuffle_tokens=shuffle,
                             max_train=10000, max_eval=3000)
        res = train_predictor(cfg, data, steps=steps)
        return {"bench": bench, "convergence": conv,
                "n_classes": vocab.n_classes,
                "f1": res.metrics["f1"], "top1": res.metrics["top1"],
                "top10": res.metrics.get("top10"),
                "train_seconds": res.train_seconds,
                "d_model": cfg.d_model}

    return cached(key, compute)


@functools.lru_cache(maxsize=32)
def _service_predictions(bench: str, steps: int):
    """Predictions for one benchmark's eval trace via the content-addressed
    prediction cache — trains at most once per (trace, model) pair, shared
    with the sweep workers through ``TRACE_CACHE_DIR``."""
    from repro.uvm import predcache
    trace = get_eval_trace(bench)
    preds = predcache.get_or_train(
        trace, steps=steps,
        cache_dir=os.path.join(TRACE_CACHE_DIR, predcache.DEFAULT_SUBDIR))
    return trace, preds


def _eval_cell(bench: str, prefetcher: str, *, prediction_us: float = 1.0,
               device_pages: Optional[int] = None,
               eviction: str = "lru") -> SweepCell:
    """The sweep-grid point matching the paper's evaluation setup."""
    return SweepCell(bench=bench, prefetcher=prefetcher,
                     prediction_us=prediction_us, device_pages=device_pages,
                     eviction=eviction,
                     window=EVAL_WINDOW, engine="vectorized",
                     backend=SWEEP_BACKEND, service_steps=SERVICE_STEPS)


def _run_cell(cell: SweepCell, timeline: bool = False) -> Dict:
    """One sweep cell on the in-process trace/predictor caches.  On the
    paper's default grid point the learned prefetcher shares a single
    trained service across every prediction_us and capacity point of a
    benchmark; off-default cells train their own (sweep.make_prefetcher)."""
    default_point = (cell.scale == 1.0 and cell.seed == 0
                     and cell.window == EVAL_WINDOW)
    trace = get_eval_trace(cell.bench) if default_point else None
    pf = None
    if (cell.prefetcher == "learned" and default_point
            and cell.service_steps == SERVICE_STEPS):
        _, preds = _service_predictions(cell.bench, cell.service_steps)
        pf = LearnedPrefetcher(
            preds,
            extra_latency_cycles=(cell.prediction_us
                                  * UVMConfig().cycles_per_us))
    row = simulate_cell(cell, trace=trace, prefetcher=pf,
                        record_timeline=timeline)
    row["simulated_instructions"] = row["n_instructions"]
    return row


def _cached_cell(cell: SweepCell) -> Dict:
    # keyed on SWEEP_VERSION too, so one knob invalidates both this JSON
    # cache and the sweep-cell store after a timing-model change
    key = json.dumps(dict(v=9, sweep_v=SWEEP_VERSION, **cell.to_dict()),
                     sort_keys=True)
    return cached(key, lambda: _run_cell(cell))


def uvm_cell(bench: str, prefetcher: str, *,
             prediction_us: float = 1.0,
             device_pages: Optional[int] = None,
             timeline: bool = False) -> Dict:
    """Run one UVM cell through the sweep engine; cached (except when a
    timeline is requested)."""
    cell = _eval_cell(bench, prefetcher, prediction_us=prediction_us,
                      device_pages=device_pages)
    if timeline:
        return _run_cell(cell, timeline=True)
    return _cached_cell(cell)


def uvm_sweep(cells: List[SweepCell]) -> List[Dict]:
    """Run a (bench × prefetcher × config) grid via the sweep orchestrator.

    Every cell — learned included — fans out across ``SWEEP_WORKERS``
    processes with on-disk resume state: the prediction cache under
    ``TRACE_CACHE_DIR`` gives learned cells train-once semantics, so a
    worker either reuses an existing predictions array or trains it for
    every other cell (and future run) of the same (trace, model) pair.
    """
    # several suites share this out_dir: skip the aggregate files so
    # they never reflect just the last suite's grid
    rows = run_sweep(cells, out_dir=SWEEP_DIR, cache_dir=TRACE_CACHE_DIR,
                     workers=SWEEP_WORKERS, write_aggregate=False)
    for row in rows:
        row["simulated_instructions"] = row["n_instructions"]
    return rows


def geomean(xs: List[float]) -> float:
    xs = np.asarray(xs, dtype=np.float64)
    return float(np.exp(np.mean(np.log(np.maximum(xs, 1e-12)))))


def print_table(title: str, rows: List[Dict], cols: List[str]) -> None:
    print(f"\n== {title} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    return str(v)
