"""Paper Table 5: full attention vs the proposed HLSH attention."""
from __future__ import annotations

from benchmarks.common import print_table, train_cell

BENCHES = ["ATAX", "BICG", "NW", "Backprop"]


def run():
    rows = []
    for attn in ("full", "hlsh"):
        for b in BENCHES:
            r = train_cell(b, attention=attn, shuffle=True, distance=1)
            rows.append({"bench": b, "attention": attn,
                         "f1": r["f1"], "top1": r["top1"]})
    return rows


def main():
    print_table("Table 5: full vs HLSH attention", run(),
                ["bench", "attention", "f1", "top1"])


if __name__ == "__main__":
    main()
