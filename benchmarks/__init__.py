"""Benchmark harness: one module per paper table/figure + framework
benchmarks (kernels, offload, pipeline).  Results are cached under
``benchmarks/cache`` so reruns are incremental."""
