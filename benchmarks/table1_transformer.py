"""Paper Table 1: unconstrained Transformer UVM page prediction accuracy."""
from __future__ import annotations

from benchmarks.common import PREDICTOR_BENCHMARKS, print_table, train_cell


def run(benches=None):
    rows = []
    for b in benches or PREDICTOR_BENCHMARKS:
        r = train_cell(b, cluster="sm", distance=1)
        rows.append({"bench": b, "f1": r["f1"], "top1": r["top1"],
                     "top10": r["top10"]})
    return rows


def main():
    print_table("Table 1: Transformer-based UVM page prediction",
                run(), ["bench", "f1", "top1", "top10"])


if __name__ == "__main__":
    main()
