"""Paper Fig 9: CNN / LSTM / MLP / Transformer / revised-HLSH comparison."""
from __future__ import annotations

from benchmarks.common import print_table, train_cell

BENCHES = ["ATAX", "Backprop", "NW", "Srad-v2"]
MODELS = [("transformer", {}), ("lstm", {}), ("cnn", {}), ("mlp", {}),
          ("hlsh", {"revised": True})]


def run():
    rows = []
    for name, kw in MODELS:
        for b in BENCHES:
            arch = "transformer" if name in ("transformer", "hlsh") else name
            r = train_cell(b, arch=arch, distance=1, **kw)
            rows.append({"bench": b, "model": name,
                         "f1": r["f1"], "top1": r["top1"]})
    return rows


def main():
    print_table("Fig 9: predictor comparison", run(),
                ["bench", "model", "f1", "top1"])


if __name__ == "__main__":
    main()
