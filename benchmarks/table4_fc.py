"""Paper Table 4: Transformer vs a single FC layer (shuffled inputs).
High-convergence benchmarks (ATAX, BICG) survive the FC-only predictor;
NW / Backprop need attention."""
from __future__ import annotations

from benchmarks.common import print_table, train_cell

BENCHES = ["ATAX", "BICG", "NW", "Backprop"]


def run():
    rows = []
    for arch in ("transformer", "fc"):
        for b in BENCHES:
            r = train_cell(b, arch=arch, shuffle=True, distance=1)
            rows.append({"bench": b, "predictor": arch,
                         "f1": r["f1"], "top1": r["top1"]})
    return rows


def main():
    print_table("Table 4: Transformer vs FC (shuffled)", run(),
                ["bench", "predictor", "f1", "top1"])


if __name__ == "__main__":
    main()
