"""Beyond-paper benchmark: learned KV-block offload prefetching during
serving (the paper's technique as a framework feature, DESIGN §3.3)."""
from __future__ import annotations

from benchmarks.common import print_table
from repro.offload import OffloadPrefetcher, PagedKVStore
from repro.offload.paged_store import BLOCK_TOKENS


def _run(n_requests: int, gen: int, capacity: int, prefetch: bool,
         evict: str = "lru"):
    store = PagedKVStore(n_requests=n_requests, max_len=4096,
                         hbm_capacity_blocks=capacity, evict=evict)
    pf = OffloadPrefetcher(store) if prefetch else None
    start = 512
    for step in range(gen):
        pos = start + step
        if pf is not None:
            pf.step(pos)
        store.on_decode_step(pos)
    return store.stats()


def run():
    rows = []
    for cap_frac, cap in (("tight", 64), ("roomy", 160)):
        for evict in ("lru", "pin"):
            for prefetch in (False, True):
                st = _run(n_requests=8, gen=256, capacity=cap,
                          prefetch=prefetch, evict=evict)
                rows.append({"capacity": f"{cap}blk({cap_frac})",
                             "evict": evict, "prefetch": prefetch,
                             "hit_rate": st["hit_rate"],
                             "prefetch_acc": st["prefetch_accuracy"],
                             "host_mb": st["host_bytes"] / 1e6})
    return rows


def main():
    print_table("Offload: learned KV-block prefetch (serving)", run(),
                ["capacity", "evict", "prefetch", "hit_rate",
                 "prefetch_acc", "host_mb"])


if __name__ == "__main__":
    main()
