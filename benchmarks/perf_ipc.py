"""Paper §7.4 headline: IPC improvement of our solution vs UVMSmart
(geomean over the 11 benchmarks), plus hit-rate and traffic summaries."""
from __future__ import annotations

from benchmarks.common import (ALL_BENCHMARKS, _eval_cell, geomean,
                               print_table, uvm_sweep)


def run():
    grid = uvm_sweep([_eval_cell(b, pf)
                      for b in ALL_BENCHMARKS for pf in ("tree", "learned")])
    by = {(r["bench"], r["prefetcher"]): r for r in grid}
    rows = []
    gains, hits_u, hits_r, traffic = [], [], [], []
    for b in ALL_BENCHMARKS:
        tree, ours = by[(b, "tree")], by[(b, "learned")]
        g = ours["ipc"] / tree["ipc"]
        gains.append(g)
        hits_u.append(tree["hit_rate"])
        hits_r.append(ours["hit_rate"])
        traffic.append(ours["pcie_bytes"] / max(tree["pcie_bytes"], 1))
        rows.append({"bench": b, "ipc_U": tree["ipc"], "ipc_R": ours["ipc"],
                     "ipc_gain": g})
    rows.append({"bench": "GEOMEAN", "ipc_U": float("nan"),
                 "ipc_R": float("nan"), "ipc_gain": geomean(gains)})
    summary = {
        "ipc_gain_geomean": geomean(gains),
        "hit_U_mean": sum(hits_u) / len(hits_u),
        "hit_R_mean": sum(hits_r) / len(hits_r),
        "traffic_ratio_geomean": geomean(traffic),
    }
    return rows, summary


def main():
    rows, summary = run()
    print_table("Performance: IPC vs UVMSmart", rows,
                ["bench", "ipc_U", "ipc_R", "ipc_gain"])
    print("summary:", summary)


if __name__ == "__main__":
    main()
