"""Paper Fig 10: IPC sensitivity to prediction overhead (1/2/5/10 us),
normalized to the UVMSmart (tree) runtime."""
from __future__ import annotations

from benchmarks.common import (ALL_BENCHMARKS, geomean, print_table,
                               uvm_cell)

LATENCIES = [1.0, 2.0, 5.0, 10.0]


def run():
    rows = []
    means = {}
    for us in LATENCIES:
        gains = []
        for b in ALL_BENCHMARKS:
            tree = uvm_cell(b, "tree")
            ours = uvm_cell(b, "learned", prediction_us=us)
            gain = ours["ipc"] / tree["ipc"]
            gains.append(gain)
            rows.append({"bench": b, "latency_us": us,
                         "ipc_normalized": gain})
        means[us] = geomean(gains)
    for us, g in means.items():
        rows.append({"bench": "GEOMEAN", "latency_us": us,
                     "ipc_normalized": g})
    return rows


def main():
    print_table("Fig 10: prediction-overhead sensitivity (IPC vs UVMSmart)",
                run(), ["bench", "latency_us", "ipc_normalized"])


if __name__ == "__main__":
    main()
