"""Paper Fig 10: IPC sensitivity to prediction overhead (1/2/5/10 us),
normalized to the UVMSmart (tree) runtime.

One batched sweep over the (benchmark × {tree, learned × latency}) grid:
the prediction cache trains each benchmark's predictor once and every
latency variant replays the same predictions array, so the whole
sensitivity grid costs one training run per benchmark."""
from __future__ import annotations

from benchmarks.common import (ALL_BENCHMARKS, _eval_cell, geomean,
                               print_table, uvm_sweep)

LATENCIES = [1.0, 2.0, 5.0, 10.0]


def run():
    cells = [_eval_cell(b, "tree") for b in ALL_BENCHMARKS]
    cells += [_eval_cell(b, "learned", prediction_us=us)
              for us in LATENCIES for b in ALL_BENCHMARKS]
    grid = uvm_sweep(cells)
    by = {(r["bench"], r["prefetcher"], r["prediction_us"]): r for r in grid}
    rows = []
    means = {}
    for us in LATENCIES:
        gains = []
        for b in ALL_BENCHMARKS:
            tree = by[(b, "tree", 1.0)]
            ours = by[(b, "learned", us)]
            gain = ours["ipc"] / tree["ipc"]
            gains.append(gain)
            rows.append({"bench": b, "latency_us": us,
                         "ipc_normalized": gain})
        means[us] = geomean(gains)
    for us, g in means.items():
        rows.append({"bench": "GEOMEAN", "latency_us": us,
                     "ipc_normalized": g})
    return rows


def main():
    print_table("Fig 10: prediction-overhead sensitivity (IPC vs UVMSmart)",
                run(), ["bench", "latency_us", "ipc_normalized"])


if __name__ == "__main__":
    main()
