"""Kernel micro-benchmarks: Pallas kernels (interpret mode on CPU — a
correctness/shape harness; wall-times are meaningful only on TPU) vs the
pure-jnp oracles, plus the oracle's XLA-CPU throughput as the runnable
number."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.kernels import ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run():
    rows = []
    rng = np.random.default_rng(0)
    # flash attention oracle throughput at serving shapes
    for (b, h, hkv, s, d) in [(1, 8, 2, 1024, 64), (1, 16, 4, 2048, 128)]:
        q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
        f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, True))
        us = _time(f, q, k, v)
        flops = 4.0 * b * h * s * s * d
        rows.append({"kernel": f"attn_b{b}h{h}s{s}d{d}", "us_per_call": us,
                     "derived_gflops": flops / us / 1e3})
    # int4 matmul oracle
    for (m, kk, n) in [(256, 1024, 1024)]:
        x = jnp.asarray(rng.normal(size=(m, kk)), jnp.float32)
        w = jnp.asarray(rng.integers(0, 256, (kk, n // 2)).astype(np.uint8))
        f = jax.jit(lambda x, w: ref.int4_matmul_ref(x, w, 0.05))
        us = _time(f, x, w)
        rows.append({"kernel": f"int4_{m}x{kk}x{n}", "us_per_call": us,
                     "derived_gflops": 2.0 * m * kk * n / us / 1e3})
    return rows


def main():
    print_table("Kernel micro-benchmarks (XLA-CPU oracle timings)", run(),
                ["kernel", "us_per_call", "derived_gflops"])


if __name__ == "__main__":
    main()
