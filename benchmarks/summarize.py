"""Paper-vs-reproduction summary: reads the benchmark cache and prints the
EXPERIMENTS.md headline tables with the paper's published numbers alongside.

    PYTHONPATH=src python -m benchmarks.summarize
"""
from __future__ import annotations

from benchmarks import (fig10_latency, perf_ipc, table1_transformer,
                        table2_clustering, table5_hlsh, table8_revised,
                        table11_unity, table67_memory)
from benchmarks.common import geomean

# paper Table 1 (f1, top1)
PAPER_T1 = {
    "AddVectors": (0.9785, 0.9767), "ATAX": (0.9904, 0.9943),
    "Backprop": (0.9175, 0.8893), "BICG": (0.9932, 0.9959),
    "Hotspot": (0.7611, 0.7676), "MVT": (0.9889, 0.9936),
    "NW": (0.97, 0.964), "Pathfinder": (0.9128, 0.9119),
    "Srad-v2": (0.9708, 0.9707),
}
# paper headline system numbers (§7)
PAPER_SYS = {"ipc_gain_geomean": 1.1089, "hit_U_mean": 0.7610,
             "hit_R_mean": 0.8902, "traffic_ratio_geomean": 0.8895,
             "unity_U": 0.85, "unity_R": 0.90}


def main() -> None:
    print("## Paper vs reproduction — predictor accuracy (Table 1)\n")
    print("| bench | paper f1 | ours f1 | paper top1 | ours top1 |")
    print("|---|---|---|---|---|")
    rows = table1_transformer.run()
    for r in rows:
        pf1, pt1 = PAPER_T1.get(r["bench"], (float("nan"),) * 2)
        print(f"| {r['bench']} | {pf1:.4f} | {r['f1']:.4f} | {pt1:.4f} | "
              f"{r['top1']:.4f} |")
    ours_t1 = geomean([r["top1"] for r in rows])
    paper_t1 = geomean([v[1] for v in PAPER_T1.values()])
    print(f"\nmean top-1: paper {paper_t1:.4f} vs ours {ours_t1:.4f}\n")

    print("## Clustering ablation (Table 2): SM-id must win\n")
    t2 = table2_clustering.run()
    print("| bench | cluster | ours top1 |")
    print("|---|---|---|")
    for r in t2:
        print(f"| {r['bench']} | {r['cluster']} | {r['top1']:.4f} |")

    print("\n## HLSH vs full attention (Table 5)\n")
    t5 = table5_hlsh.run()
    print("| bench | attention | ours top1 |")
    print("|---|---|---|")
    for r in t5:
        print(f"| {r['bench']} | {r['attention']} | {r['top1']:.4f} |")

    print("\n## Revised predictor (Table 8) + memory (Tables 6-7)\n")
    t8 = table8_revised.run()
    t67 = {r["bench"]: r for r in table67_memory.run()}
    print("| bench | top1 T | top1 R | full MB | revised MB |")
    print("|---|---|---|---|---|")
    for r in t8:
        m = t67.get(r["bench"], {})
        print(f"| {r['bench']} | {r['top1_T']:.4f} | {r['top1_R']:.4f} | "
              f"{m.get('full_total_mb', 0):.1f} | "
              f"{m.get('revised_total_mb', 0):.2f} |")

    print("\n## System headline (vs UVMSmart)\n")
    _, summary = perf_ipc.run()
    print("| metric | paper | ours |")
    print("|---|---|---|")
    print(f"| IPC gain (geomean) | {PAPER_SYS['ipc_gain_geomean']:.4f} | "
          f"{summary['ipc_gain_geomean']:.4f} |")
    print(f"| hit rate U (mean) | {PAPER_SYS['hit_U_mean']:.4f} | "
          f"{summary['hit_U_mean']:.4f} |")
    print(f"| hit rate R (mean) | {PAPER_SYS['hit_R_mean']:.4f} | "
          f"{summary['hit_R_mean']:.4f} |")
    print(f"| PCIe traffic R/U (geomean) | "
          f"{PAPER_SYS['traffic_ratio_geomean']:.4f} | "
          f"{summary['traffic_ratio_geomean']:.4f} |")
    t11 = table11_unity.run()
    for tag in ("U", "R"):
        mean = [r for r in t11 if r["bench"] == "MEAN"
                and r["prefetcher"] == tag][0]["unity"]
        print(f"| unity {tag} (mean) | {PAPER_SYS['unity_' + tag]:.2f} | "
              f"{mean:.4f} |")

    print("\n## Prediction-overhead sensitivity (Fig 10, IPC vs UVMSmart)\n")
    f10 = fig10_latency.run()
    print("| latency us | paper | ours (geomean) |")
    print("|---|---|---|")
    paper_f10 = {1.0: 1.10, 2.0: 1.06, 5.0: 1.00, 10.0: 0.90}
    for us in (1.0, 2.0, 5.0, 10.0):
        g = [r for r in f10 if r["bench"] == "GEOMEAN"
             and r["latency_us"] == us][0]["ipc_normalized"]
        print(f"| {us} | {paper_f10[us]:.2f} | {g:.4f} |")


if __name__ == "__main__":
    main()
