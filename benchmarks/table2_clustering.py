"""Paper Table 2: prediction accuracy under different trace clusterings."""
from __future__ import annotations

from benchmarks.common import print_table, train_cell

BENCHES = ["AddVectors", "NW"]
CLUSTERS = ["pc", "kernel", "sm", "cta", "warp"]


def run():
    rows = []
    for cluster in CLUSTERS:
        for b in BENCHES:
            r = train_cell(b, cluster=cluster, distance=1)
            rows.append({"bench": b, "cluster": cluster,
                         "f1": r["f1"], "top1": r["top1"]})
    return rows


def main():
    print_table("Table 2: clustering ablation", run(),
                ["bench", "cluster", "f1", "top1"])


if __name__ == "__main__":
    main()
