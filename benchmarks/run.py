"""Run every benchmark table/figure.  Prints ``name,us_per_call,derived``
summary CSV at the end (per-table CSVs above it).

    PYTHONPATH=src python -m benchmarks.run            # full
    REPRO_BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --only table1,perf
    PYTHONPATH=src python -m benchmarks.run --only table10,table11,oversub \
        --workers 8                                    # parallel UVM sweeps
    PYTHONPATH=src python -m benchmarks.run --emit-json BENCH_sweep.json
    PYTHONPATH=src python -m benchmarks.run --scenario oversub-full \
        --workers 8     # full 11-bench x ratio x eviction-policy matrix

The UVM suites (table10/table11/perf/oversub/fig10/fig12) all route through
``repro.uvm.sweep``: simulations run on the backend-pluggable replay core
(``--backend {auto,numpy,pallas}``; pallas packs compatible cells into
multi-lane kernel batches), non-learned cells fan out over ``--workers``
processes, and completed cells persist under ``benchmarks/cache/sweep/``
for resume.  Every sweep row records the backend that actually ran.
"""
from __future__ import annotations

import argparse
import json
import time
import traceback

from benchmarks import (common, family_accuracy, fig5_features,
                        fig6_convergence,
                        fig9_predictors, mt_bench, oversub_bench,
                        fig10_latency, fig12_pcie, kernels_bench,
                        offload_bench, perf_ipc, serve_bench,
                        table1_transformer,
                        table2_clustering, table3_distance, table4_fc,
                        table5_hlsh, table67_memory, table8_revised,
                        table10_hitrate, table11_unity)

SUITES = [
    ("table1", table1_transformer.main),
    # predictor-family comparison (simplified vs reference Transformer);
    # explicit empty argv: it has its own CLI like oversub_bench
    ("families", lambda: family_accuracy.main([])),
    ("table2", table2_clustering.main),
    ("table3", table3_distance.main),
    ("table4", table4_fc.main),
    ("table5", table5_hlsh.main),
    ("table67", table67_memory.main),
    ("table8", table8_revised.main),
    ("fig5", fig5_features.main),
    ("fig6", fig6_convergence.main),
    ("fig9", fig9_predictors.main),
    ("fig10", fig10_latency.main),
    ("table10", table10_hitrate.main),
    ("table11", table11_unity.main),
    ("fig12", fig12_pcie.main),
    ("perf", perf_ipc.main),
    ("kernels", kernels_bench.main),
    ("offload", offload_bench.main),
    # explicit empty argv: oversub_bench has its own CLI and must not
    # re-parse run.py's flags when invoked as a suite
    ("oversub", lambda: oversub_bench.main([])),
    # serving-traffic SLO sweep (rate x capacity x eviction x prefetcher)
    ("serve", lambda: serve_bench.main([])),
    # multi-tenant interference sweep (pair x capacity split x eviction)
    ("mt", lambda: mt_bench.main([])),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--workers", type=int, default=None,
                    help="process fan-out for the UVM sweep suites")
    ap.add_argument("--backend", default=None,
                    choices=["auto", "numpy", "pallas"],
                    help="replay backend for the UVM sweep suites "
                         "(pallas = multi-lane kernel batches; auto "
                         "picks pallas only where the lanes compile "
                         "natively — TPU, or REPRO_PALLAS_COMPILE=1 on "
                         "other accelerators; every result row records "
                         "the backend that actually ran, so per-cell "
                         "fallbacks are visible)")
    ap.add_argument("--emit-json", default=None, metavar="PATH",
                    help="write per-suite wall-clock rows as JSON so "
                         "future PRs can diff the perf trajectory")
    ap.add_argument("--scenario", default=None,
                    help="run a named repro.uvm.scenarios oversubscription "
                         "matrix (e.g. oversub-full) as the only suite, "
                         "through the shared sweep caches; honors "
                         "--workers/--backend")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.workers is not None:
        common.SWEEP_WORKERS = args.workers
    if args.backend is not None:
        common.SWEEP_BACKEND = args.backend
    suites = SUITES
    if args.scenario and args.only:
        ap.error("--scenario replaces the suite list; it cannot be "
                 "combined with --only")
    if args.scenario:
        # scenario routing replaces the suite list: each name is a
        # registry-defined (bench x ratio x eviction x prefetcher) matrix,
        # resumable; oversub_bench's own --emit-json writes the row-level
        # JSON (the per-suite wall-clock doc below is still written when
        # asked).  Comma lists run several matrices as separate suites —
        # module/argv are bound per iteration via default args so the
        # closures don't all collapse onto the last scenario.
        suites = []
        for scen in args.scenario.split(","):
            scenario_argv = ["--scenario", scen]
            if args.emit_json:
                scenario_argv += ["--emit-json",
                                  f"{args.emit_json}.{scen}.rows.json"]
            # serve-* scenarios route through serve_bench so the printed
            # table carries the SLO latency columns; mt-* through
            # mt_bench for the per-tenant/interference columns
            module = (serve_bench if scen.startswith("serve")
                      else mt_bench if scen.startswith("mt")
                      else oversub_bench)
            suites.append((f"scenario:{scen}",
                           lambda m=module, a=scenario_argv: m.main(a)))
        only = None

    t_start = time.time()
    summary = []
    failed = []
    for name, fn in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
            status = "ok"
        except Exception:
            traceback.print_exc()
            status = "FAILED"
            failed.append(name)
        summary.append((name, (time.time() - t0) * 1e6, status))

    print("\n== summary ==")
    print("name,us_per_call,derived")
    for name, us, status in summary:
        print(f"{name},{us:.0f},{status}")
    if args.emit_json:
        doc = {
            "version": 1,
            "quick": common.QUICK,
            "workers": common.SWEEP_WORKERS,
            "backend": common.SWEEP_BACKEND,
            "scenario": args.scenario,
            "total_seconds": time.time() - t_start,
            "rows": [{"suite": name, "seconds": us / 1e6, "status": status}
                     for name, us, status in summary],
        }
        with open(args.emit_json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.emit_json}")
    if failed:
        raise SystemExit(f"failed suites: {failed}")


if __name__ == "__main__":
    main()
