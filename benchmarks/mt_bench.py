"""Multi-tenant interference study: interleaved bench pairs contending
for one device (beyond paper).

The paper evaluates one benchmark at a time; shared-virtual-memory
studies (arXiv 2405.06811) show co-resident applications interfering
through the paging layer is what deployments actually see.  This suite
replays interleaved bench-pair traces (``repro.traces.interleave``)
through the UVM replay backends, sweeping capacity ratio x capacity
split (shared contention vs. hard per-tenant quotas with a spill pool) x
eviction policy x prefetcher, and reports per-tenant hit rates plus the
interference slowdown — each tenant's completion cycles in the mix over
its solo replay — for every cell.

CLI::

    PYTHONPATH=src python -m benchmarks.mt_bench
    PYTHONPATH=src python -m benchmarks.mt_bench \
        --emit-json BENCH_mt.json               # trajectory rows
    PYTHONPATH=src python -m benchmarks.mt_bench --scenario mt-smoke

Counter-class row fields (``counter_*``) are deterministic pure
functions of the cell, so ``scripts/check_bench.py`` gates them exactly:
any drift in per-tenant accounting or the interference columns fails CI.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from benchmarks import common
from benchmarks.common import QUICK, print_table, uvm_sweep
from repro.uvm.eviction import EVICTION_POLICIES
from repro.uvm.sweep import SWEEP_VERSION, SweepCell

BENCHES = ["ATAX+Pathfinder"] if QUICK else ["ATAX+Pathfinder",
                                             "BICG+Hotspot"]
RATIOS = [0.5] if QUICK else [0.75, 0.5]
EVICTIONS = ("lru",) if QUICK else EVICTION_POLICIES
SPLITS = ("shared", "0.5/0.5") if QUICK else ("shared", "0.5/0.5",
                                              "0.4/0.4")
PREFETCHERS = ("none", "tree")
SCALE = 0.25

COLS = ["bench", "capacity_x", "capacity_split", "eviction", "prefetcher",
        "backend", "hit_rate", "counter_hit_rate_t0", "counter_hit_rate_t1",
        "counter_interference_slowdown"]


def run() -> List[Dict]:
    cells, tags = [], []
    for bench in BENCHES:
        for ratio in RATIOS:
            for ev in EVICTIONS:
                for split in SPLITS:
                    for pf in PREFETCHERS:
                        # common.SWEEP_BACKEND read at call time, not
                        # import time, so run.py --backend reaches here
                        cells.append(SweepCell(
                            bench=bench, prefetcher=pf, scale=SCALE,
                            device_frac=ratio, eviction=ev,
                            capacity_split=split, engine="vectorized",
                            backend=common.SWEEP_BACKEND))
                        tags.append((bench, ratio, ev, split, pf))
    rows = []
    for (bench, ratio, ev, split, pf), r in zip(tags, uvm_sweep(cells)):
        rows.append({
            "name": f"{bench}/{ratio}/{ev}/{split}/{pf}",
            "bench": bench, "capacity_x": ratio, "capacity_split": split,
            "eviction": ev, "prefetcher": pf, "backend": r.get("backend"),
            "tenants": r["tenants"],
            "hit_rate": r["hit_rate"],
            "counter_hits": r["hits"],
            "counter_faults": r["faults"],
            "counter_pages_evicted": r["pages_evicted"],
            "counter_hit_rate_t0": r["hit_rate_t0"],
            "counter_hit_rate_t1": r["hit_rate_t1"],
            "counter_slowdown_t0": r["slowdown_t0"],
            "counter_slowdown_t1": r["slowdown_t1"],
            "counter_interference_slowdown": r["interference_slowdown"],
        })
    return rows


def run_scenario(name: str) -> List[Dict]:
    """Replay a registry scenario (e.g. ``mt-smoke`` / ``mt-full``)
    through the shared benchmark sweep caches; returns the raw sweep
    rows (per-tenant and interference columns included)."""
    from repro.uvm.scenarios import expand_scenario

    cells = expand_scenario(name, engine="vectorized",
                            backend=common.SWEEP_BACKEND)
    return uvm_sweep(cells)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Multi-tenant interference sweep: interleaved bench "
                    "pairs x capacity split x eviction x prefetcher")
    ap.add_argument("--emit-json", default=None, metavar="PATH",
                    help="write result rows (per-tenant hit rates + "
                         "interference slowdown) as JSON for BENCH_* "
                         "tracking")
    ap.add_argument("--scenario", default=None,
                    help="route a named repro.uvm.scenarios matrix "
                         "through the sweep instead of the local grid")
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.scenario:
        rows = run_scenario(args.scenario)
        print_table(f"Scenario matrix: {args.scenario}", rows,
                    ["bench", "device_frac", "capacity_split", "eviction",
                     "prefetcher", "backend", "hit_rate", "hit_rate_t0",
                     "hit_rate_t1", "interference_slowdown"])
    else:
        rows = run()
        print_table("Multi-tenant interference: pair x capacity split x "
                    "eviction x prefetcher (beyond paper)", rows, COLS)
    if args.emit_json:
        doc = {"version": 1, "sweep_version": SWEEP_VERSION,
               "scenario": args.scenario, "scale": SCALE, "quick": QUICK,
               "total_seconds": time.time() - t0, "rows": rows}
        with open(args.emit_json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=float)
            f.write("\n")
        print(f"wrote {args.emit_json}")


if __name__ == "__main__":
    main()
