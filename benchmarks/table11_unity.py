"""Paper Table 11: Unity = cbrt(accuracy * coverage * hit-rate).

Shares its sweep cells (and the train-once prediction cache) with
Table 10: on a combined run the whole grid is resumed from disk."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ALL_BENCHMARKS, _eval_cell, print_table, uvm_sweep


def run():
    grid = uvm_sweep([_eval_cell(b, pf)
                      for pf in ("tree", "learned") for b in ALL_BENCHMARKS])
    rows = []
    for r in grid:
        tag = "U" if r["prefetcher"] == "tree" else "R"
        rows.append({"bench": r["bench"], "prefetcher": tag,
                     "acc": r["accuracy"], "cov": r["coverage"],
                     "hit": r["hit_rate"], "unity": r["unity"]})
    for tag in ("U", "R"):
        us = [r["unity"] for r in rows if r["prefetcher"] == tag]
        rows.append({"bench": "MEAN", "prefetcher": tag,
                     "acc": float(np.mean([r["acc"] for r in rows
                                           if r["prefetcher"] == tag])),
                     "cov": float(np.mean([r["cov"] for r in rows
                                           if r["prefetcher"] == tag])),
                     "hit": float(np.mean([r["hit"] for r in rows
                                           if r["prefetcher"] == tag])),
                     "unity": float(np.mean(us))})
    rows.append({"bench": "Ideal", "prefetcher": "-", "acc": 1.0, "cov": 1.0,
                 "hit": 1.0, "unity": 1.0})
    return rows


def main():
    print_table("Table 11: Unity", run(),
                ["bench", "prefetcher", "acc", "cov", "hit", "unity"])


if __name__ == "__main__":
    main()
