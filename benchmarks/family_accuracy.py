"""Table 2-style predictor-family comparison (paper §5 vs §6): the
reference Transformer and the simplified (revised) predictor trained per
benchmark through the same :class:`~repro.core.service.PredictorService`
path the sweep uses, reporting page-prediction accuracy (top-1 / F1 on
the held-out split) and prediction coverage (fraction of eval-trace
accesses that get a gated prediction) side by side.

    PYTHONPATH=src python -m benchmarks.family_accuracy
    PYTHONPATH=src python -m benchmarks.family_accuracy \
        --benches ATAX,Pathfinder --emit-json /tmp/families.json

The reference Transformer sets the accuracy bar the simplified family is
engineered to match; ``scripts/ci_check.sh`` gates the emitted JSON
against ``BENCH_families.json`` and asserts the bar holds on the smoke
set.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

import numpy as np

from benchmarks import common
from benchmarks.common import cached, get_eval_trace, get_trace, print_table

FAMILIES = ("simplified", "transformer")

#: the quick/CI benchmark set: small traces where the families converge
#: within the quick step budget.  NW is the interesting cell — the
#: reference Transformer reaches full prediction coverage there while the
#: simplified predictor's confidence gate keeps its coverage at zero.
SMOKE_BENCHES = ["ATAX", "BICG", "NW"]


def family_cell(bench: str, family: str) -> Dict:
    """Train one (benchmark, family) pair via PredictorService; cached."""
    key = json.dumps(dict(v=1, suite="family_accuracy", bench=bench,
                          family=family, steps=common.STEPS),
                     sort_keys=True)

    def compute():
        from repro.core.service import PredictorService
        svc = PredictorService(model_family=family, steps=common.STEPS)
        res = svc.fit(get_trace(bench))
        preds = svc.predict_trace(get_eval_trace(bench))
        return {"name": f"{bench}/{family}", "bench": bench,
                "model_family": family,
                "top1": float(res.metrics["top1"]),
                "f1": float(res.metrics["f1"]),
                "coverage": float(np.mean(preds >= 0)),
                "train_seconds": float(res.train_seconds)}

    return cached(key, compute)


def run(benches: Optional[List[str]] = None) -> List[Dict]:
    if benches is None:
        benches = (SMOKE_BENCHES if common.QUICK
                   else common.PREDICTOR_BENCHMARKS)
    return [family_cell(b, fam) for b in benches for fam in FAMILIES]


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="Simplified-vs-Transformer predictor accuracy and "
                    "coverage per benchmark")
    ap.add_argument("--benches", default=None,
                    help="comma-separated benchmark list (default: the "
                         "smoke set under REPRO_BENCH_QUICK=1, the full "
                         "predictor suite otherwise)")
    ap.add_argument("--emit-json", default=None, metavar="PATH",
                    help="write rows as JSON for scripts/check_bench.py")
    args = ap.parse_args(argv)
    benches = args.benches.split(",") if args.benches else None
    rows = run(benches)
    cols = ["name", "bench", "model_family", "top1", "f1", "coverage",
            "train_seconds"]
    print_table("Predictor families: simplified vs reference Transformer",
                rows, cols)
    if args.emit_json:
        doc = {"version": 1, "quick": common.QUICK,
               "rows": [{c: r[c] for c in cols} for r in rows]}
        with open(args.emit_json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.emit_json}")


if __name__ == "__main__":
    main()
