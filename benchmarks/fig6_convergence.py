"""Paper Fig 6: page-delta convergence vs order sensitivity.

High-convergence benchmarks (one dominant delta) lose nothing when input
token order is shuffled — they don't need self-attention (the revised
predictor's bypass indicator); low-convergence benchmarks degrade."""
from __future__ import annotations

from benchmarks.common import print_table, train_cell

BENCHES = ["ATAX", "BICG", "MVT", "NW", "Backprop", "Srad-v2"]


def run():
    rows = []
    for b in BENCHES:
        ordered = train_cell(b, distance=1)
        shuffled = train_cell(b, distance=1, shuffle=True)
        conv = ordered["convergence"]
        rows.append({
            "bench": b, "convergence": conv,
            "top1_ordered": ordered["top1"],
            "top1_shuffled": shuffled["top1"],
            "degradation": ordered["top1"] - shuffled["top1"],
        })
    return rows


def main():
    print_table("Fig 6: delta convergence vs shuffle sensitivity", run(),
                ["bench", "convergence", "top1_ordered", "top1_shuffled",
                 "degradation"])


if __name__ == "__main__":
    main()
