"""Lane-executor throughput bench: the perf trajectory behind
``BENCH_lanes.json``.

Two measurements, both on the serve trace family:

* **Per-family lane throughput** — one 8-lane pallas batch per prefetcher
  family (demand/tree/learned/oracle) on ``ServeDecode``: cold replay
  (kernel build or executable-cache deserialize + run), warm replay
  (packed arrays + kernel run), and the numpy reference replay of the
  same lanes.  Every lane is cross-checked against the numpy backend on
  all replay counters — **any drift aborts the bench** (exit 1), the same
  contract as ``sim_throughput``.
* **End-to-end serve-smoke sweep** — a fresh ``repro.uvm.sweep
  --scenario serve-smoke --backend pallas`` subprocess with a throwaway
  results dir, measured after one warmup run so the kernel-executable
  cache (``REPRO_KERNEL_CACHE``) is hot: the steady-state wall time a CI
  host pays per sweep, and the number the ≥1.5x PR-8 acceptance
  criterion is recorded against.

CLI::

    JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.lane_bench
    JAX_PLATFORMS=cpu PYTHONPATH=src python -m benchmarks.lane_bench \
        --emit-json BENCH_lanes.json      # trajectory point
    ... --skip-e2e                        # micro rows only (fast)

``scripts/check_bench.py`` diffs a fresh emission against the committed
baseline: row names and per-row key sets must match exactly, ``counter_*``
fields must be bit-identical, and timing fields are gated by
``REPRO_BENCH_TOL`` (fractional slack; 0 disables the timing gate).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

#: replay counters cross-checked lane-by-lane against the numpy backend
COUNTER_FIELDS = ("cycles", "hits", "late", "faults", "prefetch_issued",
                  "prefetch_used", "pages_migrated", "pages_evicted",
                  "pcie_bytes")
#: one representative prefetcher per lane-kernel family
FAMILIES = (("demand", "none"), ("tree", "tree"),
            ("learned", "learned"), ("oracle", "oracle"))
N_LANES = 8
SCALE = 0.25
RATIO = 0.5


def _mk_prefetcher(name: str, trace):
    from repro.uvm.prefetchers import (BlockPrefetcher, LearnedPrefetcher,
                                       NoPrefetcher, OraclePrefetcher,
                                       TreePrefetcher)
    if name == "none":
        return NoPrefetcher()
    if name == "block":
        return BlockPrefetcher()
    if name == "tree":
        return TreePrefetcher()
    if name == "learned":
        # deterministic 30%-masked oracle predictions: exercises the
        # learned lane kernel without training a predictor
        rng = np.random.default_rng(0)
        preds = np.asarray(trace.pages, dtype=np.int64).copy()
        preds[rng.random(preds.size) < 0.3] = -1
        return LearnedPrefetcher(predicted_pages=preds)
    if name == "oracle":
        return OraclePrefetcher(np.asarray(trace.pages), lookahead=8)
    raise ValueError(name)


def _mk_requests(trace, pf_name: str, config, bounds):
    from repro.uvm.replay_core import ReplayRequest
    return [ReplayRequest(trace, _mk_prefetcher(pf_name, trace), config,
                          step_bounds=bounds) for _ in range(N_LANES)]


def family_rows() -> List[Dict]:
    """Per-family 8-lane batch timings + fatal numpy counter cross-check."""
    from repro.offload.serve_trace import build_serve_trace, trace_step_bounds
    from repro.uvm.config import UVMConfig
    from repro.uvm.replay_core import dispatch, get_backend

    trace = build_serve_trace("ServeDecode", scale=SCALE, seed=0)
    bounds = trace_step_bounds(trace)
    config = UVMConfig(device_pages=int(trace.working_set_pages * RATIO))
    backend = get_backend("pallas")
    rows = []
    for family, pf_name in FAMILIES:
        t0 = time.perf_counter()
        cold = backend.replay(_mk_requests(trace, pf_name, config, bounds))
        t1 = time.perf_counter()
        warm = backend.replay(_mk_requests(trace, pf_name, config, bounds))
        t2 = time.perf_counter()
        refs = [dispatch(r, backend="numpy")
                for r in _mk_requests(trace, pf_name, config, bounds)]
        t3 = time.perf_counter()

        row = {"name": f"family:{family}", "prefetcher": pf_name,
               "lanes": N_LANES, "accesses": len(trace) * N_LANES,
               "cold_s": t1 - t0, "warm_s": t2 - t1, "numpy_s": t3 - t2}
        for lane, (got, want) in enumerate(zip(warm, refs)):
            if got.backend != "pallas":
                raise SystemExit(f"lane_bench: {family} lane {lane} fell "
                                 f"off the pallas lanes ({got.backend})")
            for f in COUNTER_FIELDS:
                if getattr(got, f) != getattr(want, f):
                    raise SystemExit(
                        f"lane_bench: counter drift on {family} lane "
                        f"{lane}: {f} pallas={getattr(got, f)} "
                        f"numpy={getattr(want, f)}")
            if not np.array_equal(got.step_clocks, want.step_clocks):
                raise SystemExit(f"lane_bench: step-clock drift on "
                                 f"{family} lane {lane}")
        for f in ("cycles", "hits", "faults", "pcie_bytes"):
            row[f"counter_{f}"] = float(sum(getattr(s, f) for s in warm))
        rows.append(row)
        print(f"  {row['name']:16s} cold {row['cold_s']:.3f}s  "
              f"warm {row['warm_s']:.3f}s  numpy {row['numpy_s']:.3f}s")
    return rows


def _sweep_once(out_dir: str) -> float:
    """One fresh serve-smoke sweep subprocess; returns wall seconds."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    t0 = time.perf_counter()
    subprocess.run([sys.executable, "-m", "repro.uvm.sweep",
                    "--scenario", "serve-smoke", "--backend", "pallas",
                    "--out", out_dir],
                   check=True, env=env, stdout=subprocess.DEVNULL)
    return time.perf_counter() - t0


def e2e_row() -> Dict:
    """Fresh-process serve-smoke wall time, warm kernel-executable cache.

    The warmup run both hides one-time costs this bench does not track
    (filesystem cache, Python import compilation) and populates the
    kernel-executable cache, so the timed run measures the steady state a
    resumed/CI sweep actually pays."""
    with tempfile.TemporaryDirectory(prefix="lane_bench_warm_") as d:
        warmup_s = _sweep_once(d)
    with tempfile.TemporaryDirectory(prefix="lane_bench_e2e_") as d:
        seconds = _sweep_once(d)
        with open(os.path.join(d, "results.json")) as f:
            rows = json.load(f)["rows"]
    if len(rows) != 24:
        raise SystemExit(f"lane_bench: serve-smoke produced {len(rows)} "
                         "rows, not 24")
    off_lane = [r for r in rows if r["backend"] != "pallas"]
    if off_lane:
        raise SystemExit(f"lane_bench: {len(off_lane)} serve cells fell "
                         "off the pallas lanes")
    bad_src = [r for r in rows if r["slo_source"] != "kernel"]
    if bad_src:
        raise SystemExit(f"lane_bench: {len(bad_src)} lane rows took the "
                         "side-pass SLO path instead of in-kernel clocks")
    print(f"  e2e:serve-smoke  warmup {warmup_s:.3f}s  timed {seconds:.3f}s")
    return {"name": "e2e:serve-smoke", "rows": len(rows),
            "warmup_s": warmup_s, "seconds": seconds}


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="pallas lane throughput: per-family batches + "
                    "end-to-end serve-smoke sweep")
    ap.add_argument("--emit-json", default=None, metavar="PATH",
                    help="write the trajectory point (BENCH_lanes.json)")
    ap.add_argument("--skip-e2e", action="store_true",
                    help="micro rows only; skip the subprocess sweeps")
    args = ap.parse_args(argv)

    from repro.uvm.sweep import SWEEP_VERSION

    print("== lane_bench: per-family 8-lane batches (ServeDecode@0.25) ==")
    rows = family_rows()
    if not args.skip_e2e:
        print("== lane_bench: end-to-end serve-smoke sweep ==")
        rows.append(e2e_row())
    if args.emit_json:
        doc = {"version": 1, "sweep_version": SWEEP_VERSION,
               "scale": SCALE, "ratio": RATIO, "rows": rows}
        with open(args.emit_json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=float)
            f.write("\n")
        print(f"wrote {args.emit_json}")


if __name__ == "__main__":
    main()
