"""Paper Fig 5 / §5.3: single-feature prediction (which features carry the
signal — page delta, page address and PC dominate)."""
from __future__ import annotations

from benchmarks.common import print_table, train_cell

BENCHES = ["NW", "Backprop"]
FEATURES = ["dp", "paddr", "pc", "bbaddr", "cta", "warp", "sm", "kernel"]


def run():
    rows = []
    for b in BENCHES:
        for f in FEATURES:
            r = train_cell(b, single_feature=f, distance=1, steps=150)
            rows.append({"bench": b, "feature": f, "f1": r["f1"],
                         "top1": r["top1"]})
    return rows


def main():
    print_table("Fig 5: single-feature prediction", run(),
                ["bench", "feature", "f1", "top1"])


if __name__ == "__main__":
    main()
