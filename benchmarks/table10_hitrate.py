"""Paper Table 10: device-memory page hit rate, UVMSmart (U) vs ours (R)."""
from __future__ import annotations

from benchmarks.common import ALL_BENCHMARKS, print_table, uvm_cell


def run():
    rows = []
    for b in ALL_BENCHMARKS:
        tree = uvm_cell(b, "tree")
        ours = uvm_cell(b, "learned")
        rows.append({"bench": b, "hit_U": tree["hit_rate"],
                     "hit_R": ours["hit_rate"],
                     "simulated_inst": int(tree["simulated_instructions"])})
    return rows


def main():
    print_table("Table 10: page hit rate (U=UVMSmart, R=ours)", run(),
                ["bench", "hit_U", "hit_R", "simulated_inst"])


if __name__ == "__main__":
    main()
