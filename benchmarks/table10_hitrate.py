"""Paper Table 10: device-memory page hit rate, UVMSmart (U) vs ours (R).

One batched sweep over the (benchmark × {tree, learned}) grid; learned
cells fan out across workers like the rest, reusing (or seeding) the
per-benchmark predictions in the shared train-once cache."""
from __future__ import annotations

from benchmarks.common import ALL_BENCHMARKS, _eval_cell, print_table, uvm_sweep


def run():
    grid = uvm_sweep([_eval_cell(b, pf)
                      for b in ALL_BENCHMARKS for pf in ("tree", "learned")])
    by = {(r["bench"], r["prefetcher"]): r for r in grid}
    rows = []
    for b in ALL_BENCHMARKS:
        tree, ours = by[(b, "tree")], by[(b, "learned")]
        rows.append({"bench": b, "hit_U": tree["hit_rate"],
                     "hit_R": ours["hit_rate"],
                     "simulated_inst": int(tree["simulated_instructions"])})
    return rows


def main():
    print_table("Table 10: page hit rate (U=UVMSmart, R=ours)", run(),
                ["bench", "hit_U", "hit_R", "simulated_inst"])


if __name__ == "__main__":
    main()
