"""Paper Table 8: unconstrained Transformer vs the revised predictor
(3 features, 1 layer, 1 head, HLSH + bypass, 4-bit QAT)."""
from __future__ import annotations

from benchmarks.common import PREDICTOR_BENCHMARKS, print_table, train_cell


def run():
    rows = []
    for b in PREDICTOR_BENCHMARKS:
        full = train_cell(b, cluster="sm", distance=1)
        rev = train_cell(b, cluster="sm", distance=1, revised=True,
                         quantize=True)
        rows.append({"bench": b, "f1_T": full["f1"], "top1_T": full["top1"],
                     "f1_R": rev["f1"], "top1_R": rev["top1"],
                     "convergence": rev["convergence"]})
    return rows


def main():
    print_table("Table 8: Transformer (T) vs revised predictor (R)", run(),
                ["bench", "f1_T", "top1_T", "f1_R", "top1_R", "convergence"])


if __name__ == "__main__":
    main()
