"""Micro-benchmark: legacy vs vectorized UVM-engine replay throughput.

    PYTHONPATH=src python -m benchmarks.sim_throughput            # 1M accesses
    PYTHONPATH=src python -m benchmarks.sim_throughput --n 200000
    PYTHONPATH=src python -m benchmarks.sim_throughput --bench ATAX --scale 1.0
    PYTHONPATH=src python -m benchmarks.sim_throughput --json BENCH_sim.json
    PYTHONPATH=src python -m benchmarks.sim_throughput --backends numpy,pallas

``--backends numpy,pallas`` adds per-backend rows: each prefetcher cell
(tree/learned included) also replays through the pallas multi-lane
kernels and its row records ``backend == "pallas"`` — a cross-backend
counter-drift gate (interpret mode on CPU hosts, so the pallas rows are
a correctness smoke, not a speed contest; the wall-clock floors below
only ever look at the NumPy rows).

The default workload is a 1M-access DP-style trace (per "row", a block of
newly-streamed pages plus repeated sweeps over two reused result buffers —
the Pathfinder access structure that dominates the paper's reuse-heavy
benchmarks).  Every cell also cross-checks that both engines produced
identical counters, so the speedup is never bought with drift.

CI thresholds
-------------
On the default-size dp-sweep run the vectorized engine must hold its
speedups (tree >= 8x, geomean >= 7.5x — the PR 2 acceptance floor).  Both
floors are overridable via ``REPRO_SIM_MIN_TREE`` / ``REPRO_SIM_MIN_GEOMEAN``
(set 0 to disable), so slow or noisy CI machines can relax the wall-clock
gates and still fail hard on counter drift.  Small ``--n`` smoke runs
(< 500k accesses) are warmup-dominated and skip the default floors.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

import numpy as np

from repro.traces.trace import Trace, make_records
from repro.uvm import (NoPrefetcher, OraclePrefetcher, TreePrefetcher,
                       UVMConfig, UVMSimulator, VectorizedUVMSimulator)
from repro.uvm.prefetchers import LearnedPrefetcher
from repro.uvm.metrics import geomean

CHECK_FIELDS = ("hits", "late", "faults", "prefetch_issued", "prefetch_used",
                "pages_migrated", "pages_evicted", "cycles", "pcie_bytes")

#: default speedup floors for the dp-sweep run (ROADMAP acceptance); only
#: enforced at representative sizes — tiny smoke traces are dominated by
#: per-run constants, where wall-clock noise would mask real regressions
DEFAULT_MIN_TREE = 8.0
DEFAULT_MIN_GEOMEAN = 7.5
THRESHOLD_MIN_ACCESSES = 500_000


def speedup_floor(env: str, default: float, n: int) -> float:
    """Threshold from ``env`` if set, else ``default`` at representative
    trace sizes and disabled (0) below ``THRESHOLD_MIN_ACCESSES``."""
    raw = os.environ.get(env)
    if raw is not None:
        return float(raw)
    return default if n >= THRESHOLD_MIN_ACCESSES else 0.0


def dp_sweep_trace(n: int) -> Trace:
    """DP-style rows: 400 fresh streaming pages + 8 sweeps over two reused
    1000-page result buffers per row (≈98% reuse, like Pathfinder)."""
    per_row = 20_000
    rows = max(1, n // per_row)
    stream = 400
    reuse = np.tile(np.arange(2000, dtype=np.int64), 10)[:19_600]
    chunks = [np.concatenate([np.arange(r * stream, (r + 1) * stream,
                                        dtype=np.int64) + 100_000,
                              reuse])
              for r in range(rows)]
    pages = np.concatenate(chunks)[:n]
    recs = make_records(len(pages))
    recs["page"] = pages
    recs["sm"] = np.arange(len(pages)) % 4
    return Trace("dp-sweep", recs, {}, {}, len(pages) * 100)


def bench_trace(name: str, scale: float) -> Trace:
    from repro.traces import GPUModel, generate_benchmark
    return GPUModel().run(generate_benchmark(name, scale=scale))


def prefetchers(trace: Trace, cfg: UVMConfig) -> List:
    from repro.uvm.golden import perfect_preds
    pages = np.asarray(trace.pages)
    preds = perfect_preds(trace, distance=64)
    return [
        ("none", lambda: NoPrefetcher()),
        ("tree", lambda: TreePrefetcher()),
        ("learned", lambda: LearnedPrefetcher(
            preds, extra_latency_cycles=1.0 * cfg.cycles_per_us)),
        ("oracle", lambda: OraclePrefetcher(pages)),
    ]


def _stats_close(got, want) -> bool:
    """Integer counters exact; cycles/pcie_bytes to 1e-9 relative (the
    pallas lanes replay the legacy op order but a ULP of slack keeps the
    gate about *drift*, not about heroic bit-equality on every host)."""
    import math
    for f in CHECK_FIELDS:
        g, w = getattr(got, f), getattr(want, f)
        if f in ("cycles", "pcie_bytes"):
            if not math.isclose(g, w, rel_tol=1e-9, abs_tol=1e-9):
                return False
        elif g != w:
            return False
    return True


def run(trace: Trace, cfg: UVMConfig, skip_oracle: bool = False,
        backends=("numpy",)):
    n = len(trace)
    rows = []
    print(f"\n== sim_throughput: {trace.name} ({n} accesses) ==")
    print("prefetcher,backend,legacy_s,legacy_acc_per_s,backend_s,"
          "backend_acc_per_s,speedup,identical")
    for name, factory in prefetchers(trace, cfg):
        if skip_oracle and name == "oracle":
            continue
        t0 = time.time()
        s_legacy = UVMSimulator(cfg).run(trace, factory())
        t_legacy = time.time() - t0
        if "numpy" in backends:
            t0 = time.time()
            s_vec = VectorizedUVMSimulator(cfg).run(trace, factory())
            t_vec = time.time() - t0
            same = all(getattr(s_legacy, f) == getattr(s_vec, f)
                       for f in CHECK_FIELDS)
            speedup = t_legacy / max(t_vec, 1e-9)
            rows.append({"trace": trace.name, "n_accesses": n,
                         "prefetcher": name, "speedup": speedup,
                         "same": same, "backend": s_vec.backend,
                         "legacy_s": t_legacy, "vec_s": t_vec,
                         "legacy_aps": n / max(t_legacy, 1e-9),
                         "vec_aps": n / max(t_vec, 1e-9)})
            print(f"{name},{s_vec.backend},{t_legacy:.3f},"
                  f"{n / max(t_legacy, 1e-9):.0f},"
                  f"{t_vec:.3f},{n / max(t_vec, 1e-9):.0f},"
                  f"{speedup:.2f},{same}")
        if "pallas" in backends:
            # per-backend rows: the same cell through the pallas lanes
            # (interpret mode on CPU hosts — a correctness smoke, not a
            # speed contest; rows record the backend so downstream perf
            # tracking can split the trajectories).  Asking for pallas
            # asserts lane eligibility at this size: a declined cell is
            # recorded as a failed row so the drift gate can never pass
            # vacuously by silently skipping a family — run pallas
            # smokes at sizes the lanes cover (see can_replay's
            # per-family ceilings).
            from repro.uvm.replay_core import ReplayRequest, get_backend
            backend = get_backend("pallas")
            req = ReplayRequest(trace, factory(), cfg)
            if not backend.can_replay(req):
                rows.append({"trace": trace.name, "n_accesses": n,
                             "prefetcher": name, "speedup": 0.0,
                             "same": False, "backend": "pallas",
                             "declined": True,
                             "legacy_s": t_legacy, "vec_s": None,
                             "legacy_aps": n / max(t_legacy, 1e-9),
                             "vec_aps": 0.0})
                print(f"{name},pallas,{t_legacy:.3f},"
                      f"{n / max(t_legacy, 1e-9):.0f},,,"
                      f",False (cell declined by can_replay)")
                continue
            t0 = time.time()
            s_pal = backend.replay([req])[0]
            t_pal = time.time() - t0
            same_p = _stats_close(s_pal, s_legacy)
            rows.append({"trace": trace.name, "n_accesses": n,
                         "prefetcher": name,
                         "speedup": t_legacy / max(t_pal, 1e-9),
                         "same": same_p, "backend": s_pal.backend,
                         "legacy_s": t_legacy, "vec_s": t_pal,
                         "legacy_aps": n / max(t_legacy, 1e-9),
                         "vec_aps": n / max(t_pal, 1e-9)})
            print(f"{name},pallas,{t_legacy:.3f},"
                  f"{n / max(t_legacy, 1e-9):.0f},"
                  f"{t_pal:.3f},{n / max(t_pal, 1e-9):.0f},"
                  f"{t_legacy / max(t_pal, 1e-9):.2f},{same_p}")
    # interpret-mode pallas rows are correctness smokes — the wall-clock
    # floors and the geomean track the NumPy engine only
    numpy_speedups = [r["speedup"] for r in rows
                      if r["backend"] != "pallas"]
    gm = geomean(numpy_speedups) if numpy_speedups else None
    gm_str = f"{gm:.2f}x" if gm is not None else "n/a (no numpy rows)"
    print(f"GEOMEAN speedup (non-pallas): {gm_str}; all identical: "
          f"{all(r['same'] for r in rows)}")
    return rows, gm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000,
                    help="accesses in the synthetic dp-sweep trace")
    ap.add_argument("--bench", default=None,
                    help="also run a generated benchmark trace (e.g. ATAX)")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--skip-oracle", action="store_true",
                    help="oracle is slow on both engines at large n")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-prefetcher per-backend "
                         "engine-throughput rows + geomean as JSON (perf "
                         "trajectory for future PRs)")
    ap.add_argument("--backends", default="numpy",
                    help="comma list from numpy,pallas — 'pallas' adds "
                         "per-backend rows replaying each cell through "
                         "the multi-lane kernels (interpret mode on CPU; "
                         "counter drift fails the run, wall-clock floors "
                         "track the NumPy rows only)")
    args = ap.parse_args()

    backends = tuple(args.backends.split(","))
    bad = [b for b in backends if b not in ("numpy", "pallas")]
    if bad:
        ap.error(f"unknown backend(s) {','.join(bad)}; choose from "
                 "numpy,pallas")
    cfg = UVMConfig()
    all_rows = []
    geomeans = {}
    rows, gm = run(dp_sweep_trace(args.n), cfg, skip_oracle=args.skip_oracle,
                   backends=backends)
    all_rows += rows
    geomeans["dp-sweep"] = gm
    if args.bench:
        rows, gm = run(bench_trace(args.bench, args.scale), cfg,
                       skip_oracle=args.skip_oracle, backends=backends)
        all_rows += rows
        geomeans[args.bench] = gm
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"version": 2, "benchmark": "sim_throughput",
                       "rows": all_rows, "geomean_speedup": geomeans},
                      f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    if not all(r["same"] for r in all_rows):
        # any counter drift between the engines is a correctness failure,
        # not a perf data point — make CI smoke runs fail loudly
        bad = [f"{r['trace']}/{r['prefetcher']}/{r['backend']}"
               + (" (declined)" if r.get("declined") else "")
               for r in all_rows if not r["same"]]
        sys.exit("FAIL: backend rows diverged from legacy counters or "
                 "were declined: " + ", ".join(bad))

    # wall-clock floors (dp-sweep run only; env-overridable so slow CI
    # machines fail on counter drift above, not on scheduling noise here)
    min_tree = speedup_floor("REPRO_SIM_MIN_TREE", DEFAULT_MIN_TREE, args.n)
    min_gm = speedup_floor("REPRO_SIM_MIN_GEOMEAN", DEFAULT_MIN_GEOMEAN,
                           args.n)
    failures = []
    tree = next((r["speedup"] for r in all_rows
                 if r["trace"] == "dp-sweep" and r["prefetcher"] == "tree"
                 and r["backend"] != "pallas"),
                None)
    if min_tree and tree is not None and tree < min_tree:
        failures.append(f"tree speedup {tree:.2f}x < {min_tree:.2f}x "
                        "(REPRO_SIM_MIN_TREE)")
    dp_gm = geomeans.get("dp-sweep")
    if min_gm and dp_gm is not None and dp_gm < min_gm:
        failures.append(f"geomean speedup {geomeans['dp-sweep']:.2f}x < "
                        f"{min_gm:.2f}x (REPRO_SIM_MIN_GEOMEAN)")
    if failures:
        sys.exit("FAIL: " + "; ".join(failures))


if __name__ == "__main__":
    main()
