"""Micro-benchmark: legacy vs vectorized UVM-engine replay throughput.

    PYTHONPATH=src python -m benchmarks.sim_throughput            # 1M accesses
    PYTHONPATH=src python -m benchmarks.sim_throughput --n 200000
    PYTHONPATH=src python -m benchmarks.sim_throughput --bench ATAX --scale 1.0
    PYTHONPATH=src python -m benchmarks.sim_throughput --json BENCH_sim.json

The default workload is a 1M-access DP-style trace (per "row", a block of
newly-streamed pages plus repeated sweeps over two reused result buffers —
the Pathfinder access structure that dominates the paper's reuse-heavy
benchmarks).  Every cell also cross-checks that both engines produced
identical counters, so the speedup is never bought with drift.

CI thresholds
-------------
On the default-size dp-sweep run the vectorized engine must hold its
speedups (tree >= 8x, geomean >= 7.5x — the PR 2 acceptance floor).  Both
floors are overridable via ``REPRO_SIM_MIN_TREE`` / ``REPRO_SIM_MIN_GEOMEAN``
(set 0 to disable), so slow or noisy CI machines can relax the wall-clock
gates and still fail hard on counter drift.  Small ``--n`` smoke runs
(< 500k accesses) are warmup-dominated and skip the default floors.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

import numpy as np

from repro.traces.trace import Trace, make_records
from repro.uvm import (NoPrefetcher, OraclePrefetcher, TreePrefetcher,
                       UVMConfig, UVMSimulator, VectorizedUVMSimulator)
from repro.uvm.prefetchers import LearnedPrefetcher
from repro.uvm.metrics import geomean

CHECK_FIELDS = ("hits", "late", "faults", "prefetch_issued", "prefetch_used",
                "pages_migrated", "pages_evicted", "cycles", "pcie_bytes")

#: default speedup floors for the dp-sweep run (ROADMAP acceptance); only
#: enforced at representative sizes — tiny smoke traces are dominated by
#: per-run constants, where wall-clock noise would mask real regressions
DEFAULT_MIN_TREE = 8.0
DEFAULT_MIN_GEOMEAN = 7.5
THRESHOLD_MIN_ACCESSES = 500_000


def speedup_floor(env: str, default: float, n: int) -> float:
    """Threshold from ``env`` if set, else ``default`` at representative
    trace sizes and disabled (0) below ``THRESHOLD_MIN_ACCESSES``."""
    raw = os.environ.get(env)
    if raw is not None:
        return float(raw)
    return default if n >= THRESHOLD_MIN_ACCESSES else 0.0


def dp_sweep_trace(n: int) -> Trace:
    """DP-style rows: 400 fresh streaming pages + 8 sweeps over two reused
    1000-page result buffers per row (≈98% reuse, like Pathfinder)."""
    per_row = 20_000
    rows = max(1, n // per_row)
    stream = 400
    reuse = np.tile(np.arange(2000, dtype=np.int64), 10)[:19_600]
    chunks = [np.concatenate([np.arange(r * stream, (r + 1) * stream,
                                        dtype=np.int64) + 100_000,
                              reuse])
              for r in range(rows)]
    pages = np.concatenate(chunks)[:n]
    recs = make_records(len(pages))
    recs["page"] = pages
    recs["sm"] = np.arange(len(pages)) % 4
    return Trace("dp-sweep", recs, {}, {}, len(pages) * 100)


def bench_trace(name: str, scale: float) -> Trace:
    from repro.traces import GPUModel, generate_benchmark
    return GPUModel().run(generate_benchmark(name, scale=scale))


def prefetchers(trace: Trace, cfg: UVMConfig) -> List:
    from repro.uvm.golden import perfect_preds
    pages = np.asarray(trace.pages)
    preds = perfect_preds(trace, distance=64)
    return [
        ("none", lambda: NoPrefetcher()),
        ("tree", lambda: TreePrefetcher()),
        ("learned", lambda: LearnedPrefetcher(
            preds, extra_latency_cycles=1.0 * cfg.cycles_per_us)),
        ("oracle", lambda: OraclePrefetcher(pages)),
    ]


def run(trace: Trace, cfg: UVMConfig, skip_oracle: bool = False):
    n = len(trace)
    rows = []
    print(f"\n== sim_throughput: {trace.name} ({n} accesses) ==")
    print("prefetcher,legacy_s,legacy_acc_per_s,vec_s,vec_acc_per_s,"
          "speedup,identical")
    for name, factory in prefetchers(trace, cfg):
        if skip_oracle and name == "oracle":
            continue
        t0 = time.time()
        s_legacy = UVMSimulator(cfg).run(trace, factory())
        t_legacy = time.time() - t0
        t0 = time.time()
        s_vec = VectorizedUVMSimulator(cfg).run(trace, factory())
        t_vec = time.time() - t0
        same = all(getattr(s_legacy, f) == getattr(s_vec, f)
                   for f in CHECK_FIELDS)
        speedup = t_legacy / max(t_vec, 1e-9)
        rows.append({"trace": trace.name, "n_accesses": n,
                     "prefetcher": name, "speedup": speedup, "same": same,
                     "backend": s_vec.backend,
                     "legacy_s": t_legacy, "vec_s": t_vec,
                     "legacy_aps": n / max(t_legacy, 1e-9),
                     "vec_aps": n / max(t_vec, 1e-9)})
        print(f"{name},{t_legacy:.3f},{n / max(t_legacy, 1e-9):.0f},"
              f"{t_vec:.3f},{n / max(t_vec, 1e-9):.0f},"
              f"{speedup:.2f},{same}")
    gm = geomean([r["speedup"] for r in rows])
    print(f"GEOMEAN speedup: {gm:.2f}x; all identical: "
          f"{all(r['same'] for r in rows)}")
    return rows, gm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000,
                    help="accesses in the synthetic dp-sweep trace")
    ap.add_argument("--bench", default=None,
                    help="also run a generated benchmark trace (e.g. ATAX)")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--skip-oracle", action="store_true",
                    help="oracle is slow on both engines at large n")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-prefetcher engine-throughput rows + "
                         "geomean as JSON (perf trajectory for future PRs)")
    args = ap.parse_args()

    cfg = UVMConfig()
    all_rows = []
    geomeans = {}
    rows, gm = run(dp_sweep_trace(args.n), cfg, skip_oracle=args.skip_oracle)
    all_rows += rows
    geomeans["dp-sweep"] = gm
    if args.bench:
        rows, gm = run(bench_trace(args.bench, args.scale), cfg,
                       skip_oracle=args.skip_oracle)
        all_rows += rows
        geomeans[args.bench] = gm
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"version": 1, "benchmark": "sim_throughput",
                       "rows": all_rows, "geomean_speedup": geomeans},
                      f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    if not all(r["same"] for r in all_rows):
        # any counter drift between the engines is a correctness failure,
        # not a perf data point — make CI smoke runs fail loudly
        sys.exit("FAIL: vectorized engine diverged from legacy counters")

    # wall-clock floors (dp-sweep run only; env-overridable so slow CI
    # machines fail on counter drift above, not on scheduling noise here)
    min_tree = speedup_floor("REPRO_SIM_MIN_TREE", DEFAULT_MIN_TREE, args.n)
    min_gm = speedup_floor("REPRO_SIM_MIN_GEOMEAN", DEFAULT_MIN_GEOMEAN,
                           args.n)
    failures = []
    tree = next((r["speedup"] for r in all_rows
                 if r["trace"] == "dp-sweep" and r["prefetcher"] == "tree"),
                None)
    if min_tree and tree is not None and tree < min_tree:
        failures.append(f"tree speedup {tree:.2f}x < {min_tree:.2f}x "
                        "(REPRO_SIM_MIN_TREE)")
    if min_gm and geomeans.get("dp-sweep", min_gm) < min_gm:
        failures.append(f"geomean speedup {geomeans['dp-sweep']:.2f}x < "
                        f"{min_gm:.2f}x (REPRO_SIM_MIN_GEOMEAN)")
    if failures:
        sys.exit("FAIL: " + "; ".join(failures))


if __name__ == "__main__":
    main()
