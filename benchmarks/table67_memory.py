"""Paper Tables 6-7: memory footprint of the full Transformer predictor vs
the revised (3-feature, 1-layer, HLSH, 4-bit) predictor."""
from __future__ import annotations

import jax

from benchmarks.common import (PREDICTOR_BENCHMARKS, get_trace, print_table)
from repro.core import (DeltaVocab, PredictorConfig, cluster_trace,
                        delta_convergence, init_params, revised_config)
from repro.core.model import count_activation_elems
from repro.core.quantize import footprint_report

BATCH = 128


def run():
    rows = []
    for b in PREDICTOR_BENCHMARKS:
        trace = get_trace(b)
        ct = cluster_trace(trace, "sm")
        vocab = DeltaVocab.build(ct)
        conv = delta_convergence(ct)

        full_cfg = PredictorConfig(n_classes=vocab.n_classes)
        full_p = init_params(full_cfg, jax.random.PRNGKey(0))
        full = footprint_report(full_p, count_activation_elems(full_cfg),
                                BATCH, bits=32)

        rev_cfg = revised_config(vocab.n_classes, conv)
        rev_p = init_params(rev_cfg, jax.random.PRNGKey(0))
        rev = footprint_report(rev_p, count_activation_elems(rev_cfg),
                               BATCH, bits=4)

        rows.append({
            "bench": b,
            "full_params_mb": full["params_bytes"] / 1e6,
            "full_total_mb": full["total_bytes"] / 1e6,
            "revised_params_mb": rev["params_bytes"] / 1e6,
            "revised_total_mb": rev["total_bytes"] / 1e6,
            "ratio": full["total_bytes"] / max(rev["total_bytes"], 1),
        })
    return rows


def main():
    print_table("Tables 6-7: memory footprint (full vs revised)", run(),
                ["bench", "full_params_mb", "full_total_mb",
                 "revised_params_mb", "revised_total_mb", "ratio"])


if __name__ == "__main__":
    main()
