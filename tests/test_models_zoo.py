"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finiteness, plus decode-vs-prefill parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import build_model, init_params, train_loss, prefill, decode

B, S = 2, 32


def _batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S // 8, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("name", list_archs())
def test_arch_smoke(name):
    rng = np.random.default_rng(0)
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = init_params(model, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss, metrics = train_loss(model, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    grads = jax.grad(lambda p: train_loss(model, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, name


# MoE archs are excluded: capacity-limited routing is sequence-global, so
# prefilling S vs S+1 tokens legitimately drops different tokens.
@pytest.mark.parametrize("name", ["llama3-8b", "mamba2-780m",
                                  "recurrentgemma-9b", "internvl2-1b",
                                  "seamless-m4t-medium"])
def test_prefill_decode_parity(name):
    """Decoding token S with the prefill cache must match prefilling S+1
    tokens — the strongest serve-path correctness check."""
    rng = np.random.default_rng(1)
    cfg = get_arch(name).reduced()
    model = build_model(cfg)
    params = init_params(model, jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    toks = batch["tokens"]

    # prefill S tokens, then decode the token at position S.  For VLM the
    # cache also holds the patch prefix: decode indices are cache-relative.
    prefix = cfg.frontend_seq if cfg.family == "vlm" else 0
    logits1, states = prefill(model, params, batch, max_len=prefix + S + 4)
    next_tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    logits_dec, _ = decode(model, params, states, next_tok,
                           jnp.asarray(prefix + S, jnp.int32))

    # ground truth: prefill S+1 tokens directly
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([toks, next_tok], axis=1)
    if cfg.family == "audio":
        batch2["frames"] = batch["frames"]
    logits2, _ = prefill(model, params, batch2)

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits2[:, -1], np.float32), atol=2e-2, rtol=2e-2)


def test_moe_routing_mass():
    """MoE combine weights renormalize: output magnitude is sane and the
    aux loss is near 1 (balanced) for random tokens."""
    cfg = get_arch("qwen3-moe-235b-a22b").reduced()
    model = build_model(cfg)
    params = init_params(model, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    loss, metrics = train_loss(model, params, batch)
    assert 0.5 < float(metrics["aux"]) / cfg.n_layers < 4.0


def test_reduced_configs_are_small():
    for name in list_archs():
        cfg = get_arch(name).reduced()
        assert cfg.d_model <= 64
        assert cfg.n_layers <= 2
        assert cfg.vocab <= 512
