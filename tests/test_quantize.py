"""Quantization: fake-quant grids, STE, int4 pack/unpack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property-based quantize tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core.quantize import (QMAX, QMIN, dequantize_int4, fake_quant,
                                 fake_quant_tensor, quantize_int4)


def test_fake_quant_grid():
    x = jnp.linspace(-12, 12, 101)
    q = fake_quant(x)
    assert float(q.min()) >= QMIN
    assert float(q.max()) <= QMAX
    # on-grid: integers
    assert np.allclose(np.asarray(q), np.round(np.asarray(q)))


def test_fake_quant_ste_gradient():
    g = jax.grad(lambda x: fake_quant(x).sum())(jnp.asarray([0.3, 5.0, 20.0]))
    # straight-through: gradient 1 everywhere (including clamped region,
    # by this STE formulation)
    assert np.allclose(np.asarray(g), 1.0)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=64))
def test_int4_roundtrip_error(vals):
    x = np.asarray(vals, np.float32)
    packed, scale = quantize_int4(x)
    y = dequantize_int4(packed, scale, x.size, x.shape)
    # max error is half a quantization step
    assert np.abs(x - y).max() <= scale * 1.01 + 1e-6


def test_fake_quant_tensor_preserves_scale():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 0.02,
                    jnp.float32)
    q = fake_quant_tensor(x)
    # per-tensor scaling: small weights survive (not rounded to zero)
    assert float(jnp.abs(q).max()) > 0
    assert float(jnp.max(jnp.abs(q - x))) < float(jnp.abs(x).max())
