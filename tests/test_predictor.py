"""Predictor models + training: learnability, quantized training, revised
config behaviour."""
import jax
import numpy as np
import pytest

from repro.core import (DeltaVocab, PredictorConfig, build_dataset,
                        cluster_trace, delta_convergence, init_params,
                        revised_config, train_predictor)
from repro.core import apply as model_apply


def _dataset(trace, distance=1, revised=False):
    from repro.core.model import REVISED_FEATURES, EMB_DIMS
    ct = cluster_trace(trace, "sm")
    vocab = DeltaVocab.build(ct, distance=distance)
    feats = list(REVISED_FEATURES if revised else EMB_DIMS)
    data = build_dataset(ct, vocab, features=feats, distance=distance,
                         max_train=4000, max_eval=2000)
    return ct, vocab, data


@pytest.mark.parametrize("arch", ["transformer", "fc", "mlp", "cnn", "lstm"])
def test_model_shapes(arch, small_trace):
    _, vocab, data = _dataset(small_trace)
    cfg = PredictorConfig(n_classes=vocab.n_classes, arch=arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    logits = model_apply(cfg, params, data.x_train[:8])
    assert logits.shape == (8, vocab.n_classes)
    assert bool(np.isfinite(np.asarray(logits)).all())


def test_training_beats_chance(small_trace):
    _, vocab, data = _dataset(small_trace)
    cfg = PredictorConfig(n_classes=vocab.n_classes)
    res = train_predictor(cfg, data, steps=60)
    # ATAX is the paper's easiest benchmark: far above chance quickly
    assert res.metrics["top1"] > 0.5


def test_revised_quantized_trains(small_trace):
    ct, vocab, data = _dataset(small_trace, revised=True)
    conv = delta_convergence(ct)
    cfg = revised_config(vocab.n_classes, conv, quantize=True)
    res = train_predictor(cfg, data, steps=60)
    assert res.metrics["top1"] > 0.5
    assert cfg.d_model == 12          # 3-feature, 12-dim embedding (paper §6)


def test_bypass_indicator():
    hi = revised_config(10, convergence=0.95)
    lo = revised_config(10, convergence=0.1)
    assert hi.attention == "bypass"
    assert lo.attention == "hlsh"


def test_service_end_to_end(small_trace):
    from repro.core import PredictorService
    svc = PredictorService(steps=40)
    res = svc.fit(small_trace)
    preds = svc.predict_trace()
    assert len(preds) == len(small_trace)
    valid = preds >= 0
    assert valid.mean() > 0.5
    # predictions are plausible pages (within the trace's address range)
    pages = small_trace.pages
    assert preds[valid].min() >= pages.min() - 10_000
    assert preds[valid].max() <= pages.max() + 10_000
