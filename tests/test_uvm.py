"""UVM simulator invariants + prefetcher behaviour."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property-based UVM tests skipped")
from hypothesis import given, settings, strategies as st

from repro.traces.trace import BASIC_BLOCK_PAGES, Trace, make_records
from repro.uvm import (NoPrefetcher, OraclePrefetcher, TreePrefetcher,
                       UVMConfig, UVMSimulator)
from repro.uvm.prefetchers import LearnedPrefetcher


def _mk_trace(pages, n_inst=None) -> Trace:
    recs = make_records(len(pages))
    recs["page"] = pages
    recs["sm"] = np.arange(len(pages)) % 4
    return Trace("synth", recs, {}, {}, n_inst or len(pages) * 100)


def test_accounting_invariant(small_trace):
    sim = UVMSimulator()
    st_ = sim.run(small_trace, NoPrefetcher())
    assert st_.hits + st_.late + st_.faults == st_.n_accesses
    assert st_.coverage == 0.0        # nothing prefetched
    assert st_.accuracy == 1.0        # vacuous
    assert st_.pcie_bytes == st_.pages_migrated * 4096


def test_on_demand_faults_once_per_page():
    pages = np.concatenate([np.arange(100), np.arange(100)])
    tr = _mk_trace(pages)
    st_ = UVMSimulator().run(tr, NoPrefetcher())
    assert st_.faults == 100


def test_tree_prefetches_blocks():
    pages = np.arange(0, 64, 1)  # 4 basic blocks, sequential
    tr = _mk_trace(pages)
    st_ = UVMSimulator().run(tr, TreePrefetcher())
    # faults only at block boundaries (or fewer, via escalation)
    assert st_.faults <= 4
    assert st_.prefetch_issued >= 60 - st_.faults


def test_tree_escalation_covers_chunk():
    # touch >50% of a 2MB chunk's blocks: the rest must be prefetched
    pf = TreePrefetcher()
    pages = np.arange(0, 272, 1)   # 17 blocks > half of 32
    tr = _mk_trace(pages)
    st_ = UVMSimulator().run(tr, pf)
    assert st_.pages_migrated >= 512  # whole 2MB chunk pulled


def test_eviction_capacity():
    cfg = UVMConfig(device_pages=64)
    pages = np.arange(0, 1000)
    tr = _mk_trace(pages)
    st_ = UVMSimulator(cfg).run(tr, NoPrefetcher())
    assert st_.pages_evicted >= 1000 - 64 - 1


def test_oracle_upper_bound(small_trace):
    sim = UVMSimulator()
    tree = sim.run(small_trace, TreePrefetcher())
    oracle = sim.run(small_trace, OraclePrefetcher(small_trace.pages))
    assert oracle.accuracy >= 0.99
    assert oracle.ipc >= tree.ipc * 0.9


def test_learned_latency_hurts(pathfinder_trace):
    """Fig 10 mechanism: larger per-prediction overhead -> fewer predictions
    served -> worse IPC."""
    n = len(pathfinder_trace)
    # perfect distance-k predictions
    k = 64
    preds = np.full(n, -1, np.int64)
    preds[:-k] = pathfinder_trace.pages[k:]
    cfg = UVMConfig()
    sim = UVMSimulator(cfg)
    fast = sim.run(pathfinder_trace, LearnedPrefetcher(
        preds, extra_latency_cycles=1.0 * cfg.cycles_per_us))
    slow = sim.run(pathfinder_trace, LearnedPrefetcher(
        preds, extra_latency_cycles=40.0 * cfg.cycles_per_us))
    assert fast.ipc >= slow.ipc
    assert fast.prefetch_issued >= slow.prefetch_issued


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 500), min_size=50, max_size=400))
def test_property_conservation(pages):
    tr = _mk_trace(np.asarray(pages, np.int64))
    st_ = UVMSimulator().run(tr, TreePrefetcher())
    # conservation: every access classified exactly once
    assert st_.hits + st_.late + st_.faults == st_.n_accesses
    # every unique page migrated at least once, never "negative" traffic
    assert st_.pages_migrated >= len(set(pages))
    assert st_.prefetch_used <= st_.prefetch_issued
    assert 0.0 <= st_.hit_rate <= 1.0
    assert 0.0 <= st_.unity <= 1.0


def test_unity_formula():
    from repro.uvm.metrics import unity
    assert unity(1, 1, 1) == pytest.approx(1.0)
    assert unity(0.5, 1, 1) == pytest.approx(0.5 ** (1 / 3))
