"""Paged KV offload store + learned prefetcher."""
import numpy as np
import pytest

from repro.offload import OffloadPrefetcher, PagedKVStore
from repro.offload.paged_store import BLOCK_BYTES, BLOCK_TOKENS


def _run(capacity, prefetch, gen=128, n_req=4, start=256, evict="lru"):
    store = PagedKVStore(n_requests=n_req, max_len=2048,
                         hbm_capacity_blocks=capacity, evict=evict)
    pf = OffloadPrefetcher(store) if prefetch else None
    for step in range(gen):
        pos = start + step
        if pf:
            pf.step(pos)
        store.on_decode_step(pos)
    return store.stats(), store


def test_capacity_respected():
    _, store = _run(capacity=16, prefetch=False)
    assert len(store.resident) <= 16


def test_prefetch_not_harmful_and_used():
    base, _ = _run(capacity=64, prefetch=False)
    pf, _ = _run(capacity=64, prefetch=True)
    assert pf["hit_rate"] >= base["hit_rate"] - 0.05
    assert pf["prefetch_accuracy"] >= 0.0


def test_pin_beats_lru_under_thrash():
    """Cyclic decode sweeps thrash LRU to ~0%; insertion-bypass pinning
    (the paper's soft-pin insight, serving-side) keeps a stable subset."""
    lru, _ = _run(capacity=16, prefetch=False, evict="lru")
    pin, _ = _run(capacity=16, prefetch=False, evict="pin")
    assert pin["hit_rate"] > lru["hit_rate"] + 0.2


def test_stats_sane():
    st, store = _run(capacity=64, prefetch=True)
    assert 0 <= st["hit_rate"] <= 1
    assert 0 <= st["prefetch_accuracy"] <= 1
    assert st["host_bytes"] > 0


# ---------------------------------------------------------------------------
# prefetch accounting (regressions for the pin-policy bypass leak)
# ---------------------------------------------------------------------------

def test_pin_prefetch_bypass_accounting():
    """Under pin at capacity, bypassed prefetch blocks must not be DMA'd,
    charged to host_bytes / prefetch_issued, or flagged as prefetched
    (the old code transferred and flagged blocks _insert then rejected,
    deflating prefetch accuracy and inflating interconnect traffic)."""
    store = PagedKVStore(n_requests=1, max_len=2048,
                         hbm_capacity_blocks=4, evict="pin")
    store.prefetch([(0, b) for b in range(4)])        # fill to capacity
    assert len(store.resident) == 4
    bytes_full = store.host_bytes
    issued_full = store.prefetch_issued

    store.prefetch([(0, b) for b in range(4, 10)])    # no room: all bypass
    assert store.host_bytes == bytes_full
    assert store.prefetch_issued == issued_full
    assert store.prefetch_bypassed == 6
    # no phantom prefetched flags for blocks that never became resident
    assert set(store.prefetched) <= set(store.resident)
    assert store.stats()["prefetch_bypassed"] == 6.0


def test_pin_prefetch_partial_room():
    """A batch larger than the remaining HBM room is trimmed, not
    rejected wholesale: the first `room` blocks insert and are charged."""
    store = PagedKVStore(n_requests=1, max_len=2048,
                         hbm_capacity_blocks=4, evict="pin")
    store.prefetch([(0, 0), (0, 1)])
    store.prefetch([(0, b) for b in range(2, 7)])     # room for 2 of 5
    assert len(store.resident) == 4
    assert store.prefetch_issued == 4
    assert store.prefetch_bypassed == 3
    assert store.host_bytes == 4 * BLOCK_BYTES
    assert set(store.prefetched) == {(0, 0), (0, 1), (0, 2), (0, 3)}


def test_prefetch_duplicates_collapse_to_one_dma():
    """Duplicate keys in one prefetch batch transfer (and count) once."""
    store = PagedKVStore(n_requests=1, max_len=2048,
                         hbm_capacity_blocks=8)
    store.prefetch([(0, 0), (0, 0), (0, 1), (0, 0), (0, 1)])
    assert store.prefetch_issued == 2
    assert store.host_bytes == 2 * BLOCK_BYTES
    assert len(store.resident) == 2


def test_inflight_miss_does_not_re_dma():
    """A block whose DMA is still in flight stalls (counts a miss) but is
    never transferred again."""
    store = PagedKVStore(n_requests=2, max_len=2048,
                         hbm_capacity_blocks=8)
    store.on_decode_step(0, step_us=1.0)     # 2 blocks DMA'd, arrive ~+5us
    assert store.host_bytes == 2 * BLOCK_BYTES
    store.on_decode_step(0, step_us=1.0)     # still in flight at +2us
    assert store.misses == 4
    assert store.host_bytes == 2 * BLOCK_BYTES   # no re-DMA
    store.on_decode_step(0, step_us=10.0)    # arrived by +12us: hits now
    assert store.hits == 2
    assert store.host_bytes == 2 * BLOCK_BYTES


def test_decode_position_guard():
    """Positions outside max_len mean the KV-cache index and the capacity
    accounting disagree (the VLM prefix bug) — the store must refuse."""
    store = PagedKVStore(n_requests=1, max_len=128, hbm_capacity_blocks=8)
    with pytest.raises(ValueError, match="outside max_len"):
        store.on_decode_step(128)
    with pytest.raises(ValueError, match="outside max_len"):
        store.on_decode_step(-1)


def test_access_log_round_trips_through_trace():
    """The store's access log encodes to a replay-core trace and decodes
    back byte-identically (the serve-trace block <-> page mapping is
    lossless), with decode steps riding in the kernel column."""
    from repro.offload.serve_trace import (access_log_to_trace,
                                           trace_to_access_log)

    store = PagedKVStore(n_requests=3, max_len=512, hbm_capacity_blocks=8)
    step_ends = []
    for step in range(6):
        store.on_decode_step(200 + step)
        step_ends.append(len(store.access_log))
    trace = access_log_to_trace(
        store.access_log, n_requests=3,
        blocks_per_seq=store.blocks_per_seq, step_ends=step_ends)
    assert trace_to_access_log(trace) == store.access_log
    kern = trace.accesses["kernel"]
    assert np.all(np.diff(kern.astype(np.int64)) >= 0)
    assert int(kern.max()) == len(step_ends) - 1
