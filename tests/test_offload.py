"""Paged KV offload store + learned prefetcher."""
from repro.offload import OffloadPrefetcher, PagedKVStore
from repro.offload.paged_store import BLOCK_TOKENS


def _run(capacity, prefetch, gen=128, n_req=4, start=256, evict="lru"):
    store = PagedKVStore(n_requests=n_req, max_len=2048,
                         hbm_capacity_blocks=capacity, evict=evict)
    pf = OffloadPrefetcher(store) if prefetch else None
    for step in range(gen):
        pos = start + step
        if pf:
            pf.step(pos)
        store.on_decode_step(pos)
    return store.stats(), store


def test_capacity_respected():
    _, store = _run(capacity=16, prefetch=False)
    assert len(store.resident) <= 16


def test_prefetch_not_harmful_and_used():
    base, _ = _run(capacity=64, prefetch=False)
    pf, _ = _run(capacity=64, prefetch=True)
    assert pf["hit_rate"] >= base["hit_rate"] - 0.05
    assert pf["prefetch_accuracy"] >= 0.0


def test_pin_beats_lru_under_thrash():
    """Cyclic decode sweeps thrash LRU to ~0%; insertion-bypass pinning
    (the paper's soft-pin insight, serving-side) keeps a stable subset."""
    lru, _ = _run(capacity=16, prefetch=False, evict="lru")
    pin, _ = _run(capacity=16, prefetch=False, evict="pin")
    assert pin["hit_rate"] > lru["hit_rate"] + 0.2


def test_stats_sane():
    st, store = _run(capacity=64, prefetch=True)
    assert 0 <= st["hit_rate"] <= 1
    assert 0 <= st["prefetch_accuracy"] <= 1
    assert st["host_bytes"] > 0
