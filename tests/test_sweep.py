"""Sweep orchestrator: grid expansion, worker determinism, resume, round-trip."""
import json
import os

import numpy as np
import pytest

from repro.uvm.sweep import (ROW_FIELDS, SweepCell, expand_grid, load_trace,
                             read_results, read_results_csv, run_sweep,
                             simulate_cell, write_results)

BENCHES = ["ATAX", "Pathfinder"]
PREFETCHERS = ["none", "tree"]


def _small_cells(**kw):
    return expand_grid(BENCHES, PREFETCHERS, scales=[0.25], **kw)


def _strip_timing(rows):
    return [{k: v for k, v in r.items() if k != "seconds"} for r in rows]


def test_grid_expansion_axes():
    cells = expand_grid(BENCHES, PREFETCHERS, scales=[0.25, 0.5],
                        device_fracs=[None, 0.5], prediction_us=[1.0, 10.0])
    assert len(cells) == 2 * 2 * 2 * 2 * 2
    # deterministic order and distinct cache keys
    assert [c.key() for c in cells] == [c.key() for c in cells]
    assert len({c.key() for c in cells}) == len(cells)
    # every axis value is represented
    assert {c.bench for c in cells} == set(BENCHES)
    assert {c.device_frac for c in cells} == {None, 0.5}


def test_trace_cache_roundtrip(tmp_path):
    cache = str(tmp_path / "cache")
    t1 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)
    assert any(f.startswith("trace_") for f in os.listdir(cache))
    t2 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)  # from disk
    assert t1.name == t2.name
    assert t1.n_instructions == t2.n_instructions
    np.testing.assert_array_equal(t1.accesses, t2.accesses)
    assert t1.array_pages == t2.array_pages


def test_simulate_cell_row_shape():
    row = simulate_cell(SweepCell("ATAX", "tree", scale=0.25))
    missing = [c for c in ROW_FIELDS if c not in row]
    assert not missing, missing
    assert row["hits"] + row["late"] + row["faults"] == row["n_accesses"]
    assert 0.0 <= row["hit_rate"] <= 1.0


def test_device_frac_resolves_capacity():
    row = simulate_cell(SweepCell("ATAX", "none", scale=0.25,
                                  device_frac=0.5))
    assert row["device_pages"] is not None and row["device_pages"] > 0
    assert row["pages_evicted"] > 0


def test_serial_and_parallel_match(tmp_path):
    cells = _small_cells()
    serial = run_sweep(cells, out_dir=str(tmp_path / "serial"), workers=1)
    parallel = run_sweep(cells, out_dir=str(tmp_path / "parallel"), workers=2)
    assert _strip_timing(serial) == _strip_timing(parallel)


def test_resume_from_partial_results(tmp_path):
    out = str(tmp_path / "out")
    cells = _small_cells()
    full = run_sweep(cells, out_dir=out, workers=1)

    # wipe half the cell files; poison the survivors so we can prove the
    # resumed sweep loaded them instead of recomputing
    cell_dir = os.path.join(out, "cells")
    kept = 0
    for i, cell in enumerate(cells):
        path = os.path.join(cell_dir, f"{cell.key()}.json")
        if i % 2 == 0:
            os.remove(path)
        else:
            with open(path) as f:
                row = json.load(f)
            row["seconds"] = 12345.0
            with open(path, "w") as f:
                json.dump(row, f)
            kept += 1
    assert kept > 0

    resumed = run_sweep(cells, out_dir=out, workers=1)
    assert _strip_timing(resumed) == _strip_timing(full)
    marks = [r["seconds"] for r in resumed if r["seconds"] == 12345.0]
    assert len(marks) == kept          # loaded, not recomputed

    # resume=False recomputes everything
    fresh = run_sweep(cells, out_dir=out, workers=1, resume=False)
    assert not any(r["seconds"] == 12345.0 for r in fresh)


def test_results_json_csv_roundtrip(tmp_path):
    out = str(tmp_path / "out")
    cells = _small_cells(device_fracs=[None, 0.75])
    rows = run_sweep(cells, out_dir=out, workers=1)

    back = read_results(out)
    assert _strip_timing(back) == _strip_timing(rows)

    csv_rows = read_results_csv(os.path.join(out, "results.csv"))
    assert len(csv_rows) == len(rows)
    for got, want in zip(csv_rows, rows):
        assert got["bench"] == want["bench"]
        assert got["prefetcher"] == want["prefetcher"]
        assert got["n_accesses"] == want["n_accesses"]
        assert got["faults"] == want["faults"]
        assert got["device_frac"] == want["device_frac"]
        assert got["hit_rate"] == pytest.approx(want["hit_rate"], rel=1e-9)
        assert got["cycles"] == pytest.approx(want["cycles"], rel=1e-9)

    # write_results is idempotent over loaded rows
    write_results(back, out)
    assert _strip_timing(read_results(out)) == _strip_timing(rows)


def test_engine_choice_is_equivalent():
    base = dict(bench="ATAX", prefetcher="tree", scale=0.25)
    vec = simulate_cell(SweepCell(engine="vectorized", **base))
    legacy = simulate_cell(SweepCell(engine="legacy", **base))
    for f in ("hits", "late", "faults", "pages_migrated", "prefetch_issued"):
        assert vec[f] == legacy[f]
    assert vec["cycles"] == pytest.approx(legacy["cycles"], rel=1e-6)
