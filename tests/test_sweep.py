"""Sweep orchestrator: grid expansion, worker determinism, resume,
round-trip, and train-once learned cells."""
import json
import os
import time

import numpy as np
import pytest

from repro.uvm import predcache
from repro.uvm.sweep import (ROW_FIELDS, SweepCell, expand_grid,
                             load_cell_row, load_trace, read_results,
                             read_results_csv, run_sweep, simulate_cell,
                             write_cell_row, write_results)

BENCHES = ["ATAX", "Pathfinder"]
PREFETCHERS = ["none", "tree"]


def _small_cells(**kw):
    return expand_grid(BENCHES, PREFETCHERS, scales=[0.25], **kw)


def _strip_timing(rows):
    # seconds and the lease-attempt counter are execution metadata — a
    # recomputed or resumed cell may legitimately differ in both
    return [{k: v for k, v in r.items() if k not in ("seconds", "retries")}
            for r in rows]


def test_grid_expansion_axes():
    cells = expand_grid(BENCHES, PREFETCHERS, scales=[0.25, 0.5],
                        device_fracs=[None, 0.5], prediction_us=[1.0, 10.0])
    assert len(cells) == 2 * 2 * 2 * 2 * 2
    # deterministic order and distinct cache keys
    assert [c.key() for c in cells] == [c.key() for c in cells]
    assert len({c.key() for c in cells}) == len(cells)
    # every axis value is represented
    assert {c.bench for c in cells} == set(BENCHES)
    assert {c.device_frac for c in cells} == {None, 0.5}


def test_trace_cache_roundtrip(tmp_path):
    cache = str(tmp_path / "cache")
    t1 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)
    assert any(f.startswith("trace_") for f in os.listdir(cache))
    t2 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)  # from disk
    assert t1.name == t2.name
    assert t1.n_instructions == t2.n_instructions
    np.testing.assert_array_equal(t1.accesses, t2.accesses)
    assert t1.array_pages == t2.array_pages


def test_simulate_cell_row_shape():
    row = simulate_cell(SweepCell("ATAX", "tree", scale=0.25))
    missing = [c for c in ROW_FIELDS if c not in row]
    assert not missing, missing
    assert row["hits"] + row["late"] + row["faults"] == row["n_accesses"]
    assert 0.0 <= row["hit_rate"] <= 1.0


def test_device_frac_resolves_capacity():
    row = simulate_cell(SweepCell("ATAX", "none", scale=0.25,
                                  device_frac=0.5))
    assert row["device_pages"] is not None and row["device_pages"] > 0
    assert row["pages_evicted"] > 0


def test_serial_and_parallel_match(tmp_path):
    cells = _small_cells()
    serial = run_sweep(cells, out_dir=str(tmp_path / "serial"), workers=1)
    parallel = run_sweep(cells, out_dir=str(tmp_path / "parallel"), workers=2)
    assert _strip_timing(serial) == _strip_timing(parallel)


def test_resume_from_partial_results(tmp_path):
    out = str(tmp_path / "out")
    cells = _small_cells()
    full = run_sweep(cells, out_dir=out, workers=1)

    # wipe half the cell files; poison the survivors so we can prove the
    # resumed sweep loaded them instead of recomputing
    cell_dir = os.path.join(out, "cells")
    kept = 0
    for i, cell in enumerate(cells):
        path = os.path.join(cell_dir, f"{cell.key()}.json")
        if i % 2 == 0:
            os.remove(path)
        else:
            row, reason = load_cell_row(path)
            assert reason == "ok"
            row["seconds"] = 12345.0
            write_cell_row(path, row)     # checksum must cover the poke
            kept += 1
    assert kept > 0

    resumed = run_sweep(cells, out_dir=out, workers=1)
    assert _strip_timing(resumed) == _strip_timing(full)
    marks = [r["seconds"] for r in resumed if r["seconds"] == 12345.0]
    assert len(marks) == kept          # loaded, not recomputed

    # resume=False recomputes everything
    fresh = run_sweep(cells, out_dir=out, workers=1, resume=False)
    assert not any(r["seconds"] == 12345.0 for r in fresh)


def test_results_json_csv_roundtrip(tmp_path):
    out = str(tmp_path / "out")
    cells = _small_cells(device_fracs=[None, 0.75])
    rows = run_sweep(cells, out_dir=out, workers=1)

    back = read_results(out)
    assert _strip_timing(back) == _strip_timing(rows)

    csv_rows = read_results_csv(os.path.join(out, "results.csv"))
    assert len(csv_rows) == len(rows)
    for got, want in zip(csv_rows, rows):
        assert got["bench"] == want["bench"]
        assert got["prefetcher"] == want["prefetcher"]
        assert got["n_accesses"] == want["n_accesses"]
        assert got["faults"] == want["faults"]
        assert got["device_frac"] == want["device_frac"]
        assert got["hit_rate"] == pytest.approx(want["hit_rate"], rel=1e-9)
        assert got["cycles"] == pytest.approx(want["cycles"], rel=1e-9)

    # write_results is idempotent over loaded rows
    write_results(back, out)
    assert _strip_timing(read_results(out)) == _strip_timing(rows)


def test_engine_choice_is_equivalent():
    base = dict(bench="ATAX", prefetcher="tree", scale=0.25)
    vec = simulate_cell(SweepCell(engine="vectorized", **base))
    legacy = simulate_cell(SweepCell(engine="legacy", **base))
    for f in ("hits", "late", "faults", "pages_migrated", "prefetch_issued"):
        assert vec[f] == legacy[f]
    assert vec["cycles"] == pytest.approx(legacy["cycles"], rel=1e-6)
    # the backend that actually ran is recorded, never silent
    assert vec["backend"] == "numpy"
    assert legacy["backend"] == "legacy"


# ---------------------------------------------------------------------------
# backend scheduling: pallas lane batches + visible fallbacks
# ---------------------------------------------------------------------------

INT_ROW_FIELDS = ("n_accesses", "hits", "late", "faults", "prefetch_issued",
                  "prefetch_used", "pages_migrated", "pages_evicted")


def _backend_grid(backend):
    return expand_grid(BENCHES, ["none", "block"], scales=[0.25],
                       device_fracs=[None, 0.6], backend=backend)


def test_backend_axis_distinguishes_cells():
    keys = {c.key() for b in ("auto", "numpy", "pallas")
            for c in _backend_grid(b)}
    assert len(keys) == 3 * len(_backend_grid("auto"))


def test_sweep_pallas_grid_matches_numpy(tmp_path):
    """A >=8-cell grid replayed as ONE pallas lane batch produces rows
    identical (integer counters exact, floats to golden tolerance) to the
    NumPy backend, with the backend recorded per row."""
    from repro.uvm.replay_core import ReplayRequest, get_backend
    from repro.uvm.sweep import prepare_cell

    cells_p = _backend_grid("pallas")
    assert len(cells_p) >= 8
    # the whole grid packs into a single multi-lane kernel launch
    backend = get_backend("pallas")
    requests = []
    for cell in cells_p:
        trace, config, prefetcher, _ = prepare_cell(cell)
        requests.append(ReplayRequest(trace, prefetcher, config))
    assert all(backend.can_replay(r) for r in requests)
    assert len(backend.pack_lanes(requests)) == 1

    rows_p = run_sweep(cells_p, out_dir=str(tmp_path / "pallas"), workers=1)
    rows_n = run_sweep(_backend_grid("numpy"),
                       out_dir=str(tmp_path / "numpy"), workers=1)
    assert [r["backend"] for r in rows_p] == ["pallas"] * len(rows_p)
    assert [r["backend"] for r in rows_n] == ["numpy"] * len(rows_n)
    for got, want in zip(rows_p, rows_n):
        for f in INT_ROW_FIELDS:
            assert got[f] == want[f], f
        assert got["cycles"] == pytest.approx(want["cycles"], rel=1e-6)
        assert got["pcie_bytes"] == pytest.approx(want["pcie_bytes"],
                                                  rel=1e-6)
        assert got["hit_rate"] == pytest.approx(want["hit_rate"], rel=1e-6)


def test_sweep_mixed_family_grid_runs_on_lanes(tmp_path):
    """A grid interleaving every non-learned prefetcher family under
    --backend pallas replays every cell on the lanes (family-homogeneous
    batches), with rows matching the NumPy backend."""
    cells_p = expand_grid(BENCHES, ["none", "tree", "oracle", "block"],
                          scales=[0.25], device_fracs=[None, 0.6],
                          backend="pallas")
    rows_p = run_sweep(cells_p, out_dir=str(tmp_path / "pallas"), workers=1)
    assert [r["backend"] for r in rows_p] == ["pallas"] * len(rows_p)
    cells_n = expand_grid(BENCHES, ["none", "tree", "oracle", "block"],
                          scales=[0.25], device_fracs=[None, 0.6],
                          backend="numpy")
    rows_n = run_sweep(cells_n, out_dir=str(tmp_path / "numpy"), workers=1)
    for got, want in zip(rows_p, rows_n):
        for f in INT_ROW_FIELDS:
            assert got[f] == want[f], (got["bench"], got["prefetcher"], f)
        assert got["cycles"] == pytest.approx(want["cycles"], rel=1e-6)


def test_sweep_policy_grid_runs_on_lanes(tmp_path):
    """A grid crossing every eviction policy under --backend pallas
    replays every cell on the lanes (policy-homogeneous batches), rows
    record the policy, and the numpy backend agrees per cell."""
    kw = dict(scales=[0.25], device_fracs=[0.5],
              evictions=["lru", "random", "hotcold"])
    cells_p = expand_grid(BENCHES, ["none", "tree"], backend="pallas", **kw)
    rows_p = run_sweep(cells_p, out_dir=str(tmp_path / "pallas"), workers=1)
    assert [r["backend"] for r in rows_p] == ["pallas"] * len(rows_p)
    assert [r["eviction"] for r in rows_p] == \
        [c.eviction for c in cells_p]
    assert {r["eviction"] for r in rows_p} == {"lru", "random", "hotcold"}
    rows_n = run_sweep(expand_grid(BENCHES, ["none", "tree"],
                                   backend="numpy", **kw),
                       out_dir=str(tmp_path / "numpy"), workers=1)
    for got, want in zip(rows_p, rows_n):
        for f in INT_ROW_FIELDS:
            assert got[f] == want[f], (got["bench"], got["prefetcher"],
                                       got["eviction"], f)
        assert got["cycles"] == pytest.approx(want["cycles"], rel=1e-6)


def test_sweep_pallas_fallback_is_recorded(tmp_path, monkeypatch):
    """Cells the lanes decline under --backend pallas fall back per cell
    to the NumPy path and the row says so instead of reading as
    covered."""
    from repro.uvm.backends.pallas_backend import PallasReplayBackend

    monkeypatch.setattr(PallasReplayBackend, "can_replay",
                        lambda self, request: False)
    cells = expand_grid(["ATAX"], ["tree"], scales=[0.25], backend="pallas")
    rows = run_sweep(cells, out_dir=str(tmp_path / "out"), workers=1)
    assert rows[0]["backend"] == "numpy"


def test_sweep_pallas_runtime_failure_degrades_per_cell(tmp_path,
                                                        monkeypatch):
    """A lane batch that dies at runtime (not structurally) must not abort
    the grid: affected cells replay per cell on the NumPy path and their
    rows say so."""
    from repro.uvm.backends.pallas_backend import PallasReplayBackend

    def _boom(self, requests):
        raise RuntimeError("synthetic kernel failure")

    monkeypatch.setattr(PallasReplayBackend, "replay", _boom)
    cells = _backend_grid("pallas")[:4]
    with pytest.warns(RuntimeWarning, match="lane batch failed"):
        rows = run_sweep(cells, out_dir=str(tmp_path / "out"), workers=1)
    assert [r["backend"] for r in rows] == ["numpy"] * len(rows)
    want = run_sweep(_backend_grid("numpy")[:4],
                     out_dir=str(tmp_path / "ref"), workers=1)
    for got, ref in zip(rows, want):
        for f in INT_ROW_FIELDS:
            assert got[f] == ref[f], f


def test_sweep_pallas_resume_skips_lane_batches(tmp_path, monkeypatch):
    """Resumed pallas grids read persisted cells — no kernel relaunch."""
    import repro.uvm.sweep as sweep_mod

    out = str(tmp_path / "out")
    cells = _backend_grid("pallas")[:4]
    first = run_sweep(cells, out_dir=out, workers=1)

    def _boom(*a, **k):
        raise AssertionError("resume must not replay any lane batch")

    monkeypatch.setattr(sweep_mod, "_run_lane_batches", _boom)
    monkeypatch.setattr(sweep_mod, "simulate_cell", _boom)
    resumed = run_sweep(cells, out_dir=out, workers=1)
    assert _strip_timing(resumed) == _strip_timing(first)


# ---------------------------------------------------------------------------
# train-once learned cells
# ---------------------------------------------------------------------------

LEARNED_STEPS = 20


def _learned_grid():
    """1 trace x 3 prediction_us x 2 device_frac — the Fig 10-style
    sensitivity grid whose learned variants must share one training run."""
    return expand_grid(["ATAX"], ["learned"], scales=[0.25],
                       prediction_us=[1.0, 2.0, 5.0],
                       device_fracs=[None, 0.5],
                       service_steps=LEARNED_STEPS)


def test_learned_grid_trains_once_and_beats_retrain(tmp_path, monkeypatch):
    """A (trace x prediction_us x device_frac) learned grid invokes
    PredictorService.fit exactly once, and the cached grid is >=3x faster
    end to end than the retrain-per-cell baseline."""
    from repro.core.service import PredictorService

    fit_calls = []
    orig_fit = PredictorService.fit

    def counting_fit(self, *args, **kwargs):
        fit_calls.append(1)
        return orig_fit(self, *args, **kwargs)

    monkeypatch.setattr(PredictorService, "fit", counting_fit)
    cells = _learned_grid()
    assert len(cells) == 6

    # warm jit (train.step_fn recompiles per fit; the apply cache persists)
    predcache.clear_memo()
    monkeypatch.setenv("REPRO_PREDCACHE", "0")
    simulate_cell(cells[0])
    fit_calls.clear()

    # retrain-per-cell baseline: cache disabled, one training run per cell
    t0 = time.monotonic()
    base_rows = run_sweep(cells, out_dir=str(tmp_path / "base"), workers=1)
    t_base = time.monotonic() - t0
    assert len(fit_calls) == len(cells)

    # train-once grid: one fit, every variant reuses the cached array
    monkeypatch.setenv("REPRO_PREDCACHE", "1")
    predcache.clear_memo()
    fit_calls.clear()
    t0 = time.monotonic()
    rows = run_sweep(cells, out_dir=str(tmp_path / "cached"), workers=1)
    t_cached = time.monotonic() - t0
    assert len(fit_calls) == 1

    # identical replay knobs per cell -> identical rows (training is
    # deterministic, so sharing the array cannot change any result)
    assert _strip_timing(rows) == _strip_timing(base_rows)
    assert t_base >= 3.0 * t_cached, (
        f"train-once grid not >=3x faster: baseline {t_base:.2f}s "
        f"vs cached {t_cached:.2f}s")

    # the shared array landed in the on-disk cache next to the traces
    pred_dir = os.path.join(str(tmp_path / "cached"), "trace_cache",
                            predcache.DEFAULT_SUBDIR)
    assert [f for f in os.listdir(pred_dir) if f.startswith("preds_")]
    predcache.clear_memo()


def test_learned_resume_needs_no_training(tmp_path, monkeypatch):
    """Resuming a completed learned grid reads persisted cells — nothing is
    re-simulated, so in particular nothing retrains."""
    import repro.uvm.sweep as sweep_mod

    predcache.clear_memo()
    out = str(tmp_path / "out")
    cells = _learned_grid()[:2]
    first = run_sweep(cells, out_dir=out, workers=1)

    def _boom(*a, **k):
        raise AssertionError("resume must not re-simulate any cell")

    # guard the whole cell path: a memo/disk prediction hit could mask a
    # broken resume if we only watched PredictorService.fit
    monkeypatch.setattr(sweep_mod, "simulate_cell", _boom)
    predcache.clear_memo()
    resumed = run_sweep(cells, out_dir=out, workers=1)
    assert _strip_timing(resumed) == _strip_timing(first)
    predcache.clear_memo()


# ---------------------------------------------------------------------------
# crash safety: checksummed cell store, leases, retries, quarantine
# ---------------------------------------------------------------------------

def _strip_volatile(rows):
    from repro.uvm.faults import VOLATILE_ROW_FIELDS
    return [{k: v for k, v in r.items() if k not in VOLATILE_ROW_FIELDS}
            for r in rows]


def test_cell_row_envelope_rejects_corruption_and_versions(tmp_path):
    path = str(tmp_path / "cell.json")
    row = {"bench": "ATAX", "hit_rate": 0.5}
    write_cell_row(path, row)
    assert load_cell_row(path) == (row, "ok")

    # payload edited without the checksum: corrupt, never served
    with open(path) as f:
        text = f.read()
    with open(path, "w") as f:
        f.write(text.replace("0.5", "0.9"))
    assert load_cell_row(path) == (None, "corrupt")

    # truncation (torn write surviving a crashed rename-less writer)
    write_cell_row(path, row)
    with open(path, "r+") as f:
        f.truncate(os.path.getsize(path) // 2)
    assert load_cell_row(path) == (None, "corrupt")

    # foreign SWEEP_VERSION envelopes and pre-envelope flat rows are
    # "version", not "ok" — a version bump invalidates old grids
    write_cell_row(path, row)
    with open(path) as f:
        doc = json.load(f)
    doc["_v"] = 99
    with open(path, "w") as f:
        json.dump(doc, f)
    assert load_cell_row(path) == (None, "version")
    with open(path, "w") as f:
        json.dump(row, f)                  # legacy flat row, no envelope
    assert load_cell_row(path) == (None, "version")

    assert load_cell_row(str(tmp_path / "nope.json")) == (None, "missing")


def test_resume_requeues_invalid_cell_files(tmp_path):
    """Satellite: a truncated/corrupt/cross-version cell file warns, is
    quarantined aside, and its cell recomputes — resume never raises and
    never trusts bad bytes."""
    out = str(tmp_path / "out")
    cells = _small_cells()
    full = run_sweep(cells, out_dir=out, workers=1)
    paths = [os.path.join(out, "cells", f"{c.key()}.json") for c in cells]

    with open(paths[0], "r+") as f:        # torn write
        f.truncate(os.path.getsize(paths[0]) // 2)
    with open(paths[1], "w") as f:         # garbage bytes
        f.write("not json{{{")
    with open(paths[2], "w") as f:         # pre-envelope flat row
        json.dump(full[2], f)

    with pytest.warns(RuntimeWarning, match="quarantining"):
        resumed = run_sweep(cells, out_dir=out, workers=1)
    assert _strip_volatile(resumed) == _strip_volatile(full)
    for p in paths[:3]:
        assert os.path.exists(p + ".corrupt")     # evidence kept aside
        assert load_cell_row(p) == (load_cell_row(p)[0], "ok")


def test_worker_sigkill_mid_cell_and_mid_write_converges(tmp_path,
                                                         monkeypatch):
    """Satellite: SIGKILL a lease worker mid-cell and another mid
    cell-file write; the pool restarts workers, reclaims the dead pids'
    leases, and the grid is byte-identical to a fault-free run."""
    from repro.uvm import faults

    cells = _small_cells(backend="numpy")
    base = run_sweep(cells, out_dir=str(tmp_path / "base"), workers=1)

    plan = faults.FaultPlan(
        seed=3, ledger_dir=str(tmp_path / "ledger"), specs=(
            faults.FaultSpec("cell.start", "kill", prob=1.0, max_count=1,
                             match=cells[1].key()),
            faults.FaultSpec("cell.result.write", "kill", prob=1.0,
                             max_count=1, match=cells[2].key()),
        ))
    monkeypatch.setenv(faults.FAULT_PLAN_ENV, plan.to_json())
    faults.reset()
    try:
        rows = run_sweep(cells, out_dir=str(tmp_path / "chaos"), workers=2)
    finally:
        monkeypatch.delenv(faults.FAULT_PLAN_ENV)
        faults.reset()

    assert _strip_volatile(rows) == _strip_volatile(base)
    assert all(r["quarantined"] is False for r in rows)
    assert all(isinstance(r["retries"], int) for r in rows)
    # both sabotaged cells needed a second lease claim
    assert rows[1]["retries"] >= 1
    assert rows[2]["retries"] >= 1
    assert faults.rows_digest(rows) == faults.rows_digest(base)


def test_unrecoverable_cell_quarantines_instead_of_aborting(tmp_path,
                                                            monkeypatch):
    """A cell that fails every attempt lands in the quarantine manifest
    as a stub row after capped retries — the rest of the grid completes,
    and a resumed sweep reloads the verdict without recomputing."""
    from repro.uvm import faults

    cells = _small_cells(backend="numpy")
    victim = cells[0].key()
    plan = faults.FaultPlan(seed=0, specs=(
        faults.FaultSpec("cell.start", "raise", prob=1.0, max_count=None,
                         match=victim),))
    monkeypatch.setenv(faults.FAULT_PLAN_ENV, plan.to_json())
    faults.reset()
    out = str(tmp_path / "out")
    try:
        with pytest.warns(RuntimeWarning, match="quarantined"):
            rows = run_sweep(cells, out_dir=out, workers=1, max_attempts=2)

        assert rows[0]["quarantined"] is True
        assert rows[0]["hit_rate"] is None and rows[0]["ipc"] is None
        assert rows[0]["retries"] == 1            # 2 attempts = 1 retry
        assert rows[0]["bench"] == cells[0].bench
        assert all(r["quarantined"] is False for r in rows[1:])
        assert all(r["hit_rate"] is not None for r in rows[1:])

        with open(os.path.join(out, "quarantine.json")) as f:
            manifest = json.load(f)
        assert len(manifest["cells"]) == 1
        assert manifest["cells"][0]["key"] == victim
        assert manifest["cells"][0]["errors"]     # the injected raises

        # resume: the verdict is loaded, not recomputed
        resumed = run_sweep(cells, out_dir=out, workers=1, max_attempts=2)
        assert _strip_volatile(resumed) == _strip_volatile(rows)

        # CSV round-trip keeps the new bool/int columns typed
        csv_rows = read_results_csv(os.path.join(out, "results.csv"))
        assert csv_rows[0]["quarantined"] is True
        assert csv_rows[1]["quarantined"] is False
        assert csv_rows[0]["hit_rate"] is None
    finally:
        monkeypatch.delenv(faults.FAULT_PLAN_ENV)
        faults.reset()

    # resume=False clears the verdict and the cell recovers (the plan is
    # gone): the quarantine is a judgment about past attempts, not fate
    fresh = run_sweep(cells, out_dir=out, workers=1, resume=False)
    assert all(r["quarantined"] is False for r in fresh)
    assert fresh[0]["hit_rate"] is not None


def test_aggregate_results_rebuild_from_cell_store(tmp_path):
    """A torn results.json falls back to the checksummed per-cell store."""
    out = str(tmp_path / "out")
    cells = _small_cells()
    rows = run_sweep(cells, out_dir=out, workers=1)
    agg = os.path.join(out, "results.json")
    with open(agg, "r+") as f:
        f.truncate(os.path.getsize(agg) // 3)
    with pytest.warns(RuntimeWarning, match="rebuilding"):
        back = read_results(out)
    key = lambda r: (r["bench"], r["prefetcher"], str(r["device_frac"]))
    assert sorted(map(key, back)) == sorted(map(key, rows))
    assert {json.dumps(r, sort_keys=True) for r in back} \
        == {json.dumps(r, sort_keys=True) for r in rows}


# ---------------------------------------------------------------------------
# trace memo: checksum once per (path, sha); cold-read quarantine unchanged
# ---------------------------------------------------------------------------

def _trace_cache_file(cache):
    names = [f for f in os.listdir(cache)
             if f.startswith("trace_") and not f.endswith(".corrupt")]
    assert len(names) == 1, names
    return os.path.join(cache, names[0])


def _clobber_middle(path):
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)
        f.write(b"\xde\xad\xbe\xef" * 16)


def test_trace_memo_checksums_once_per_path(tmp_path):
    """Within a process the npz cache is opened and hashed once per
    (path, sha): a file corrupted *after* the first verified read is never
    re-read, so memoized loads serve the verified trace with no
    quarantine.  A cold reader (fresh memo) still quarantines and
    regenerates — the PR 7 crash-safety path is unchanged."""
    from repro.uvm.sweep import _trace_memo
    cache = str(tmp_path / "cache")
    t1 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)
    path = _trace_cache_file(cache)
    _trace_memo.clear()
    t2 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)  # disk, verified
    np.testing.assert_array_equal(t1.accesses, t2.accesses)

    _clobber_middle(path)
    t3 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)
    assert t3 is t2                      # memo hit: no re-open, no re-hash
    assert not os.path.exists(path + ".corrupt")

    _trace_memo.clear()                  # simulate a fresh process
    with pytest.warns(RuntimeWarning, match="quarantining"):
        t4 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)
    assert os.path.exists(path + ".corrupt")
    np.testing.assert_array_equal(t4.accesses, t1.accesses)


def test_trace_memo_disabled_rereads_disk(tmp_path, monkeypatch):
    """REPRO_TRACE_MEMO=0 restores the read-per-call behavior: disk
    corruption is caught on the very next load."""
    from repro.uvm.sweep import _trace_memo
    monkeypatch.setenv("REPRO_TRACE_MEMO", "0")
    _trace_memo.clear()
    cache = str(tmp_path / "cache")
    t1 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)
    path = _trace_cache_file(cache)
    _clobber_middle(path)
    with pytest.warns(RuntimeWarning, match="quarantining"):
        t2 = load_trace("ATAX", 0.25, 0, 0.6, cache_dir=cache)
    np.testing.assert_array_equal(t2.accesses, t1.accesses)


# ---------------------------------------------------------------------------
# model-family axis + adaptive eviction resolution
# ---------------------------------------------------------------------------

def test_grid_model_family_axis():
    """model_families is a first-class grid axis: cells carry it, key on
    it, and rows record it."""
    cells = expand_grid(["ATAX"], ["learned"], scales=[0.25],
                        model_families=["simplified", "transformer"])
    assert len(cells) == 2
    assert [c.model_family for c in cells] == ["simplified", "transformer"]
    assert len({c.key() for c in cells}) == 2
    assert "model_family" in ROW_FIELDS


def test_row_records_model_family(monkeypatch):
    """The learned cell hands its family to predcache (so training keys
    on the model identity) and the row records which family replayed."""
    from repro.uvm import predcache as predcache_mod

    seen = []

    def fake_get_or_train(trace, *, steps, cache_dir=None,
                          service_kwargs=None, **kw):
        seen.append(dict(service_kwargs or {}, steps=steps))
        return np.full(len(trace.accesses), -1, dtype=np.int64)

    monkeypatch.setattr(predcache_mod, "get_or_train", fake_get_or_train)
    row = simulate_cell(SweepCell("ATAX", "learned", scale=0.25,
                                  model_family="transformer",
                                  service_steps=5))
    assert seen == [{"model_family": "transformer", "steps": 5}]
    assert row["model_family"] == "transformer"
    # non-learned cells default to (and record) the simplified family
    base = simulate_cell(SweepCell("ATAX", "none", scale=0.25))
    assert base["model_family"] == "simplified"


def test_adaptive_cell_resolves_to_concrete_policy(tmp_path, monkeypatch):
    """An adaptive cell resolves at prepare time — the row's eviction
    column records the concrete policy that replayed, never the
    ``adaptive`` literal, and a selector table pins the choice."""
    from repro.uvm import adaptive

    adaptive.clear_memo()
    row = simulate_cell(SweepCell("ATAX", "none", scale=0.25,
                                  device_frac=0.5, eviction="adaptive"))
    from repro.uvm.eviction import EVICTION_POLICIES
    assert row["eviction"] in EVICTION_POLICIES

    table = tmp_path / "table.json"
    table.write_text(json.dumps({"ATAX": "hotcold"}))
    monkeypatch.setenv("REPRO_ADAPTIVE_TABLE", str(table))
    pinned = simulate_cell(SweepCell("ATAX", "none", scale=0.25,
                                     device_frac=0.5, eviction="adaptive"))
    assert pinned["eviction"] == "hotcold"
    # no pressure -> every policy is a no-op -> canonical lru
    monkeypatch.delenv("REPRO_ADAPTIVE_TABLE")
    free = simulate_cell(SweepCell("Pathfinder", "none", scale=0.25,
                                   eviction="adaptive"))
    assert free["eviction"] == "lru"
    adaptive.clear_memo()


def test_selector_from_rows_picks_cheapest_per_bench():
    from repro.uvm.adaptive import selector_from_rows

    rows = [
        {"bench": "A", "eviction": "lru", "cycles": 300},
        {"bench": "A", "eviction": "random", "cycles": 100},
        {"bench": "A", "eviction": "hotcold", "cycles": 200},
        # bench B: two rows per policy -> mean decides
        {"bench": "B", "eviction": "lru", "cycles": 100},
        {"bench": "B", "eviction": "lru", "cycles": 300},
        {"bench": "B", "eviction": "hotcold", "cycles": 150},
        {"bench": "B", "eviction": "hotcold", "cycles": 150},
        # ties break in EVICTION_POLICIES order (lru first)
        {"bench": "C", "eviction": "random", "cycles": 50},
        {"bench": "C", "eviction": "lru", "cycles": 50},
        # quarantined rows (no cycles) and adaptive literals are ignored
        {"bench": "D", "eviction": "lru", "cycles": None},
        {"bench": "D", "eviction": "adaptive", "cycles": 10},
    ]
    assert selector_from_rows(rows) == {"A": "random", "B": "hotcold",
                                        "C": "lru"}


def test_adaptive_table_parsed_once_per_mtime(tmp_path, monkeypatch):
    """The selector table is parsed once per (path, mtime): prepare-stage
    threads resolving thousands of cells must not re-read + re-parse the
    JSON per cell.  Editing the file (new mtime) invalidates the cache;
    an unreadable path fails loudly with the env var named."""
    import repro.uvm.adaptive as adaptive

    adaptive.clear_memo()
    table = tmp_path / "table.json"
    table.write_text(json.dumps({"ATAX": "hotcold"}))
    monkeypatch.setenv("REPRO_ADAPTIVE_TABLE", str(table))

    opens = []
    real_open = open

    def counting_open(path, *a, **kw):
        if str(path) == str(table):
            opens.append(path)
        return real_open(path, *a, **kw)

    # adaptive._table reads via the open builtin resolved in its module
    monkeypatch.setattr(adaptive, "open", counting_open, raising=False)
    for _ in range(5):
        assert adaptive.resolve_eviction("adaptive", "ATAX") == "hotcold"
    assert len(opens) == 1                 # parsed once, served 5x

    # content change (bump mtime explicitly: coarse filesystem
    # timestamps could otherwise collide) -> one re-parse
    table.write_text(json.dumps({"ATAX": "random"}))
    st = os.stat(table)
    os.utime(table, ns=(st.st_atime_ns, st.st_mtime_ns + 1))
    assert adaptive.resolve_eviction("adaptive", "ATAX") == "random"
    assert adaptive.resolve_eviction("adaptive", "ATAX") == "random"
    assert len(opens) == 2

    monkeypatch.setenv("REPRO_ADAPTIVE_TABLE", str(tmp_path / "gone.json"))
    with pytest.raises(FileNotFoundError, match="REPRO_ADAPTIVE_TABLE"):
        adaptive.resolve_eviction("adaptive", "ATAX")
    adaptive.clear_memo()


def test_adaptive_probe_keyed_by_prefetcher_family(monkeypatch):
    """The probe replays under the cell's prefetcher-family proxy and the
    memo keys on it: a tree cell must not be resolved from demand-paging
    behavior, while oracle and learned cells share one oracle probe."""
    from repro.uvm import adaptive
    from repro.uvm.eviction import EVICTION_POLICIES

    assert adaptive.probe_proxy(None) == "none"
    assert adaptive.probe_proxy("none") == "none"
    assert adaptive.probe_proxy("block") == "block"
    assert adaptive.probe_proxy("tree") == "tree"
    assert adaptive.probe_proxy("oracle") == "oracle"
    assert adaptive.probe_proxy("learned") == "oracle"

    trace = load_trace("ATAX", 0.25, 0, 0.6)
    cap = trace.working_set_pages // 2
    probes = []
    orig_probe = adaptive._probe

    def counting_probe(tr, device_pages, probe_accesses, proxy="none"):
        probes.append(proxy)
        return orig_probe(tr, device_pages, probe_accesses, proxy)

    monkeypatch.setattr(adaptive, "_probe", counting_probe)
    monkeypatch.delenv("REPRO_ADAPTIVE_TABLE", raising=False)
    adaptive.clear_memo()
    kw = dict(trace=trace, device_pages=cap, probe_accesses=2000)
    for pf in ("none", "tree", "oracle", "learned", "tree", "none"):
        got = adaptive.resolve_eviction("adaptive", "ATAX", prefetcher=pf,
                                        **kw)
        assert got in EVICTION_POLICIES
    # one probe per distinct proxy family; learned reused oracle's and
    # the repeats hit the memo
    assert probes == ["none", "tree", "oracle"]
    adaptive.clear_memo()


# ---------------------------------------------------------------------------
# serve rows: SLO columns come from in-band step clocks (slo_source)
# ---------------------------------------------------------------------------

def test_serve_rows_slo_source_kernel(tmp_path):
    """Serve rows derive their SLO columns from the step clocks the
    primary replay already produced (``slo_source="kernel"`` — in-kernel
    on the pallas lanes, host-side on numpy); the PR 6 double-replay
    side pass only fires when a row arrives without clocks.  Both
    backends must emit bit-identical latency columns."""
    cells = [SweepCell(bench="ServeDecode", prefetcher="none", scale=0.1,
                       window=None, device_frac=0.5, engine="vectorized",
                       backend=be)
             for be in ("numpy", "pallas")]
    rows = run_sweep(cells, out_dir=str(tmp_path / "out"), workers=1)
    assert [r["backend"] for r in rows] == ["numpy", "pallas"]
    lat = ("decode_lat_p50_us", "decode_lat_p95_us", "decode_lat_p99_us",
           "ttft_p50_us", "ttft_p95_us", "ttft_p99_us")
    for r in rows:
        assert r["slo_source"] == "kernel"
        for f in lat:
            assert isinstance(r[f], float) and r[f] > 0.0, f
        assert (r["decode_lat_p50_us"] <= r["decode_lat_p95_us"]
                <= r["decode_lat_p99_us"])
    for f in lat:                       # lanes == host math, bitwise
        assert rows[0][f] == rows[1][f], f
