"""HLSH / LSH / full attention semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as A


def test_full_attention_softmax_rows():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    out = A.full_attention(q, q, q)
    assert out.shape == q.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_hlsh_plan_invariants():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 30, 12)), jnp.float32)
    plan = A.hlsh_plan(x, jax.random.PRNGKey(0))
    assert plan.keep.shape == (4, 30)
    assert plan.keep.dtype == jnp.bool_
    src = np.asarray(plan.share_src)
    assert src.min() >= 0 and src.max() < 30
    # non-shared rows map to themselves
    keep = np.asarray(plan.keep)
    idx = np.arange(30)[None, :]
    self_rows = src == idx
    assert (self_rows | ~keep | self_rows).all()


def test_hlsh_identical_rows_share():
    """Duplicate rows must hash identically -> at most one representative
    survives among the near-duplicates."""
    rng = np.random.default_rng(2)
    row = rng.normal(size=(1, 1, 16))
    x = jnp.asarray(np.repeat(np.repeat(row, 32, axis=1), 2, axis=0),
                    jnp.float32)
    plan = A.hlsh_plan(x, jax.random.PRNGKey(3))
    # all rows identical -> hamming distance 0 -> all "low" -> one base kept
    keep = np.asarray(plan.keep)
    assert keep.sum(axis=1).max() <= 1


def test_hlsh_apply_matches_direct():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
    plan = A.hlsh_plan(q, jax.random.PRNGKey(0))
    out = A.hlsh_apply(q, q, v, plan)
    # direct recomputation
    keep = plan.keep[..., None].astype(q.dtype)
    logits = jnp.einsum("bnd,bmd->bnm", q * keep, q * keep) / jnp.sqrt(8.0)
    want = jnp.einsum("bnm,bmd->bnd", jax.nn.softmax(logits, -1), v)
    want = jnp.take_along_axis(want, plan.share_src[..., None], axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_lsh_attention_close_to_full_when_one_bucket():
    rng = np.random.default_rng(4)
    # nearly-identical vectors all collide -> lsh == full
    base = rng.normal(size=(1, 1, 8))
    x = jnp.asarray(np.repeat(base, 10, axis=1) +
                    rng.normal(size=(1, 10, 8)) * 1e-3, jnp.float32)
    full = A.full_attention(x, x, x)
    lsh = A.lsh_attention(x, x, x, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(full), np.asarray(lsh), atol=1e-3)


def test_erased_fraction():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(8, 30, 12)), jnp.float32)
    plan = A.hlsh_plan(x, jax.random.PRNGKey(1))
    f = float(A.hlsh_erased_fraction(plan))
    assert 0.0 <= f < 1.0
