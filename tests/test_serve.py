"""Serving-traffic trace source + replay-core step-clock support.

Covers the serve-* scenario family end to end: load-generator
determinism, the block <-> page / step <-> kernel encoding invariants,
``step_bounds`` replay support (legacy, numpy, and the pallas lanes —
which capture ``step_clocks`` in-kernel — must agree bitwise), the SLO
latency columns, scenario registration, and sweep-row integration.
"""
import numpy as np
import pytest

from repro.offload.serve_trace import (SERVE_WORKLOADS, build_serve_trace,
                                       drive_workload, episode_to_trace,
                                       get_serve_workload, is_serve_bench,
                                       is_serve_trace, load_trace_npz,
                                       save_trace_npz,
                                       serve_latency_columns,
                                       trace_step_bounds,
                                       trace_to_access_log)
from repro.uvm import UVMConfig
from repro.uvm.golden import make_prefetcher
from repro.uvm.replay_core import ReplayRequest, get_backend

LAT_FIELDS = ("decode_lat_p50_us", "decode_lat_p95_us", "decode_lat_p99_us",
              "ttft_p50_us", "ttft_p95_us", "ttft_p99_us")

ALL_BENCHES = tuple(SERVE_WORKLOADS) + ("ServeBursty@r128",)


def _replay(trace, backend_name, pf_name="none", cap=None, eviction="lru",
            with_bounds=True):
    config = UVMConfig(device_pages=cap, eviction=eviction)
    request = ReplayRequest(
        trace, make_prefetcher(pf_name, trace, config), config,
        step_bounds=trace_step_bounds(trace) if with_bounds else None)
    backend = get_backend(backend_name)
    assert backend.can_replay(request)
    return backend.replay([request])[0]


# ---------------------------------------------------------------------------
# load generator + encoding
# ---------------------------------------------------------------------------

def test_bench_name_resolution():
    assert is_serve_bench("ServeDecode")
    assert is_serve_bench("ServeBursty@r128")
    assert not is_serve_bench("ATAX")
    assert not is_serve_bench("ServeBursty@x9")
    wl = get_serve_workload("ServeBursty@r128")
    assert wl.arrival == "open" and wl.rate_rps == 128.0
    with pytest.raises(KeyError):
        get_serve_workload("ServeNope")


@pytest.mark.parametrize("bench", ALL_BENCHES)
def test_serve_trace_encoding_invariants(bench):
    trace = build_serve_trace(bench, scale=0.25, seed=0)
    assert is_serve_trace(trace)
    sv = trace.meta["serve"]
    kern = trace.accesses["kernel"].astype(np.int64)
    assert np.all(np.diff(kern) >= 0), "access stream must be step-major"
    bounds = trace_step_bounds(trace)
    assert bounds.size == sv["n_steps"]
    assert np.all(np.diff(bounds) >= 0)
    assert int(bounds[-1]) == len(trace)
    # every page decodes back into a (request, block) inside the regions
    log = trace_to_access_log(trace)
    req = np.asarray([r for r, _ in log])
    blk = np.asarray([b for _, b in log])
    assert req.min() >= 0 and req.max() < sv["n_requests"]
    assert blk.min() >= 0 and blk.max() < sv["blocks_per_seq"]
    # the 'array' feature is the request id (learned-prefetcher input)
    assert np.array_equal(trace.accesses["array"].astype(np.int64), req)


def test_serve_trace_deterministic():
    a = build_serve_trace("ServeTenantMix", scale=0.25, seed=3)
    b = build_serve_trace("ServeTenantMix", scale=0.25, seed=3)
    assert a.accesses.tobytes() == b.accesses.tobytes()
    assert a.meta == b.meta
    c = build_serve_trace("ServeTenantMix", scale=0.25, seed=4)
    assert a.accesses.tobytes() != c.accesses.tobytes()


def test_episode_round_trips_to_access_log():
    ep = drive_workload(SERVE_WORKLOADS["ServeDecode"], scale=0.1, seed=1)
    trace = episode_to_trace(ep, seed=1)
    log = trace_to_access_log(trace)
    assert log == list(zip(ep.req.tolist(), ep.blk.tolist()))


def test_bursty_arrivals_gate_first_decode():
    ep = drive_workload(SERVE_WORKLOADS["ServeBursty"], scale=0.25, seed=0)
    assert np.all(ep.first_steps >= ep.arrival_steps)
    assert ep.arrival_steps.max() > 0          # open loop really spreads
    # slots bound concurrency: no step sweeps more than `slots` requests
    wl = SERVE_WORKLOADS["ServeBursty"]
    for s in np.unique(ep.step):
        assert np.unique(ep.req[ep.step == s]).size <= wl.slots


def test_npz_round_trip(tmp_path):
    trace = build_serve_trace("ServeDecode", scale=0.1, seed=0)
    path = str(tmp_path / "serve.npz")
    save_trace_npz(trace, path)
    back = load_trace_npz(path)
    assert back.accesses.tobytes() == trace.accesses.tobytes()
    assert back.meta == trace.meta
    assert back.array_bases == trace.array_bases
    assert back.n_instructions == trace.n_instructions


# ---------------------------------------------------------------------------
# step_bounds replay support
# ---------------------------------------------------------------------------

def test_step_clocks_legacy_numpy_bitwise():
    """The per-step completion clocks (the latency columns' input) must be
    bit-identical between the legacy loop and the vectorized numpy
    backend, with and without oversubscription."""
    trace = build_serve_trace("ServeDecode", scale=0.25, seed=0)
    for cap, pf in ((None, "none"), (120, "block")):
        legacy = _replay(trace, "legacy", pf_name=pf, cap=cap)
        vector = _replay(trace, "numpy", pf_name=pf, cap=cap)
        assert legacy.step_clocks is not None
        assert vector.step_clocks is not None
        assert np.array_equal(legacy.step_clocks, vector.step_clocks)
        assert legacy.hits == vector.hits
        assert legacy.cycles == vector.cycles


def test_step_clocks_shape_and_monotone():
    trace = build_serve_trace("ServeBursty", scale=0.25, seed=0)
    stats = _replay(trace, "numpy")
    clocks = stats.step_clocks
    assert clocks.size == trace.meta["serve"]["n_steps"]
    assert np.all(np.diff(clocks) >= 0)
    assert clocks[-1] == pytest.approx(stats.cycles)


def test_pallas_accepts_step_bounds():
    """The pallas lanes capture step clocks in-kernel, so well-formed
    bounds requests are accepted; malformed bounds are declined so the
    host-side backends raise the canonical ValueError instead."""
    trace = build_serve_trace("ServeDecode", scale=0.1, seed=0)
    config = UVMConfig()
    backend = get_backend("pallas")
    with_bounds = ReplayRequest(trace, make_prefetcher("none", trace, config),
                                config, step_bounds=trace_step_bounds(trace))
    without = ReplayRequest(trace, make_prefetcher("none", trace, config),
                            config)
    assert backend.can_replay(with_bounds)
    assert backend.can_replay(without)
    for bad in (np.array([5, 3], dtype=np.int64),           # decreasing
                np.array([len(trace) + 1], dtype=np.int64),  # overrun
                np.array([], dtype=np.int64),                # empty
                np.zeros((2, 2), dtype=np.int64)):           # not 1-D
        bad_req = ReplayRequest(trace,
                                make_prefetcher("none", trace, config),
                                config, step_bounds=bad)
        assert not backend.can_replay(bad_req)


#: the serve golden cells: every serve workload x eviction policy x
#: demand-family prefetcher at 2x oversubscription — the fixed matrix the
#: in-kernel step-clock capture is pinned bit-equal on
SERVE_GOLDEN_CELLS = [(bench, pol, pf)
                      for bench in ("ServeDecode", "ServeBursty")
                      for pol in ("lru", "random", "hotcold")
                      for pf in ("none", "block")]


@pytest.mark.parametrize("bench,policy,pf", SERVE_GOLDEN_CELLS,
                         ids=[f"{b}-{pol}-{pf}"
                              for b, pol, pf in SERVE_GOLDEN_CELLS])
def test_step_clocks_pallas_bitwise(bench, policy, pf):
    """In-kernel step clocks (and every counter) are bit-identical to the
    numpy replay on every serve golden cell."""
    trace = build_serve_trace(bench, scale=0.25, seed=0)
    cap = int(trace.working_set_pages * 0.5)
    lane = _replay(trace, "pallas", pf_name=pf, cap=cap, eviction=policy)
    ref = _replay(trace, "numpy", pf_name=pf, cap=cap, eviction=policy)
    assert lane.backend == "pallas"
    assert lane.step_clocks is not None
    assert np.array_equal(lane.step_clocks, ref.step_clocks)
    for field in ("cycles", "hits", "late", "faults", "prefetch_issued",
                  "prefetch_used", "pages_migrated", "pages_evicted",
                  "pcie_bytes"):
        assert getattr(lane, field) == getattr(ref, field), field


def test_step_clocks_pallas_mixed_batch():
    """One kernel launch can mix lanes with and without bounds: the
    no-bounds lane scatters to the trash slot and reports no clocks."""
    trace = build_serve_trace("ServeDecode", scale=0.1, seed=0)
    config = UVMConfig(device_pages=int(trace.working_set_pages * 0.5))
    bounds = trace_step_bounds(trace)
    with_b = ReplayRequest(trace, make_prefetcher("none", trace, config),
                           config, step_bounds=bounds)
    without = ReplayRequest(trace, make_prefetcher("none", trace, config),
                            config)
    got = get_backend("pallas").replay([with_b, without])
    ref = _replay(trace, "numpy", cap=config.device_pages)
    assert np.array_equal(got[0].step_clocks, ref.step_clocks)
    assert got[1].step_clocks is None
    assert got[0].cycles == got[1].cycles == ref.cycles


def test_bad_step_bounds_rejected():
    trace = build_serve_trace("ServeDecode", scale=0.1, seed=0)
    config = UVMConfig()
    for bad in (np.array([5, 3], dtype=np.int64),          # decreasing
                np.array([len(trace) + 1], dtype=np.int64)):  # overrun
        request = ReplayRequest(trace,
                                make_prefetcher("none", trace, config),
                                config, step_bounds=bad)
        for name in ("legacy", "numpy"):
            with pytest.raises(ValueError):
                get_backend(name).replay([request])


# ---------------------------------------------------------------------------
# latency columns
# ---------------------------------------------------------------------------

def test_latency_columns_sane():
    trace = build_serve_trace("ServeDecode", scale=0.25, seed=0)
    config = UVMConfig(device_pages=120)
    stats = _replay(trace, "numpy", pf_name="block", cap=120)
    row = serve_latency_columns(trace, stats.step_clocks, config)
    assert set(row) == set(LAT_FIELDS)
    for f in LAT_FIELDS:
        assert isinstance(row[f], float) and row[f] > 0.0
    assert (row["decode_lat_p50_us"] <= row["decode_lat_p95_us"]
            <= row["decode_lat_p99_us"])
    assert row["ttft_p50_us"] <= row["ttft_p95_us"] <= row["ttft_p99_us"]
    # TTFT spans at least one decode step of replay time
    assert row["ttft_p50_us"] >= row["decode_lat_p50_us"]


def test_latency_columns_reject_mismatched_clocks():
    trace = build_serve_trace("ServeDecode", scale=0.1, seed=0)
    with pytest.raises(ValueError, match="step_clocks"):
        serve_latency_columns(trace, np.zeros(3), UVMConfig())


# ---------------------------------------------------------------------------
# scenarios + sweep integration
# ---------------------------------------------------------------------------

def test_serve_scenarios_registered():
    from repro.uvm.scenarios import Scenario, get_scenario

    smoke = get_scenario("serve-smoke")
    cells = smoke.cells(backend="pallas")
    assert len(cells) == 24
    assert all(c.window is None for c in cells)
    assert all(is_serve_bench(c.bench) for c in cells)
    get_scenario("serve-full").validate()
    # serve benches with a window split must fail validation
    with pytest.raises(ValueError, match="window=None"):
        Scenario(name="bad", description="", benches=("ServeDecode",),
                 ratios=(0.5,), window=0.6).validate()


def test_sweep_row_carries_latency_columns(tmp_path):
    from repro.uvm.sweep import SweepCell, simulate_cell

    cell = SweepCell(bench="ServeDecode", prefetcher="none", scale=0.25,
                     window=None, device_frac=0.75, eviction="lru",
                     backend="numpy")
    row = simulate_cell(cell, cache_dir=str(tmp_path))
    assert row["backend"] == "numpy"
    for f in LAT_FIELDS:
        assert isinstance(row[f], float) and row[f] > 0.0
    # the npz trace cache round-trips the serve sidecar: second run hits it
    row2 = simulate_cell(cell, cache_dir=str(tmp_path))
    for f in LAT_FIELDS:
        assert row2[f] == row[f]


def test_non_serve_rows_keep_schema():
    from repro.uvm.sweep import SweepCell, simulate_cell

    row = simulate_cell(SweepCell(bench="ATAX", prefetcher="none",
                                  scale=0.25, backend="numpy"))
    for f in LAT_FIELDS:
        assert f in row and row[f] is None
