"""Unit tests for ``repro.uvm.metrics`` — the paper's unified metric
(Unity = cbrt(accuracy x coverage x page-hit-rate), §Table 11), the
geometric mean used by every summary table, and the PCIe-bandwidth
timeline binning behind Fig 12."""
import numpy as np
import pytest

from repro.uvm.metrics import (geomean, pcie_gbs_timeline, slo_percentiles,
                               sorted_percentiles, unity)
from repro.uvm.simulator import UVMStats


# ---------------------------------------------------------------------------
# unity
# ---------------------------------------------------------------------------

def test_unity_is_cbrt_of_product():
    assert unity(1.0, 1.0, 1.0) == 1.0
    assert unity(0.0, 1.0, 1.0) == 0.0
    assert unity(0.5, 0.5, 0.5) == pytest.approx(0.5)
    assert unity(0.9, 0.8, 0.7) == pytest.approx((0.9 * 0.8 * 0.7) ** (1 / 3))


def test_unity_bounded_and_monotone():
    rng = np.random.default_rng(3)
    prev = unity(0.0, 0.5, 0.5)
    for a in np.linspace(0.0, 1.0, 11):
        u = unity(float(a), 0.5, 0.5)
        assert 0.0 <= u <= 1.0
        assert u >= prev            # monotone in each argument
        prev = u
    for _ in range(50):
        a, c, h = rng.uniform(0, 1, 3)
        assert 0.0 <= unity(a, c, h) <= 1.0
    assert isinstance(unity(0.3, 0.3, 0.3), float)


def test_unity_symmetric_in_arguments():
    assert unity(0.2, 0.5, 0.9) == unity(0.9, 0.2, 0.5) == unity(0.5, 0.9,
                                                                 0.2)


def test_stats_unity_property_matches_module():
    """UVMStats.unity (what sweep rows record) is the module's metric of
    its own accuracy/coverage/hit_rate properties."""
    st = UVMStats(name="t", prefetcher="tree", n_accesses=100,
                  n_instructions=1000, cycles=5000.0, hits=60, late=10,
                  faults=30, prefetch_issued=50, prefetch_used=40,
                  pages_migrated=80, pages_evicted=0, pcie_bytes=1.0,
                  zero_copy_bytes=0.0)
    assert st.unity == pytest.approx(
        unity(st.accuracy, st.coverage, st.hit_rate))
    assert st.accuracy == pytest.approx(40 / 50)
    assert st.coverage == pytest.approx(40 / (40 + 30 + 10))
    assert st.hit_rate == pytest.approx(0.6)


# ---------------------------------------------------------------------------
# geomean
# ---------------------------------------------------------------------------

def test_geomean_basics():
    assert geomean([2.0, 8.0]) == pytest.approx(4.0)
    assert geomean([5.0]) == pytest.approx(5.0)
    assert geomean([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    # accepts any iterable, returns a python float
    assert isinstance(geomean(x for x in (1.0, 4.0)), float)
    assert geomean(iter([1.0, 4.0])) == pytest.approx(2.0)


def test_geomean_clamps_nonpositive():
    """Zero/negative entries clamp to 1e-12 instead of nan/-inf — a
    crashed cell drags the mean down but never poisons the summary."""
    g = geomean([0.0, 1.0])
    assert g == pytest.approx(np.sqrt(1e-12))
    assert np.isfinite(geomean([-3.0, 2.0, 0.0]))


def test_geomean_scale_invariance():
    xs = [0.5, 2.0, 8.0]
    assert geomean([4 * x for x in xs]) == pytest.approx(4 * geomean(xs))


# ---------------------------------------------------------------------------
# sorted_percentiles / slo_percentiles
# ---------------------------------------------------------------------------

def test_sorted_percentiles_matches_np_percentile():
    """The shared-sort helper is bit-identical to np.percentile's default
    linear method — including oddly sized and single-element samples."""
    rng = np.random.default_rng(7)
    for n in (1, 2, 3, 7, 100, 1001):
        a = rng.exponential(50.0, size=n)
        got = sorted_percentiles(np.sort(a), (0, 12.5, 50, 95, 99, 100))
        want = np.percentile(a, (0, 12.5, 50, 95, 99, 100))
        assert np.array_equal(got, want)   # exact, not approx


def test_sorted_percentiles_monotone():
    """p50 <= p95 <= p99 on any sample set (monotone in q)."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        a = np.sort(rng.normal(0.0, 1e3, size=rng.integers(1, 64)))
        p50, p95, p99 = sorted_percentiles(a, (50, 95, 99))
        assert p50 <= p95 <= p99


def test_sorted_percentiles_rejects_bad_input():
    with pytest.raises(ValueError):
        sorted_percentiles(np.array([]), (50,))
    with pytest.raises(ValueError):
        sorted_percentiles(np.zeros((2, 2)), (50,))
    with pytest.raises(ValueError):
        sorted_percentiles(np.array([1.0]), (101,))
    with pytest.raises(ValueError):
        sorted_percentiles(np.array([1.0]), (-1,))


def test_sorted_percentiles_rejects_non_finite():
    """np.sort parks NaN at the tail, so a NaN-poisoned clock stream
    would land in the high percentiles and sail through p50<=p99 checks
    (NaN comparisons are all False) — the helper must refuse loudly."""
    with pytest.raises(ValueError, match="non-finite"):
        sorted_percentiles(np.array([1.0, 2.0, np.nan]), (50, 99))
    with pytest.raises(ValueError, match="non-finite"):
        sorted_percentiles(np.array([np.inf]), (50,))
    with pytest.raises(ValueError, match="non-finite"):
        sorted_percentiles(np.array([-np.inf, 3.0]), (50,))
    # the message counts the poisoned samples for triage
    with pytest.raises(ValueError, match="2 of 3"):
        sorted_percentiles(np.array([np.nan, 1.0, np.nan]), (50,))


def test_slo_percentiles_rejects_non_finite():
    with pytest.raises(ValueError, match="non-finite"):
        slo_percentiles([1.0, np.nan, 3.0], "decode_lat")


def test_slo_percentiles_columns():
    row = slo_percentiles([3.0, 1.0, 2.0], "decode_lat")
    assert set(row) == {"decode_lat_p50_us", "decode_lat_p95_us",
                        "decode_lat_p99_us"}
    assert row["decode_lat_p50_us"] == pytest.approx(2.0)
    assert row["decode_lat_p50_us"] <= row["decode_lat_p95_us"] \
        <= row["decode_lat_p99_us"]
    # schema-stable on empty input: same keys, None values
    empty = slo_percentiles([], "ttft")
    assert empty == {"ttft_p50_us": None, "ttft_p95_us": None,
                     "ttft_p99_us": None}


# ---------------------------------------------------------------------------
# pcie_gbs_timeline
# ---------------------------------------------------------------------------

def test_timeline_empty_inputs():
    assert pcie_gbs_timeline(None, core_mhz=1481.0).shape == (0, 2)
    assert pcie_gbs_timeline(np.zeros((0, 2)), core_mhz=1481.0).shape == \
        (0, 2)


def test_timeline_single_window_rate():
    """One 4 KB transfer in one 10k-cycle window: GB/s = bytes / window
    seconds, centered on the window."""
    core_mhz = 1000.0                      # 1 cycle == 1 ns
    tl = np.array([[1234.0, 4096.0]])
    out = pcie_gbs_timeline(tl, core_mhz=core_mhz, window_cycles=10_000.0)
    assert out.shape == (1, 2)
    assert out[0, 0] == pytest.approx(5000.0)          # window center
    secs = 10_000.0 / (core_mhz * 1e6)
    assert out[0, 1] == pytest.approx(4096.0 / secs / 1e9)


def test_timeline_bins_by_window_and_sums_bytes():
    core_mhz = 1481.0
    tl = np.array([
        [100.0, 4096.0], [9999.0, 4096.0],     # window 0: 2 pages
        [10_001.0, 4096.0],                    # window 1: 1 page
        [35_000.0, 8192.0],                    # window 3: 2 pages worth
    ])
    out = pcie_gbs_timeline(tl, core_mhz=core_mhz, window_cycles=10_000.0)
    assert out.shape == (4, 2)                 # through the last window
    np.testing.assert_allclose(out[:, 0],
                               [5000.0, 15000.0, 25000.0, 35000.0])
    secs = 10_000.0 / (core_mhz * 1e6)
    np.testing.assert_allclose(
        out[:, 1],
        np.array([8192.0, 4096.0, 0.0, 8192.0]) / secs / 1e9)


def test_timeline_rejects_bad_stamps_and_window():
    """A negative cycle stamp floor-divides to a negative window index,
    which np.add.at wraps to the *tail* window — the bandwidth spike
    lands at the wrong end of the plot with no error.  Non-finite stamps
    blow up the window count.  Both must be rejected, as must a
    non-positive window."""
    with pytest.raises(ValueError, match="negative or non-finite"):
        pcie_gbs_timeline(np.array([[-1.0, 4096.0]]), core_mhz=1481.0)
    with pytest.raises(ValueError, match="negative or non-finite"):
        pcie_gbs_timeline(np.array([[np.nan, 4096.0], [5.0, 4096.0]]),
                          core_mhz=1481.0)
    with pytest.raises(ValueError, match="negative or non-finite"):
        pcie_gbs_timeline(np.array([[np.inf, 4096.0]]), core_mhz=1481.0)
    # the message counts the offending stamps
    with pytest.raises(ValueError, match="2 of 3"):
        pcie_gbs_timeline(
            np.array([[-2.0, 1.0], [np.nan, 1.0], [7.0, 1.0]]),
            core_mhz=1481.0)
    with pytest.raises(ValueError, match="window_cycles"):
        pcie_gbs_timeline(np.array([[1.0, 4096.0]]), core_mhz=1481.0,
                          window_cycles=0.0)


def test_timeline_total_bytes_conserved():
    """Binning conserves total traffic whatever the window size."""
    rng = np.random.default_rng(11)
    tl = np.stack([np.sort(rng.uniform(0, 1e6, 500)),
                   np.full(500, 4096.0)], axis=1)
    for window in (1_000.0, 10_000.0, 137_000.0):
        out = pcie_gbs_timeline(tl, core_mhz=1481.0, window_cycles=window)
        secs = window / (1481.0 * 1e6)
        total = float(np.sum(out[:, 1] * secs * 1e9))
        assert total == pytest.approx(500 * 4096.0)
