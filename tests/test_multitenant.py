"""Multi-tenant replay plane: trace interleaver, capacity splits, and
the per-tenant eviction-isolation guarantee.

The acceptance bar for the tenancy axis is the *isolation property*: a
tenant whose working set fits inside its hard quota must see a
bit-identical hit count whether its co-tenant is idle or thrashing —
quota + tenant-masked victim selection make the co-tenant invisible to
its residency.  The shared-capacity control shows the interference the
quota removes (the co-tenant's churn evicts the protected tenant's
pages), so the property test cannot pass vacuously.
"""
import dataclasses

import numpy as np
import pytest

from repro.traces.interleave import (N_TENANTS, build_mt_trace, is_mt_bench,
                                     mt_component_trace, split_mt_bench,
                                     tenant_boundary, tenant_counts,
                                     tenant_last_index, tenant_stream)
from repro.traces.trace import ROOT_PAGES, Trace, make_records
from repro.uvm import UVMConfig, UVMSimulator, VectorizedUVMSimulator
from repro.uvm.eviction import resolve_tenancy
from repro.uvm.prefetchers import NoPrefetcher
from repro.uvm.sweep import MT_FIELDS, SweepCell, parse_capacity_split, \
    simulate_cell


def _mk_mt_trace(pages0, pages1, boundary, name="mt-synth"):
    """Synthetic two-tenant trace: tenant 1's pages are rebased above
    ``boundary`` and the streams merge clock-proportionally (the same
    key arithmetic as the interleaver), so any (pages0, pages1) pair
    becomes a valid multi-tenant trace."""
    pages0 = np.asarray(pages0, dtype=np.int64)
    pages1 = np.asarray(pages1, dtype=np.int64) + boundary
    assert pages0.size and int(pages0.max()) < boundary
    na, nb = len(pages0), len(pages1)
    keys = np.concatenate([np.arange(1, na + 1, dtype=np.int64) * nb,
                           np.arange(1, nb + 1, dtype=np.int64) * na])
    order = np.argsort(keys, kind="stable")
    pages = np.concatenate([pages0, pages1])[order]
    recs = make_records(len(pages))
    recs["page"] = pages
    return Trace(name, recs, {}, {}, len(pages) * 100,
                 meta={"mt": {"benches": ["A", "B"], "tenants": N_TENANTS,
                              "boundary": int(boundary)}})


# ---------------------------------------------------------------------------
# interleaver
# ---------------------------------------------------------------------------

def test_mt_bench_name_predicate():
    assert is_mt_bench("ATAX+Pathfinder")
    assert split_mt_bench("ATAX+Pathfinder") == ("ATAX", "Pathfinder")
    for bad in ("ATAX", "ATAX+NoSuchBench", "A+B+C", "+ATAX", "ATAX+",
                "ServeDecode+ATAX", 7, None):
        assert not is_mt_bench(bad), bad


def test_build_mt_trace_is_deterministic_and_disjoint():
    t1 = build_mt_trace("ATAX+Pathfinder", scale=0.25)
    t2 = build_mt_trace("ATAX+Pathfinder", scale=0.25)
    np.testing.assert_array_equal(t1.accesses, t2.accesses)
    assert t1.meta == t2.meta

    boundary = tenant_boundary(t1)
    assert boundary is not None and boundary % ROOT_PAGES == 0
    pages = np.asarray(t1.pages)
    stream = tenant_stream(t1)
    # the boundary IS the tenancy encoding: regions are disjoint with a
    # guard root window between tenant 0's span and the boundary
    assert int(pages[stream == 0].max()) < boundary - ROOT_PAGES
    assert int(pages[stream == 1].min()) >= boundary
    # both components survive with every access
    atax = build_mt_trace("ATAX+Pathfinder", scale=0.25)
    n0, n1 = tenant_counts(atax)
    assert n0 + n1 == len(atax)
    assert n0 > 0 and n1 > 0
    # a different seed relocates the regions but keeps the counts
    t3 = build_mt_trace("ATAX+Pathfinder", scale=0.25, seed=1)
    assert tenant_boundary(t3) != boundary
    assert tenant_counts(t3) == (n0, n1)


def test_mt_merge_is_clock_proportional():
    """Accesses interleave by per-tenant progress fraction — a tenant is
    never starved to the end of the stream: after any prefix of the merged
    trace, each tenant's progress stays within one access of the
    prefix's proportional share."""
    tr = _mk_mt_trace(np.arange(300), np.arange(100), boundary=1024)
    stream = tenant_stream(tr)
    n0, n1 = tenant_counts(tr)
    done1 = np.cumsum(stream == 1)
    done0 = np.arange(1, len(stream) + 1) - done1
    frac = np.arange(1, len(stream) + 1) / len(stream)
    assert np.all(np.abs(done0 / n0 - frac) <= 1.0 / n0 + 1.0 / len(stream))
    assert np.all(np.abs(done1 / n1 - frac) <= 1.0 / n1 + 1.0 / len(stream))
    # tenant 0 wins exact progress ties
    assert stream[0] == 0 and int(tenant_last_index(tr)[1]) == \
        len(stream) - 1


def test_tenancy_views_are_derived_not_stored():
    """tenant_stream/counts/last_index stay correct on any slice because
    they recompute from pages vs. the boundary."""
    tr = _mk_mt_trace(np.arange(40), np.arange(10), boundary=512)
    half = dataclasses.replace(tr, accesses=tr.accesses[:25])
    stream = tenant_stream(half)
    assert len(stream) == 25
    n0, n1 = tenant_counts(half)
    assert n0 == int((stream == 0).sum()) and n1 == int((stream == 1).sum())
    last = tenant_last_index(half)
    for t in range(N_TENANTS):
        assert stream[last[t]] == t
    # single-tenant traces yield None everywhere
    recs = make_records(4)
    recs["page"] = np.arange(4)
    plain = Trace("plain", recs, {}, {}, 400)
    assert tenant_stream(plain) is None
    assert tenant_counts(plain) is None
    assert tenant_last_index(plain) is None
    with pytest.raises(ValueError, match="not a multi-tenant"):
        mt_component_trace(plain, 0)


def test_mt_component_trace_extracts_solo_replay():
    tr = build_mt_trace("ATAX+Pathfinder", scale=0.25)
    stream = tenant_stream(tr)
    for t in range(N_TENANTS):
        solo = mt_component_trace(tr, t)
        np.testing.assert_array_equal(
            np.asarray(solo.pages), np.asarray(tr.pages)[stream == t])
        assert tenant_stream(solo) is None       # no mt sidecar: solo
        assert solo.name.endswith(f"@t{t}")
        assert all(k.startswith(f"t{t}/") for k in solo.array_bases)
        assert solo.n_instructions > 0
    assert (mt_component_trace(tr, 0).n_instructions
            + mt_component_trace(tr, 1).n_instructions
            <= tr.n_instructions + 1)


# ---------------------------------------------------------------------------
# capacity splits + tenancy validation
# ---------------------------------------------------------------------------

def test_parse_capacity_split():
    assert parse_capacity_split(None) is None
    assert parse_capacity_split("shared") is None
    assert parse_capacity_split("0.5/0.5") == (0.5, 0.5)
    assert parse_capacity_split("0.4/0.4") == (0.4, 0.4)
    assert parse_capacity_split("0/1") == (0.0, 1.0)
    for bad in ("0.7/0.7", "-0.1/0.5", "abc", "0.5", "0.3/0.3/0.3", ""):
        with pytest.raises(ValueError):
            parse_capacity_split(bad)


def test_resolve_tenancy_validation():
    tr = _mk_mt_trace(np.arange(10), np.arange(10), boundary=512)
    assert resolve_tenancy(tr, UVMConfig()) is not None          # shared
    ten = resolve_tenancy(tr, UVMConfig(device_pages=100,
                                        tenant_pages=(40, 40)))
    assert ten.quotas == (40, 40) and ten.spill == 20
    assert ten.allowed(0, 0) == (60, 60)
    assert ten.allowed(0, 50) == (50, 60)        # t1 borrowed 10 spill
    assert ten.allowed(55, 60) == (40, 45)
    recs = make_records(4)
    recs["page"] = np.arange(4)
    plain = Trace("plain", recs, {}, {}, 400)
    assert resolve_tenancy(plain, UVMConfig()) is None
    with pytest.raises(ValueError, match="not\\s+multi-tenant"):
        resolve_tenancy(plain, UVMConfig(device_pages=100,
                                         tenant_pages=(40, 40)))
    with pytest.raises(ValueError, match="device_pages"):
        resolve_tenancy(tr, UVMConfig(tenant_pages=(40, 40)))
    with pytest.raises(ValueError, match="exceed"):
        resolve_tenancy(tr, UVMConfig(device_pages=50,
                                      tenant_pages=(40, 40)))
    with pytest.raises(ValueError, match="non-negative"):
        resolve_tenancy(tr, UVMConfig(device_pages=100,
                                      tenant_pages=(-1, 40)))


def test_mt_scenarios_registered():
    from repro.uvm.scenarios import Scenario, expand_scenario, get_scenario

    smoke = get_scenario("mt-smoke")
    assert smoke.n_cells() == 36
    cells = expand_scenario("mt-smoke")
    assert len(cells) == 36
    assert {c.bench for c in cells} == {"ATAX+Pathfinder"}
    assert {c.capacity_split for c in cells} == {"shared", "0.5/0.5",
                                                 "0.4/0.4"}
    assert get_scenario("mt-full").n_cells() > smoke.n_cells()
    # quota splits require every bench to be an interleaved pair
    with pytest.raises(ValueError, match="multi-tenant"):
        Scenario(name="bad-mt", description="x",
                 benches=("ATAX", "ATAX+Pathfinder"), ratios=(0.5,),
                 capacity_splits=("0.5/0.5",)).validate()
    with pytest.raises(ValueError, match="capacity_splits"):
        Scenario(name="bad-mt2", description="x", benches=("ATAX",),
                 ratios=(0.5,), capacity_splits=()).validate()
    with pytest.raises(ValueError, match="sum"):
        Scenario(name="bad-mt3", description="x",
                 benches=("ATAX+Pathfinder",), ratios=(0.5,),
                 capacity_splits=("0.8/0.8",)).validate()


# ---------------------------------------------------------------------------
# per-tenant eviction isolation (the tentpole acceptance property)
# ---------------------------------------------------------------------------

BOUNDARY = 2 * ROOT_PAGES                      # tenant 1 starts at 1024


def _protected_run(co_pages, tenant_pages, eviction="lru"):
    """Replay tenant 0's quota-fitting cyclic sweep against a given
    co-tenant stream; returns the full stats."""
    ws0 = 200
    pages0 = np.tile(np.arange(ws0, dtype=np.int64), 5)     # 1000 accesses
    tr = _mk_mt_trace(pages0, co_pages, boundary=BOUNDARY)
    cfg = UVMConfig(device_pages=400, tenant_pages=tenant_pages,
                    eviction=eviction)
    return VectorizedUVMSimulator(cfg, strict_checks=True).run(
        tr, NoPrefetcher())


IDLE = np.arange(10, dtype=np.int64)                        # 10 accesses
THRASH = np.tile(np.arange(600, dtype=np.int64), 2)         # 1200 accesses


@pytest.mark.parametrize("eviction", ["lru", "random", "hotcold"])
def test_quota_isolates_protected_tenant(eviction):
    """Tenant 0's working set (200 pages) fits its hard quota (250 of
    400): its hit COUNT must be bit-identical (+-0) whether tenant 1
    idles over 10 pages or thrashes 600 pages through its 100-page quota
    + 50-page spill — under every eviction policy."""
    idle = _protected_run(IDLE, (250, 100), eviction)
    thrash = _protected_run(THRASH, (250, 100), eviction)
    assert idle.tenant_accesses[0] == thrash.tenant_accesses[0] == 1000
    assert idle.tenant_hits[0] == thrash.tenant_hits[0]
    # the co-tenant genuinely thrashed: it evicted pages, tenant 0's
    # stream still ran hot (first sweep faults, the rest hits)
    assert thrash.pages_evicted > 0
    assert idle.tenant_hits[0] == 1000 - 200


def test_shared_capacity_control_shows_interference():
    """Without quotas the same thrashing co-tenant evicts tenant 0's
    pages — the isolation above is the quota's doing, not an artifact of
    the traces."""
    idle = _protected_run(IDLE, None)
    thrash = _protected_run(THRASH, None)
    assert idle.tenant_hits[0] == 1000 - 200       # fits shared capacity
    assert thrash.tenant_hits[0] < idle.tenant_hits[0]


def test_isolation_property_matches_legacy_engine():
    """The quota-isolated replay is pinned across engines too: legacy and
    numpy agree on the per-tenant counters of the property trace."""
    tr = _mk_mt_trace(np.tile(np.arange(200, dtype=np.int64), 5), THRASH,
                      boundary=BOUNDARY)
    cfg = UVMConfig(device_pages=400, tenant_pages=(250, 100),
                    eviction="hotcold")
    legacy = UVMSimulator(cfg).run(tr, NoPrefetcher())
    vec = VectorizedUVMSimulator(cfg, strict_checks=True).run(
        tr, NoPrefetcher())
    assert tuple(vec.tenant_hits) == tuple(legacy.tenant_hits)
    assert tuple(vec.tenant_accesses) == tuple(legacy.tenant_accesses)
    assert vec.hits == legacy.hits and vec.faults == legacy.faults
    assert vec.pages_evicted == legacy.pages_evicted


# ---------------------------------------------------------------------------
# sweep rows carry the mt columns
# ---------------------------------------------------------------------------

def test_mt_sweep_row_records_tenant_columns():
    row = simulate_cell(SweepCell("ATAX+Pathfinder", "none", scale=0.25,
                                  device_frac=0.75,
                                  capacity_split="0.5/0.5"))
    assert row["tenants"] == N_TENANTS
    assert row["capacity_split"] == "0.5/0.5"
    for f in ("hit_rate_t0", "hit_rate_t1", "slowdown_t0", "slowdown_t1",
              "interference_slowdown"):
        assert isinstance(row[f], float), f
        assert row[f] > 0.0
    assert row["interference_slowdown"] == pytest.approx(
        max(row["slowdown_t0"], row["slowdown_t1"]))
    # shared-mode mt rows record the split as "shared"
    shared = simulate_cell(SweepCell("ATAX+Pathfinder", "none", scale=0.25,
                                     device_frac=0.75))
    assert shared["capacity_split"] == "shared"
    assert shared["tenants"] == N_TENANTS
    # single-tenant rows keep the mt columns as None (schema-stable)
    plain = simulate_cell(SweepCell("ATAX", "none", scale=0.25))
    for f in MT_FIELDS:
        assert plain[f] is None, f
