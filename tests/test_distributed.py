"""Sharding rules, optimizer, grad compression, fault-tolerance planning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property-based sharding tests skipped")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               plan_backup_dispatch,
                                               plan_remesh)
from repro.distributed.sharding import (batch_axes_for, opt_shardings,
                                        param_shardings_stacked)
from repro.models import build_model, init_params
from repro.optimizer import (AdamW, compress_with_error_feedback,
                             init_error_feedback, int8_compress,
                             int8_decompress, topk_compress, topk_decompress)


def _mesh2d(d=2, m=2):
    n = d * m
    if len(jax.devices()) < n:
        pytest.skip("not enough devices")
    from repro.distributed.sharding import make_mesh
    return make_mesh((d, m), ("data", "model"))


def test_param_specs_valid_all_archs():
    """Every arch's parameter tree must produce legal NamedShardings on a
    (data=2, model=2)-shaped abstract mesh (divisibility-checked)."""
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    for name in ("llama3-8b", "qwen3-moe-235b-a22b", "mamba2-780m",
                 "recurrentgemma-9b", "smollm-135m", "seamless-m4t-medium"):
        cfg = get_arch(name)
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda k: init_params(model, k),
                                jax.random.PRNGKey(0))
        sh = param_shardings_stacked(shapes, mesh, fsdp=True)
        # constructing NamedShardings already validates axis uniqueness;
        # also check dims divide
        def check(s, leaf):
            for axis_name, dim in zip(s.spec, leaf.shape):
                if axis_name is not None:
                    size = mesh.shape[axis_name] if isinstance(axis_name, str) else 1
                    assert dim % size == 0, (name, s.spec, leaf.shape)
        jax.tree.map(check, sh, shapes,
                     is_leaf=lambda x: hasattr(x, "spec"))


def test_zero1_no_duplicates():
    mesh = jax.sharding.AbstractMesh((4, 2), ("data", "model"))
    shapes = {"wq": jax.ShapeDtypeStruct((8, 8, 16), jnp.float32),
              "ln": jax.ShapeDtypeStruct((16,), jnp.float32)}
    psh = param_shardings_stacked(shapes, mesh)
    osh = opt_shardings(psh, shapes, mesh, zero1=True)
    for s in jax.tree.leaves(osh, is_leaf=lambda x: hasattr(x, "spec")):
        names = [a for a in s.spec if a is not None]
        assert len(names) == len(set(names))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096))
def test_batch_axes_fallback(b):
    mesh = jax.sharding.AbstractMesh((2, 16, 16), ("pod", "data", "model"))
    axes = batch_axes_for(b, mesh)
    denom = 1
    for a in axes:
        denom *= mesh.shape[a]
    assert b % denom == 0


def test_adamw_converges():
    opt = AdamW(clip_norm=None)
    params = {"w": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, params, state, 0.1)
    assert abs(float(params["w"])) < 0.05


def test_int8_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)) * 3)
    q, s = int8_compress(x)
    y = int8_decompress(q, s)
    assert float(jnp.abs(x - y).max()) <= float(s) * 1.01


def test_topk_roundtrip():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(128,)))
    v, i, shp = topk_compress(x, frac=0.1)
    y = topk_decompress(v, i, shp)
    assert y.shape == x.shape
    # kept entries exact, others zero
    assert float(jnp.abs(y[i] - x[i]).max()) < 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the cumulative transmitted signal approaches the
    cumulative true gradient."""
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    state = init_error_feedback(g)
    sent_total = jnp.zeros(64)
    for _ in range(50):
        sent, state = compress_with_error_feedback(g, state, mode="int8")
        sent_total = sent_total + sent["w"]
    want = g["w"] * 50
    rel = float(jnp.abs(sent_total - want).max() /
                (jnp.abs(want).max() + 1e-9))
    assert rel < 0.05


def test_heartbeat_and_stragglers():
    hb = HeartbeatMonitor(timeout_s=10, straggler_factor=1.5)
    hb.beat(0, 1.0, now=100.0)
    hb.beat(1, 1.0, now=100.0)
    hb.beat(2, 5.0, now=100.0)
    assert hb.stragglers() == [2]
    assert hb.dead_hosts(now=105.0) == []
    assert set(hb.dead_hosts(now=150.0)) == {0, 1, 2}
    assert plan_backup_dispatch([2], [7]) == {2: 7}


def test_plan_remesh():
    # 128 hosts x 4 chips: prefers the most pods that keep model=16 intact
    got = plan_remesh(128, 4, 16)
    assert got is not None
    pod, data, model = got
    assert pod * data * model == 512 and model == 16
    # lose a host: 508 chips; any returned mesh must fit and keep model=16
    got = plan_remesh(127, 4, 16)
    if got is not None:
        pod, data, model = got
        assert pod * data * model <= 508
        assert model == 16
    # degenerate: too few chips for the model axis
    assert plan_remesh(1, 4, 16) is None
