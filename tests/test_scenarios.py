"""Scenario registry + oversubscription invariants across eviction
policies and backends.

Two concerns:

1. **Registry** (``repro.uvm.scenarios``): the built-in matrices expand to
   the advertised shapes (``oversub-full`` = 11 benchmarks × 4 ratios ×
   3 policies × 5 prefetchers), cells are stamped with their scenario and
   eviction policy (distinct resume keys per policy), and scenarios
   round-trip through JSON with every axis validated against the live
   vocabularies.

2. **Oversubscription invariants**: for every (eviction policy × backend
   × prefetcher) combination, replays satisfy the model's conservation
   laws — hits + late + faults == accesses, no evictions when memory is
   undersubscribed, eviction churn when it is not, and migrated ≥
   evicted — and the three policies genuinely produce different victim
   sequences on a thrashing trace (a guard against a policy silently
   degrading to LRU in any backend).
"""
import json

import numpy as np
import pytest

from repro.core.families import MODEL_FAMILIES
from repro.traces.trace import Trace, make_records
from repro.uvm import UVMConfig
from repro.uvm.adaptive import ADAPTIVE_POLICY
from repro.uvm.eviction import (EVICTION_POLICIES, eviction_score,
                                eviction_scores, make_eviction_policy)
from repro.uvm.golden import make_prefetcher
from repro.uvm.replay_core import ReplayRequest, get_backend
from repro.uvm.scenarios import (DEFAULT_RATIOS, PAPER_BENCHMARKS, Scenario,
                                 available_scenarios, expand_scenario,
                                 get_scenario, register_scenario,
                                 scenario_from_dict)
from repro.uvm.sweep import PREFETCHERS, SweepCell, simulate_cell

BACKENDS = ("legacy", "numpy", "pallas")
PF_NAMES = ("none", "block", "tree", "learned", "oracle")


def _mk_trace(pages, name="scenario-synth"):
    pages = np.asarray(pages, dtype=np.int64)
    recs = make_records(len(pages))
    recs["page"] = pages
    return Trace(name, recs, {}, {}, len(pages) * 100)


def _replay(pages, pf_name, cap, eviction, backend):
    trace = _mk_trace(pages)
    config = UVMConfig(device_pages=cap, mshr_entries=16, eviction=eviction)
    req = ReplayRequest(trace, make_prefetcher(pf_name, trace, config),
                        config)
    b = get_backend(backend)
    assert b.can_replay(req), (pf_name, eviction, backend)
    return b.replay([req])[0]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_scenarios_registered():
    names = available_scenarios()
    assert "oversub-full" in names and "oversub-smoke" in names


def test_oversub_full_expands_whole_matrix():
    """The acceptance matrix: 11 paper benchmarks × ratio × policy ×
    prefetcher, every cell stamped and uniquely resumable."""
    s = get_scenario("oversub-full")
    cells = expand_scenario("oversub-full", backend="pallas")
    assert len(cells) == 11 * len(DEFAULT_RATIOS) * 3 * 5 == s.n_cells()
    assert {c.bench for c in cells} == set(PAPER_BENCHMARKS)
    assert {c.device_frac for c in cells} == set(DEFAULT_RATIOS)
    assert {c.eviction for c in cells} == set(EVICTION_POLICIES)
    assert {c.prefetcher for c in cells} == set(PREFETCHERS)
    assert all(c.scenario == "oversub-full" for c in cells)
    assert all(c.backend == "pallas" for c in cells)
    # the resume store keys every cell distinctly (policy included)
    assert len({c.key() for c in cells}) == len(cells)


def test_oversub_smoke_stays_small():
    """The CI smoke must stay sub-500k total accesses by construction:
    2 small benchmarks x 2 ratios x all policies x 2 prefetchers."""
    s = get_scenario("oversub-smoke")
    assert len(s.benches) == 2 and len(s.ratios) == 2
    assert s.evictions == EVICTION_POLICIES
    assert s.scale < 1.0
    assert s.n_cells() == 2 * 2 * 3 * 2


def test_transformer_smoke_family_axis():
    """The predictor-family CI smoke: 2 benches x adaptive eviction x
    learned, across two model families — 4 cells, each keyed distinctly
    by its family."""
    s = get_scenario("transformer-smoke")
    assert s.model_families == ("simplified", "transformer")
    assert all(f in MODEL_FAMILIES for f in s.model_families)
    assert s.evictions == (ADAPTIVE_POLICY,)
    assert s.prefetchers == ("learned",)
    assert s.n_cells() == 2 * 1 * 1 * 1 * 2
    cells = expand_scenario("transformer-smoke", backend="pallas")
    assert len(cells) == s.n_cells()
    assert {c.model_family for c in cells} == {"simplified", "transformer"}
    assert all(c.eviction == ADAPTIVE_POLICY for c in cells)
    # the family axis is part of the resume key
    assert len({c.key() for c in cells}) == len(cells)
    back = scenario_from_dict(json.loads(json.dumps(s.to_dict())))
    assert back == s and back.cells() == s.cells()


def test_scenario_json_roundtrip():
    s = get_scenario("oversub-full")
    back = scenario_from_dict(json.loads(json.dumps(s.to_dict())))
    assert back == s
    assert back.cells() == s.cells()


def test_scenario_validation_rejects_bad_axes():
    ok = dict(name="t", description="d", benches=("ATAX",), ratios=(0.5,))
    Scenario(**ok).validate()
    with pytest.raises(ValueError, match="unknown benches"):
        Scenario(**{**ok, "benches": ("NotABench",)}).validate()
    with pytest.raises(ValueError, match="unknown evictions"):
        Scenario(**{**ok, "evictions": ("lru", "mru")}).validate()
    with pytest.raises(ValueError, match="unknown prefetchers"):
        Scenario(**{**ok, "prefetchers": ("psychic",)}).validate()
    with pytest.raises(ValueError, match="unknown model_families"):
        Scenario(**{**ok, "model_families": ("lstm",)}).validate()
    with pytest.raises(ValueError, match="empty model_families"):
        Scenario(**{**ok, "model_families": ()}).validate()
    # the adaptive pseudo-policy is part of the evictions vocabulary
    Scenario(**{**ok, "evictions": ("lru", ADAPTIVE_POLICY)}).validate()
    with pytest.raises(ValueError, match="ratios"):
        Scenario(**{**ok, "ratios": ()}).validate()
    with pytest.raises(ValueError, match="ratios"):
        Scenario(**{**ok, "ratios": (0.5, -1.0)}).validate()
    with pytest.raises(ValueError, match="empty"):
        Scenario(**{**ok, "benches": ()}).validate()
    with pytest.raises(ValueError, match="scale"):
        Scenario(**{**ok, "scale": 0.0}).validate()
    with pytest.raises(ValueError, match="bad scenario name"):
        Scenario(**{**ok, "name": "a/b"}).validate()


def test_register_refuses_silent_override():
    probe = Scenario(name="probe-dup", description="d",
                     benches=("ATAX",), ratios=(0.5,))
    register_scenario(probe)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_scenario(probe)
        register_scenario(probe, replace=True)     # explicit override ok
    finally:
        from repro.uvm import scenarios as _mod
        _mod._SCENARIOS.pop("probe-dup", None)
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("never-registered")


def test_unknown_policy_fails_fast():
    with pytest.raises(ValueError, match="unknown eviction policy"):
        make_eviction_policy("mru")
    from repro.uvm import UVMSimulator
    tr = _mk_trace(np.arange(10))
    with pytest.raises(ValueError, match="unknown eviction policy"):
        UVMSimulator(UVMConfig(eviction="mru")).run(
            tr, make_prefetcher("none", tr, UVMConfig()))


def test_eviction_scorer_scalar_matches_array():
    """The random policy's reference mixer: scalar == vectorized, and the
    draws actually spread (no degenerate constant hash)."""
    pages = np.arange(0, 4096, 7, dtype=np.int64)
    for draw in (0, 1, 12345, 2**31 - 1):
        vec = eviction_scores(pages, draw)
        assert vec.dtype == np.uint32
        assert [eviction_score(int(p), draw) for p in pages[:32]] == \
            list(int(v) for v in vec[:32])
        # distinct draws re-rank: same pages, different priorities
        assert len(np.unique(vec)) > len(pages) * 0.99
    assert not np.array_equal(eviction_scores(pages, 0),
                              eviction_scores(pages, 1))


# ---------------------------------------------------------------------------
# oversubscription invariants, per (policy x backend)
# ---------------------------------------------------------------------------

_THRASH = np.tile(np.arange(500, dtype=np.int64), 4)     # ws ~2.8x cap


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("eviction", EVICTION_POLICIES)
def test_oversubscription_invariants(eviction, backend):
    """Conservation laws hold for every (policy, backend, prefetcher):
    access classes partition the trace, migrations bound evictions, and
    capacity pressure actually causes churn."""
    for pf_name in PF_NAMES:
        st = _replay(_THRASH, pf_name, 180, eviction, backend)
        assert st.eviction == eviction and st.backend == backend
        assert st.hits + st.late + st.faults == st.n_accesses
        assert st.pages_migrated >= st.faults
        assert st.pages_migrated - st.pages_evicted >= 0
        assert st.prefetch_used <= st.prefetch_issued
        assert st.pages_evicted > 0, (
            f"{pf_name}/{eviction}/{backend}: thrashing trace must evict")
        assert 0.0 <= st.hit_rate <= 1.0 and st.cycles > 0.0


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("eviction", EVICTION_POLICIES)
def test_undersubscribed_never_evicts(eviction, backend):
    """evictions == 0 whenever memory is undersubscribed: uncapped, or
    capacity comfortably above the working set — for every policy."""
    for cap in (None, 4096):
        st = _replay(_THRASH, "tree", cap, eviction, backend)
        assert st.pages_evicted == 0
        assert st.hits + st.late + st.faults == st.n_accesses


def _hot_cold_mix():
    """A hot 100-page set touched 3x per round, interleaved with a cold
    200-page streaming sweep per round — under a 180-page cap, LRU's
    recency order evicts the hot set every round (the streaming pages are
    newer), while access-frequency replacement keeps it resident.  The
    trace where the three policies must tell apart."""
    hot = np.repeat(np.arange(100, dtype=np.int64), 3)
    parts = []
    for k in range(12):
        parts.append(hot)
        parts.append(np.arange(1000 + 200 * k, 1000 + 200 * (k + 1),
                               dtype=np.int64))
    return np.concatenate(parts)


@pytest.mark.parametrize("backend", BACKENDS)
def test_policies_diverge_under_pressure(backend):
    """The policies must be genuinely different victim orders, not three
    names for LRU: on the hot/cold mix each policy produces a distinct
    stat vector, LRU thrashes the hot set to zero hits, random keeps a
    random subset of it, and hot/cold replacement keeps nearly all of it
    (the access-pattern-aware win of arXiv 2204.02974)."""
    by_policy = {
        pol: _replay(_hot_cold_mix(), "none", 180, pol, backend)
        for pol in EVICTION_POLICIES
    }
    sigs = {pol: (st.hits, st.late, st.faults, st.pages_evicted, st.cycles)
            for pol, st in by_policy.items()}
    assert len(set(sigs.values())) == 3, f"policies degenerate: {sigs}"
    assert by_policy["lru"].hits == 0
    assert by_policy["random"].hits > 0
    assert by_policy["hotcold"].hits > by_policy["random"].hits
    assert by_policy["hotcold"].cycles < by_policy["lru"].cycles


def test_policy_cells_have_distinct_sweep_keys():
    base = dict(bench="ATAX", prefetcher="none", scale=0.25,
                device_frac=0.5)
    keys = {SweepCell(eviction=ev, **base).key()
            for ev in EVICTION_POLICIES}
    assert len(keys) == 3


def test_simulate_cell_rows_carry_policy_columns():
    row = simulate_cell(SweepCell("ATAX", "none", scale=0.25,
                                  device_frac=0.5, eviction="random",
                                  scenario="probe"))
    assert row["eviction"] == "random"
    assert row["scenario"] == "probe"
    assert row["pages_evicted"] > 0
    lru = simulate_cell(SweepCell("ATAX", "none", scale=0.25,
                                  device_frac=0.5))
    assert lru["eviction"] == "lru"
    assert (row["hits"], row["cycles"]) != (lru["hits"], lru["cycles"])


# ---------------------------------------------------------------------------
# hypothesis widening (skipped when hypothesis is absent; CI installs it)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - degraded environment
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(st_.lists(st_.integers(0, 700), min_size=10, max_size=250),
           st_.sampled_from(EVICTION_POLICIES),
           st_.sampled_from([None, 40, 160]),
           st_.sampled_from(PF_NAMES))
    def test_invariants_random_cells(pages, eviction, cap, pf_name):
        """Random traces: the conservation laws hold for every policy on
        the numpy engine (strict_checks asserts the internal ones too)."""
        from repro.uvm import VectorizedUVMSimulator

        tr = _mk_trace(np.asarray(pages, dtype=np.int64))
        config = UVMConfig(device_pages=cap, mshr_entries=16,
                           eviction=eviction)
        st = VectorizedUVMSimulator(config, strict_checks=True).run(
            tr, make_prefetcher(pf_name, tr, config))
        assert st.hits + st.late + st.faults == st.n_accesses
        assert st.pages_migrated >= st.faults
        assert st.pages_migrated - st.pages_evicted >= 0
        assert st.prefetch_used <= st.prefetch_issued
        if cap is None:
            assert st.pages_evicted == 0
