"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU; TPU semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, hlsh_attention, int4_matmul
from repro.kernels import ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("b,h,hkv,sq,sk,d", [
    (1, 2, 1, 128, 128, 64),
    (2, 4, 2, 256, 256, 64),
    (1, 8, 1, 128, 384, 128),
    (1, 4, 4, 256, 128, 32),
])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, hkv, sq, sk, d, causal, dtype):
    if causal and sq > sk:
        pytest.skip("causal requires sq <= sk")
    q = _rand((b, h, sq, d), dtype)
    k = _rand((b, hkv, sk, d), dtype)
    v = _rand((b, hkv, sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("b,n,d", [(1, 128, 32), (2, 256, 64), (1, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hlsh_kernel_sweep(b, n, d, dtype):
    q = _rand((b, n, d), dtype)
    v = _rand((b, n, d), dtype)
    keep = jnp.asarray(RNG.random((b, n)) > 0.3, jnp.float32)
    keep = keep.at[:, : min(128, n)].set(0.0)   # force a skipped block
    src = jnp.asarray(RNG.integers(0, n, (b, n)), jnp.int32)
    out = hlsh_attention(q, q, v, keep, src)
    want = ref.hlsh_attention_ref(q, q, v, keep, src)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("m,k,n", [(128, 128, 256), (128, 256, 256),
                                   (256, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int4_matmul_sweep(m, k, n, dtype):
    x = _rand((m, k), dtype)
    w = jnp.asarray(RNG.integers(0, 256, (k, n // 2)).astype(np.uint8))
    out = int4_matmul(x, w, 0.03)
    want = ref.int4_matmul_ref(x, w, 0.03)
    rel = np.abs(np.asarray(out, np.float32) - np.asarray(want, np.float32))
    denom = np.abs(np.asarray(want, np.float32)) + 1.0
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert (rel / denom).max() < tol


def test_hlsh_kernel_matches_core_attention():
    """Kernel path == the model's jnp HLSH (plan -> apply) end to end."""
    from repro.core import attention as A
    q = _rand((2, 128, 32), jnp.float32)
    v = _rand((2, 128, 32), jnp.float32)
    plan = A.hlsh_plan(q, jax.random.PRNGKey(0))
    want = A.hlsh_apply(q, q, v, plan)
    out = hlsh_attention(q, q, v, plan.keep.astype(jnp.float32),
                         plan.share_src.astype(jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)
