"""Golden-equivalence harness for the UVM replay backends.

Three guarantees, pinned by recorded fixtures (tests/golden/uvm_golden.json):

1. the legacy per-access ``UVMSimulator`` still produces the recorded stats
   (no unintentional timing-model drift),
2. the NumPy backend (``VectorizedUVMSimulator``) reproduces the legacy
   engine *exactly* on every integer counter and to 1e-6 relative on the
   float accumulators (bit-equal in practice) for every
   (trace × prefetcher) cell, and
3. the jax_pallas multi-lane backend reproduces the legacy engine for
   EVERY golden cell — all five paper-facing prefetcher families
   (none/block/tree/learned/oracle, plus the cached-prediction learned
   variant) — integer counters exact, cycles/pcie_bytes within 1e-6
   relative (bit-equal in practice), with each lane family's cells
   replayed in one lane batch (interpret mode on CPU, so CI covers it
   without a GPU).  A family whose eligibility silently shrinks to zero
   cells fails the suite (``test_pallas_eligibility_is_not_vacuous``).

Regenerate fixtures after an intentional model change with
``PYTHONPATH=src python scripts/regen_uvm_golden.py``.
"""
import json
import os

import numpy as np
import pytest

from repro.traces.trace import ROOT_PAGES, Trace, make_records
from repro.uvm import UVMConfig, UVMSimulator, VectorizedUVMSimulator
from repro.uvm.engine import MAX_SPAN_PAGES
from repro.uvm.eviction import EVICTION_POLICIES
from repro.uvm.golden import (FLOAT_FIELDS, INT_FIELDS, golden_cell,
                              golden_cell_ids, golden_cell_policy,
                              stats_to_dict)
from repro.uvm.prefetchers import Prefetcher, TreePrefetcher
from repro.uvm.replay_core import ReplayRequest, get_backend

FIXTURE = os.path.join(os.path.dirname(__file__), "golden", "uvm_golden.json")

if not os.path.exists(FIXTURE):
    pytest.fail(
        f"golden fixture missing: {FIXTURE}; regenerate with "
        "PYTHONPATH=src python scripts/regen_uvm_golden.py",
        pytrace=False)
with open(FIXTURE) as _f:
    GOLDEN = json.load(_f)["cells"]

_legacy_cache = {}


def _legacy_stats(cell_id):
    """Legacy run per cell, shared between the fixture- and the
    equivalence-assertions (the reference engine is the slow one)."""
    if cell_id not in _legacy_cache:
        trace, config, factory = golden_cell(cell_id)
        _legacy_cache[cell_id] = UVMSimulator(config).run(trace, factory())
    return _legacy_cache[cell_id]


def _assert_stats_match(got, want, *, rel, context):
    for f in INT_FIELDS:
        assert got[f] == want[f], f"{context}: {f} {got[f]} != {want[f]}"
    for f in FLOAT_FIELDS:
        assert got[f] == pytest.approx(want[f], rel=rel, abs=1e-9), (
            f"{context}: {f} {got[f]} != {want[f]}")
    # multi-tenant cells additionally pin the per-tenant accounting
    # (exact: integer counters per tenant)
    for f in ("tenant_hits", "tenant_accesses"):
        assert list(got.get(f) or []) == list(want.get(f) or []), (
            f"{context}: {f} {got.get(f)} != {want.get(f)}")


@pytest.mark.parametrize("cell_id", golden_cell_ids())
def test_legacy_matches_fixture(cell_id):
    assert cell_id in GOLDEN, (
        f"no fixture for {cell_id}; regenerate with "
        "PYTHONPATH=src python scripts/regen_uvm_golden.py")
    got = stats_to_dict(_legacy_stats(cell_id))
    _assert_stats_match(got, GOLDEN[cell_id], rel=1e-9,
                        context=f"legacy vs fixture [{cell_id}]")


@pytest.mark.parametrize("cell_id", golden_cell_ids())
def test_vectorized_matches_legacy(cell_id):
    trace, config, factory = golden_cell(cell_id)
    legacy = stats_to_dict(_legacy_stats(cell_id))
    stats = VectorizedUVMSimulator(config, strict_checks=True).run(
        trace, factory())
    # the comparison is only meaningful if the numpy engine actually ran
    # (a silent legacy fallback would match trivially)
    assert stats.backend == "numpy"
    _assert_stats_match(stats_to_dict(stats), legacy, rel=1e-6,
                        context=f"vectorized vs legacy [{cell_id}]")


def test_fixture_has_no_stale_cells():
    assert set(GOLDEN) == set(golden_cell_ids())


# ---------------------------------------------------------------------------
# pallas multi-lane backend: every golden cell of each (lane family,
# eviction policy) bucket in ONE lane batch (demand = none/block, tree,
# learned (+cached), oracle; batches are policy-homogeneous too)
# ---------------------------------------------------------------------------

def _family_of(cell_id):
    pf = cell_id.split("/")[1]
    return {"none": "demand", "block": "demand", "tree": "tree",
            "learned": "learned", "learned-cached": "learned",
            "learned-tf": "learned", "oracle": "oracle"}[pf]


PALLAS_LANE_GROUPS = {}
for _cell_id in golden_cell_ids():
    PALLAS_LANE_GROUPS.setdefault(
        (_family_of(_cell_id), golden_cell_policy(_cell_id)),
        []).append(_cell_id)


def test_pallas_eligibility_is_not_vacuous():
    """Empty-eligibility regression guard: every lane family AND every
    eviction policy must have golden cells the pallas backend accepts, so
    the per-(family, policy) equivalence batches below can never silently
    replay zero cells (which would let the golden guarantee pass
    vacuously)."""
    from repro.uvm.backends.pallas_backend import lane_family

    backend = get_backend("pallas")
    seen_families = set()
    seen_policies = set()
    for (family, policy), cells in PALLAS_LANE_GROUPS.items():
        assert cells, f"no golden cells for lane bucket {(family, policy)}"
        for cell_id in cells:
            trace, config, factory = golden_cell(cell_id)
            req = ReplayRequest(trace, factory(), config)
            assert backend.can_replay(req), (
                f"pallas backend declines golden cell {cell_id}: the "
                f"{(family, policy)} lane batch would silently shrink")
            seen_families.add(lane_family(req.prefetcher).split("/")[0])
            seen_policies.add(policy)
    # all five paper-facing prefetchers map onto these four kernel
    # families and every eviction policy must have in-kernel coverage —
    # no policy's lane eligibility may silently shrink to zero
    assert seen_families == {"demand", "tree", "learned", "oracle"}
    assert seen_policies == set(EVICTION_POLICIES)
    assert sum(len(c) for c in PALLAS_LANE_GROUPS.values()) == len(
        golden_cell_ids())


@pytest.mark.parametrize("group", sorted(PALLAS_LANE_GROUPS),
                         ids=lambda g: f"{g[0]}-{g[1]}")
def test_pallas_lane_batch_matches_legacy(group):
    """All golden cells of one (lane family, eviction policy) bucket —
    including the oversubscribed eviction-churn traces, the MSHR-pressure
    storm, tree escalation churn, and cached learned predictions —
    replayed as ONE multi-lane pallas batch: integer counters exact,
    floats to 1e-6 (bit-equal in practice)."""
    cells = PALLAS_LANE_GROUPS[group]
    assert cells, f"vacuous lane batch for bucket {group!r}"
    backend = get_backend("pallas")
    requests = []
    for cell_id in cells:
        trace, config, factory = golden_cell(cell_id)
        requests.append(ReplayRequest(trace, factory(), config))
    assert all(backend.can_replay(r) for r in requests)
    assert len(backend.pack_lanes(requests)) == 1, \
        f"{group} golden cells must pack into a single lane batch"
    all_stats = backend.replay(requests)
    assert len(all_stats) == len(cells)
    for cell_id, stats in zip(cells, all_stats):
        assert stats.backend == "pallas"
        assert stats.eviction == group[1]
        _assert_stats_match(stats_to_dict(stats),
                            stats_to_dict(_legacy_stats(cell_id)), rel=1e-6,
                            context=f"pallas vs legacy [{cell_id}]")


def test_cached_learned_matches_plain_learned():
    """The predcache round trip is invisible to the replay: every
    learned-cached fixture is identical to its plain learned sibling."""
    pairs = [c for c in GOLDEN if c.endswith("/learned-cached")]
    assert pairs
    for cell_id in pairs:
        plain = cell_id.replace("/learned-cached", "/learned")
        assert GOLDEN[cell_id] == GOLDEN[plain], cell_id


def test_family_keyed_cache_distinguishes_learned_tf():
    """learned-tf rides the same predcache round trip as learned-cached
    but under ``model_family="transformer"`` with a different prediction
    distance.  If the cache key ignored the model family, the round trip
    would cross-serve the simplified cells' distance-32 array and every
    learned-tf fixture would collapse onto its plain learned sibling."""
    pairs = [c for c in GOLDEN if c.endswith("/learned-tf")]
    assert pairs
    assert any(GOLDEN[c] != GOLDEN[c.replace("/learned-tf", "/learned")]
               for c in pairs)


def test_timeline_equivalence():
    """The optional (cycle, bytes) transfer timeline matches event-for-event."""
    cell_id = "bicg-cluster/tree"
    trace, config, factory = golden_cell(cell_id)
    t_legacy = UVMSimulator(config, record_timeline=True).run(
        trace, factory()).timeline
    t_vec = VectorizedUVMSimulator(config, record_timeline=True).run(
        trace, factory()).timeline
    assert t_legacy.shape == t_vec.shape
    np.testing.assert_allclose(t_vec, t_legacy, rtol=1e-9)


# ---------------------------------------------------------------------------
# engine fallbacks
# ---------------------------------------------------------------------------

def _mk_trace(pages, name="synth"):
    pages = np.asarray(pages, dtype=np.int64)
    recs = make_records(len(pages))
    recs["page"] = pages
    return Trace(name, recs, {}, {}, len(pages) * 100)


class _EveryOtherPrefetcher(Prefetcher):
    """Unknown subclass: must route to the legacy engine (it may emit pages
    outside the vectorized engine's dense page span)."""

    name = "every-other"

    def on_fault(self, index, page, resident):
        return [page + 1] if (page + 1) not in resident else []

    def on_access(self, index, page, resident, clock=0.0):
        q = page + 2
        if index % 2 == 0 and q not in resident:
            return [q]
        return []


def test_generic_prefetcher_fallback_is_exact():
    tr = _mk_trace(np.tile(np.arange(200), 4))
    s1 = stats_to_dict(UVMSimulator().run(tr, _EveryOtherPrefetcher()))
    s2 = stats_to_dict(
        VectorizedUVMSimulator(strict_checks=True).run(
            tr, _EveryOtherPrefetcher()))
    _assert_stats_match(s2, s1, rel=1e-9, context="generic fallback")


def test_huge_span_falls_back_to_legacy():
    pages = np.array([0, MAX_SPAN_PAGES * 2, 0, 7], dtype=np.int64)
    tr = _mk_trace(pages)
    s1 = stats_to_dict(UVMSimulator().run(tr, TreePrefetcher()))
    s2 = stats_to_dict(VectorizedUVMSimulator().run(tr, TreePrefetcher()))
    _assert_stats_match(s2, s1, rel=1e-9, context="span fallback")


def test_empty_trace():
    tr = _mk_trace(np.empty(0, dtype=np.int64))
    st = VectorizedUVMSimulator().run(tr, TreePrefetcher())
    assert st.n_accesses == 0 and st.cycles == 0.0 and st.faults == 0


# ---------------------------------------------------------------------------
# invariants (strict_checks also asserts monotone clock and
# never-evict-in-flight inside the engine)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell_id", golden_cell_ids())
def test_invariants(cell_id):
    trace, config, factory = golden_cell(cell_id)
    st = VectorizedUVMSimulator(config, strict_checks=True).run(
        trace, factory())
    assert st.hits + st.late + st.faults == st.n_accesses
    assert 0.0 <= st.accuracy <= 1.0
    assert 0.0 <= st.coverage <= 1.0
    assert 0.0 <= st.hit_rate <= 1.0
    assert 0.0 <= st.unity <= 1.0
    assert st.prefetch_used <= st.prefetch_issued
    assert st.pages_migrated >= st.faults
    assert st.cycles >= 0.0
    if config.device_pages is not None:
        assert st.pages_migrated - st.pages_evicted >= 0


# ---------------------------------------------------------------------------
# property-based equivalence (skipped when hypothesis is absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - degraded environment
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(st_.lists(
        st_.one_of(
            st_.tuples(st_.just("migrate"),
                       st_.lists(st_.integers(0, 2 * ROOT_PAGES - 1),
                                 min_size=1, max_size=40, unique=True)),
            st_.tuples(st_.just("evict"),
                       st_.integers(0, 2 * ROOT_PAGES - 1)),
            st_.tuples(st_.just("fault"),
                       st_.integers(0, 2 * ROOT_PAGES - 1)),
        ), min_size=1, max_size=80))
    def test_tree_adapter_matches_dict_counts(ops):
        """Vectorized per-level count arrays vs the legacy dict on random
        migrate/evict/fault streams: node occupancy and the on_fault extras
        (pages AND order) must agree after every operation."""
        from repro.uvm.engine import _TreeAdapter

        span = 2 * ROOT_PAGES
        arrival = np.full(span, np.inf)
        resident = set()
        legacy = TreePrefetcher()
        adapter = _TreeAdapter(TreePrefetcher(), arrival, 0)

        def _migrate(pages):
            for q in pages:
                resident.add(q)
                arrival[q] = 0.0
            legacy.on_migrate(list(pages))
            adapter.on_migrate(list(pages))

        for op in ops:
            if op[0] == "migrate":
                fresh = [q for q in op[1] if q not in resident]
                if fresh:
                    _migrate(fresh)
            elif op[0] == "evict":
                q = op[1]
                if q in resident:
                    resident.discard(q)
                    arrival[q] = np.inf
                    legacy.on_evict(q)
                    adapter.on_evict(q)
            else:                        # fault, replaying engine order:
                q = op[1]                # insert + migrate, then on_fault
                if q in resident:
                    continue
                _migrate([q])
                want = legacy.on_fault(0, q, resident)
                got = adapter.on_fault(0, q, resident)
                assert [int(x) for x in got] == want
                if want:
                    _migrate(want)       # the engine schedules the extras
            for lv in range(TreePrefetcher.LEVELS + 1):
                nz = {i: int(c) for i, c in enumerate(adapter.counts[lv])
                      if c}
                dic = {node: int(c)
                       for (level, node), c in legacy.counts.items()
                       if level == lv and c}
                assert nz == dic, f"level {lv} counts diverged"

    @settings(max_examples=25, deadline=None)
    @given(st_.lists(st_.integers(0, 600), min_size=20, max_size=300),
           st_.sampled_from(["none", "block", "tree", "learned",
                             "learned-cached", "learned-tf", "oracle"]),
           st_.sampled_from([None, 48, 200]))
    def test_property_equivalence(pages, pf_name, cap):
        from repro.uvm.golden import make_prefetcher

        tr = _mk_trace(np.asarray(pages, dtype=np.int64))
        config = UVMConfig(device_pages=cap, mshr_entries=16)
        s1 = stats_to_dict(
            UVMSimulator(config).run(
                tr, make_prefetcher(pf_name, tr, config)))
        s2 = stats_to_dict(
            VectorizedUVMSimulator(config, strict_checks=True).run(
                tr, make_prefetcher(pf_name, tr, config)))
        _assert_stats_match(s2, s1, rel=1e-9,
                            context=f"property [{pf_name} cap={cap}]")
