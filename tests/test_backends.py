"""Replay-backend layer: registry/contract, dispatch fallbacks, and the
jax_pallas multi-lane engine.

The lane-packing property test is the backend's core guarantee: a
lane-batched pallas replay of N random cells must equal N independent
NumPy replays — integer counters exact, cycles/pcie_bytes to 1e-6 —
including ragged trace lengths and oversubscribed (LRU-evicting) cells.
"""
import numpy as np
import pytest

from repro.traces.trace import Trace, make_records
from repro.uvm import UVMConfig
from repro.uvm.backends.pallas_backend import (MAX_LANES_PER_BATCH,
                                               PallasReplayBackend, _bucket)
from repro.uvm.prefetchers import (BlockPrefetcher, NoPrefetcher,
                                   TreePrefetcher)
from repro.uvm.replay_core import (ReplayRequest, available_backends,
                                   backend_chain, dispatch, get_backend,
                                   resolve_backend)

INT_FIELDS = ("n_accesses", "hits", "late", "faults", "prefetch_issued",
              "prefetch_used", "pages_migrated", "pages_evicted")


def _mk_trace(pages, name="synth"):
    pages = np.asarray(pages, dtype=np.int64)
    recs = make_records(len(pages))
    recs["page"] = pages
    return Trace(name, recs, {}, {}, len(pages) * 100)


def _req(pages, pf=None, cap=None, mshr=64):
    config = UVMConfig(device_pages=cap, mshr_entries=mshr)
    return ReplayRequest(_mk_trace(pages), pf or NoPrefetcher(), config)


def _assert_equivalent(got, want, context=""):
    for f in INT_FIELDS:
        assert getattr(got, f) == getattr(want, f), (
            f"{context}: {f} {getattr(got, f)} != {getattr(want, f)}")
    assert got.cycles == pytest.approx(want.cycles, rel=1e-6), context
    assert got.pcie_bytes == pytest.approx(want.pcie_bytes, rel=1e-6), context


# ---------------------------------------------------------------------------
# registry + dispatch contract
# ---------------------------------------------------------------------------

def test_registry_has_builtin_backends():
    assert {"legacy", "numpy", "pallas"} <= set(available_backends())
    for name in ("legacy", "numpy", "pallas"):
        assert get_backend(name).name == name
    with pytest.raises(ValueError, match="unknown replay backend"):
        get_backend("cuda")


def test_backend_chains_end_in_legacy():
    assert backend_chain("legacy") == ["legacy"]
    assert backend_chain("numpy") == ["numpy", "legacy"]
    assert backend_chain("pallas") == ["pallas", "numpy", "legacy"]
    assert backend_chain("auto")[-1] == "legacy"
    with pytest.raises(ValueError):
        backend_chain("mps")


def test_dispatch_records_backend():
    assert dispatch(_req(np.arange(200) % 64), "numpy").backend == "numpy"
    assert dispatch(_req(np.arange(200) % 64), "pallas").backend == "pallas"
    assert dispatch(_req(np.arange(200) % 64), "legacy").backend == "legacy"


def test_unpackable_request_falls_back_visibly():
    """Tree cells cannot pack into pallas lanes: the chain drops to the
    NumPy path and says so in the stats instead of silently covering."""
    r = _req(np.arange(200) % 64, pf=TreePrefetcher())
    assert not get_backend("pallas").can_replay(r)
    assert resolve_backend(r, "pallas").name == "numpy"
    assert dispatch(r, "pallas").backend == "numpy"


def test_pallas_declines_timelines_and_empty_traces():
    backend = get_backend("pallas")
    assert not backend.can_replay(
        ReplayRequest(_mk_trace(np.arange(10)), NoPrefetcher(), UVMConfig(),
                      record_timeline=True))
    assert not backend.can_replay(_req(np.empty(0, dtype=np.int64)))


def test_pallas_declines_overlong_lanes():
    """Lanes longer than MAX_LANE_ACCESSES would run the int32 LRU touch
    counter out of headroom — they must fall back, not silently wrap."""
    from repro.uvm.backends.pallas_backend import MAX_LANE_ACCESSES

    backend = get_backend("pallas")
    ok = _req(np.zeros(8, dtype=np.int64))
    too_long = _req(np.zeros(8, dtype=np.int64))
    # fake the length with a zero-copy broadcast view: can_replay rejects
    # on len(trace.pages) before touching the contents
    too_long.trace.accesses = np.broadcast_to(
        too_long.trace.accesses[:1], (MAX_LANE_ACCESSES + 1,))
    assert backend.can_replay(ok)
    assert not backend.can_replay(too_long)


def test_pallas_replay_rejects_unpackable():
    backend = get_backend("pallas")
    with pytest.raises(ValueError, match="not packable"):
        backend.replay([_req(np.arange(10), pf=TreePrefetcher())])


def test_numpy_runtime_failure_propagates(monkeypatch):
    """Only *experimental* backends may degrade at runtime: a numpy-engine
    crash must surface, not silently serve legacy results (which would
    let the golden equivalence suite pass vacuously)."""
    from repro.uvm import VectorizedUVMSimulator
    from repro.uvm.backends.numpy_backend import NumpyReplayBackend

    def _boom(self, requests):
        raise IndexError("synthetic engine bug")

    monkeypatch.setattr(NumpyReplayBackend, "replay", _boom)
    with pytest.raises(IndexError, match="synthetic engine bug"):
        VectorizedUVMSimulator().run(_mk_trace(np.arange(10)),
                                     NoPrefetcher())


def test_pallas_runtime_failure_degrades_with_warning(monkeypatch):
    from repro.uvm.backends.pallas_backend import PallasReplayBackend

    def _boom(self, requests):
        raise RuntimeError("synthetic lowering failure")

    monkeypatch.setattr(PallasReplayBackend, "replay", _boom)
    with pytest.warns(RuntimeWarning, match="falling back"):
        stats = dispatch(_req(np.arange(50)), "pallas")
    assert stats.backend == "numpy"


def test_is_native_consistent_with_interpret_policy():
    """On a CPU host the lanes run in interpret mode, so they are not
    native and ``auto`` resolution must prefer the NumPy engine."""
    assert get_backend("pallas").is_native() is False
    assert backend_chain("auto") == ["numpy", "legacy"]


def test_fits_batch_budgets():
    backend = get_backend("pallas")
    assert backend.fits_batch([], (100, 512))
    assert backend.fits_batch([(100, 512)], (100, 512))
    from repro.uvm.backends.pallas_backend import (MAX_BATCH_STATE_PAGES,
                                                   MAX_LANES_PER_BATCH)
    assert not backend.fits_batch([(100, 512)] * MAX_LANES_PER_BATCH,
                                  (100, 512))
    huge_span = MAX_BATCH_STATE_PAGES // 2 + 1
    assert not backend.fits_batch([(100, huge_span)], (100, huge_span))


def test_bucketing_reuses_kernel_shapes():
    assert _bucket(1, 64) == 64
    assert _bucket(64, 64) == 64
    assert _bucket(65, 64) == 128
    assert _bucket(3, 1) == 4
    assert _bucket(1, 1) == 1


def test_pack_lanes_respects_budgets():
    backend = PallasReplayBackend()
    reqs = [_req(np.arange(50)) for _ in range(MAX_LANES_PER_BATCH + 3)]
    batches = backend.pack_lanes(reqs)
    assert sum(len(b) for b in batches) == len(reqs)
    assert sorted(i for b in batches for i in b) == list(range(len(reqs)))
    assert all(len(b) <= MAX_LANES_PER_BATCH for b in batches)
    assert len(batches) == 2


# ---------------------------------------------------------------------------
# multi-lane equivalence (deterministic)
# ---------------------------------------------------------------------------

def test_lane_batch_matches_numpy_mixed_cells():
    """One batch mixing ragged lengths, both packable prefetchers, an
    oversubscribed cell, and a tight-MSHR fault storm."""
    rng = np.random.default_rng(7)
    cases = [
        # cyclic sweep, on-demand
        (np.tile(np.arange(300), 3), NoPrefetcher, None, 64),
        # block prefetch over strided faults
        (np.arange(0, 2000, 7), BlockPrefetcher, None, 64),
        # oversubscribed: working set ~2x capacity, LRU churn
        (np.tile(np.arange(400), 4), NoPrefetcher, 180, 64),
        # oversubscribed + block batches
        (np.tile(np.arange(500), 2), BlockPrefetcher, 300, 64),
        # clustered fault storm under a tiny MSHR
        (rng.integers(0, 4000, size=700), NoPrefetcher, None, 4),
        # short ragged lane
        (np.array([5, 5, 5, 900, 5]), BlockPrefetcher, None, 64),
    ]
    requests = [_req(pages, pf=pf_cls(), cap=cap, mshr=mshr)
                for pages, pf_cls, cap, mshr in cases]
    backend = get_backend("pallas")
    assert all(backend.can_replay(r) for r in requests)
    got = backend.replay(requests)
    want = [dispatch(_req(pages, pf=pf_cls(), cap=cap, mshr=mshr), "numpy")
            for pages, pf_cls, cap, mshr in cases]
    for i, (g, w) in enumerate(zip(got, want)):
        assert g.backend == "pallas"
        _assert_equivalent(g, w, context=f"lane {i}")


# ---------------------------------------------------------------------------
# property-based lane packing (skipped when hypothesis is absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - degraded environment
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    _cell = st_.tuples(
        st_.lists(st_.integers(0, 600), min_size=1, max_size=120),
        st_.sampled_from(["none", "block"]),
        st_.sampled_from([None, 48, 200]),
    )

    @settings(max_examples=15, deadline=None)
    @given(st_.lists(_cell, min_size=1, max_size=5))
    def test_lane_batch_property(cells):
        """A lane-batched pallas replay of N random cells equals N
        independent NumPy replays on every integer counter — ragged
        lengths and oversubscribed (cap=48/200) cells included."""
        def build(spec):
            pages, pf_name, cap = spec
            pf = NoPrefetcher() if pf_name == "none" else BlockPrefetcher()
            return _req(np.asarray(pages), pf=pf, cap=cap)

        backend = get_backend("pallas")
        requests = [build(c) for c in cells]
        assert all(backend.can_replay(r) for r in requests)
        got = backend.replay(requests)
        want = [dispatch(build(c), "numpy") for c in cells]
        for i, (g, w) in enumerate(zip(got, want)):
            _assert_equivalent(g, w, context=f"lane {i}/{cells[i][1:]}")