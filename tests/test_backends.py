"""Replay-backend layer: registry/contract, dispatch fallbacks, and the
jax_pallas multi-lane engine.

The lane-packing property test is the backend's core guarantee: a
lane-batched pallas replay of N random cells must equal N independent
NumPy replays — integer counters exact, cycles/pcie_bytes to 1e-6 —
including ragged trace lengths and oversubscribed (LRU-evicting) cells.
"""
import numpy as np
import pytest

from repro.traces.trace import Trace, make_records
from repro.uvm import UVMConfig
from repro.uvm.backends.pallas_backend import (MAX_LANE_SPAN_PAGES,
                                               MAX_LANES_PER_BATCH,
                                               PallasReplayBackend, _bucket,
                                               lane_family)
from repro.uvm.golden import make_prefetcher as golden_prefetcher
from repro.uvm.prefetchers import (BlockPrefetcher, NoPrefetcher,
                                   OraclePrefetcher, TreePrefetcher)
from repro.uvm.replay_core import (ReplayRequest, available_backends,
                                   backend_chain, dispatch, get_backend,
                                   resolve_backend)

INT_FIELDS = ("n_accesses", "hits", "late", "faults", "prefetch_issued",
              "prefetch_used", "pages_migrated", "pages_evicted")


def _mk_trace(pages, name="synth"):
    pages = np.asarray(pages, dtype=np.int64)
    recs = make_records(len(pages))
    recs["page"] = pages
    return Trace(name, recs, {}, {}, len(pages) * 100)


def _req(pages, pf=None, cap=None, mshr=64):
    config = UVMConfig(device_pages=cap, mshr_entries=mshr)
    return ReplayRequest(_mk_trace(pages), pf or NoPrefetcher(), config)


def _assert_equivalent(got, want, context=""):
    for f in INT_FIELDS:
        assert getattr(got, f) == getattr(want, f), (
            f"{context}: {f} {getattr(got, f)} != {getattr(want, f)}")
    assert got.cycles == pytest.approx(want.cycles, rel=1e-6), context
    assert got.pcie_bytes == pytest.approx(want.pcie_bytes, rel=1e-6), context


# ---------------------------------------------------------------------------
# registry + dispatch contract
# ---------------------------------------------------------------------------

def test_registry_has_builtin_backends():
    assert {"legacy", "numpy", "pallas"} <= set(available_backends())
    for name in ("legacy", "numpy", "pallas"):
        assert get_backend(name).name == name
    with pytest.raises(ValueError, match="unknown replay backend"):
        get_backend("cuda")


def test_backend_chains_end_in_legacy():
    assert backend_chain("legacy") == ["legacy"]
    assert backend_chain("numpy") == ["numpy", "legacy"]
    assert backend_chain("pallas") == ["pallas", "numpy", "legacy"]
    assert backend_chain("auto")[-1] == "legacy"
    with pytest.raises(ValueError):
        backend_chain("mps")


def test_dispatch_records_backend():
    assert dispatch(_req(np.arange(200) % 64), "numpy").backend == "numpy"
    assert dispatch(_req(np.arange(200) % 64), "pallas").backend == "pallas"
    assert dispatch(_req(np.arange(200) % 64), "legacy").backend == "legacy"


def test_unpackable_request_falls_back_visibly():
    """A cell the lanes decline (page span beyond the per-lane ceiling)
    drops down the chain to the NumPy path and says so in the stats
    instead of silently covering."""
    pages = np.array([0, MAX_LANE_SPAN_PAGES + 1, 0, 7], dtype=np.int64)
    r = _req(pages)
    assert not get_backend("pallas").can_replay(r)
    assert resolve_backend(r, "pallas").name == "numpy"
    assert dispatch(r, "pallas").backend == "numpy"


def test_every_prefetcher_family_is_packable():
    """All five paper-facing prefetcher families replay in-kernel: the
    pallas chain keeps them instead of falling back."""
    pages = np.arange(200) % 64
    tr = _mk_trace(pages)
    config = UVMConfig()
    for name in ("none", "block", "tree", "learned", "oracle"):
        r = ReplayRequest(_mk_trace(pages),
                          golden_prefetcher(name, tr, config), config)
        assert get_backend("pallas").can_replay(r), name
        assert resolve_backend(r, "pallas").name == "pallas", name
        assert dispatch(r, "pallas").backend == "pallas", name


def test_pallas_declines_timelines_and_empty_traces():
    backend = get_backend("pallas")
    assert not backend.can_replay(
        ReplayRequest(_mk_trace(np.arange(10)), NoPrefetcher(), UVMConfig(),
                      record_timeline=True))
    assert not backend.can_replay(_req(np.empty(0, dtype=np.int64)))


def test_pallas_declines_overlong_lanes():
    """Lanes longer than MAX_LANE_ACCESSES would run the int32 LRU touch
    counter out of headroom — they must fall back, not silently wrap."""
    from repro.uvm.backends.pallas_backend import MAX_LANE_ACCESSES

    backend = get_backend("pallas")
    ok = _req(np.zeros(8, dtype=np.int64))
    too_long = _req(np.zeros(8, dtype=np.int64))
    # fake the length with a zero-copy broadcast view: can_replay rejects
    # on len(trace.pages) before touching the contents
    too_long.trace.accesses = np.broadcast_to(
        too_long.trace.accesses[:1], (MAX_LANE_ACCESSES + 1,))
    assert backend.can_replay(ok)
    assert not backend.can_replay(too_long)


def test_pallas_replay_rejects_unpackable():
    backend = get_backend("pallas")
    too_wide = _req(np.array([0, MAX_LANE_SPAN_PAGES + 1], dtype=np.int64))
    with pytest.raises(ValueError, match="not packable"):
        backend.replay([too_wide])


def test_pallas_declines_oversized_oracle_lookahead():
    """The oracle scan window is a static kernel shape: absurd lookaheads
    fall back instead of bloating the kernel."""
    from repro.uvm.backends.pallas_backend import MAX_ORACLE_LOOKAHEAD

    backend = get_backend("pallas")
    pages = np.arange(100, dtype=np.int64)
    ok = _req(pages, pf=OraclePrefetcher(pages))
    too_wide = _req(pages, pf=OraclePrefetcher(
        pages, lookahead=MAX_ORACLE_LOOKAHEAD + 1))
    assert backend.can_replay(ok)
    assert not backend.can_replay(too_wide)
    assert dispatch(too_wide, "pallas").backend == "numpy"


def test_numpy_runtime_failure_propagates(monkeypatch):
    """Only *experimental* backends may degrade at runtime: a numpy-engine
    crash must surface, not silently serve legacy results (which would
    let the golden equivalence suite pass vacuously)."""
    from repro.uvm import VectorizedUVMSimulator
    from repro.uvm.backends.numpy_backend import NumpyReplayBackend

    def _boom(self, requests):
        raise IndexError("synthetic engine bug")

    monkeypatch.setattr(NumpyReplayBackend, "replay", _boom)
    with pytest.raises(IndexError, match="synthetic engine bug"):
        VectorizedUVMSimulator().run(_mk_trace(np.arange(10)),
                                     NoPrefetcher())


def test_pallas_runtime_failure_degrades_with_warning(monkeypatch):
    from repro.uvm.backends.pallas_backend import PallasReplayBackend

    def _boom(self, requests):
        raise RuntimeError("synthetic lowering failure")

    monkeypatch.setattr(PallasReplayBackend, "replay", _boom)
    with pytest.warns(RuntimeWarning, match="falling back"):
        stats = dispatch(_req(np.arange(50)), "pallas")
    assert stats.backend == "numpy"


def test_is_native_consistent_with_interpret_policy():
    """On a CPU host the lanes run in interpret mode, so they are not
    native and ``auto`` resolution must prefer the NumPy engine."""
    assert get_backend("pallas").is_native() is False
    assert backend_chain("auto") == ["numpy", "legacy"]


def test_fits_batch_budgets():
    backend = get_backend("pallas")
    assert backend.fits_batch([], ("demand", "lru", 100, 512))
    assert backend.fits_batch([("demand", "lru", 100, 512)],
                              ("demand", "lru", 100, 512))
    from repro.uvm.backends.pallas_backend import (MAX_BATCH_STATE_PAGES,
                                                   MAX_LANES_PER_BATCH)
    assert not backend.fits_batch(
        [("demand", "lru", 100, 512)] * MAX_LANES_PER_BATCH,
        ("demand", "lru", 100, 512))
    huge_span = MAX_BATCH_STATE_PAGES // 2 + 1
    assert not backend.fits_batch([("demand", "lru", 100, huge_span)],
                                  ("demand", "lru", 100, huge_span))


def test_fits_batch_never_mixes_families():
    """A lane batch is one kernel: incompatible prefetcher families must
    never share it, whatever the shape budgets say."""
    backend = get_backend("pallas")
    assert not backend.fits_batch([("demand", "lru", 100, 512)],
                                  ("tree", "lru", 100, 512))
    assert not backend.fits_batch([("tree", "lru", 100, 512)],
                                  ("learned", "lru", 100, 512))
    # different oracle lookaheads are different kernels too
    assert not backend.fits_batch([("oracle/96", "lru", 100, 512)],
                                  ("oracle/32", "lru", 100, 512))
    assert backend.fits_batch([("oracle/96", "lru", 100, 512)],
                              ("oracle/96", "lru", 100, 512))


def test_fits_batch_never_mixes_eviction_policies():
    """Victim selection and the extra policy carry are static kernel
    structure: lanes of different eviction policies must never share a
    batch, whatever the shape budgets say."""
    backend = get_backend("pallas")
    for fam in ("demand", "tree", "learned", "oracle/96"):
        assert not backend.fits_batch([(fam, "lru", 100, 512)],
                                      (fam, "random", 100, 512))
        assert not backend.fits_batch([(fam, "random", 100, 512)],
                                      (fam, "hotcold", 100, 512))
        assert backend.fits_batch([(fam, "hotcold", 100, 512)],
                                  (fam, "hotcold", 100, 512))


def test_lane_shape_carries_policy():
    from repro.uvm.backends.pallas_backend import _lane_shape

    pages = np.arange(120) % 64
    for pol in ("lru", "random", "hotcold"):
        req = ReplayRequest(_mk_trace(pages), NoPrefetcher(),
                            UVMConfig(device_pages=32, eviction=pol))
        fam, shape_pol, t, sp = _lane_shape(req)
        assert (fam, shape_pol, t) == ("demand", pol, 120)


def test_pack_lanes_never_cobuckets_policies():
    """Interleaved cells of every eviction policy pack into
    policy-homogeneous batches covering every request exactly once."""
    backend = PallasReplayBackend()
    pages = np.arange(200) % 64
    policies = ("lru", "random", "hotcold", "lru", "random", "hotcold")
    reqs = [ReplayRequest(_mk_trace(pages), NoPrefetcher(),
                          UVMConfig(device_pages=48, eviction=pol))
            for pol in policies]
    batches = backend.pack_lanes(reqs)
    assert sorted(i for b in batches for i in b) == list(range(len(reqs)))
    for b in batches:
        pols = {reqs[i].config.eviction for i in b}
        assert len(pols) == 1, f"mixed-policy batch: {pols}"
    # 3 policies, identical shapes -> exactly 3 batches
    assert len(batches) == 3


def test_policy_lane_batches_match_numpy():
    """One replay() call covering every (family, policy) bucket under
    oversubscription equals independent NumPy replays."""
    perm = (np.arange(2 * 512) * 7) % (2 * 512)
    cases = [(pf, pol)
             for pf in ("none", "block", "tree", "learned", "oracle")
             for pol in ("random", "hotcold")]

    def build(pf, pol):
        tr = _mk_trace(np.concatenate([perm, perm + 1024]))
        config = UVMConfig(device_pages=600, mshr_entries=16, eviction=pol)
        return ReplayRequest(tr, golden_prefetcher(pf, tr, config), config)

    backend = get_backend("pallas")
    requests = [build(pf, pol) for pf, pol in cases]
    assert all(backend.can_replay(r) for r in requests)
    got = backend.replay(requests)
    want = [dispatch(build(pf, pol), "numpy") for pf, pol in cases]
    for (pf, pol), g, w in zip(cases, got, want):
        assert g.backend == "pallas" and g.eviction == pol
        assert w.pages_evicted > 0, "vacuous: no eviction churn"
        _assert_equivalent(g, w, context=f"{pf}/{pol}")


def test_lane_family_buckets():
    assert lane_family(NoPrefetcher()) == "demand"
    assert lane_family(BlockPrefetcher()) == "demand"
    assert lane_family(TreePrefetcher()) == "tree"
    pages = np.arange(10, dtype=np.int64)
    assert lane_family(OraclePrefetcher(pages)) == "oracle/96"
    tr = _mk_trace(pages)
    assert lane_family(
        golden_prefetcher("learned", tr, UVMConfig())) == "learned"

    class Unknown(NoPrefetcher):
        pass

    assert lane_family(Unknown()) is None


def test_bucketing_reuses_kernel_shapes():
    assert _bucket(1, 64) == 64
    assert _bucket(64, 64) == 64
    assert _bucket(65, 64) == 128
    assert _bucket(3, 1) == 4
    assert _bucket(1, 1) == 1


def test_pack_lanes_respects_budgets():
    backend = PallasReplayBackend()
    reqs = [_req(np.arange(50)) for _ in range(MAX_LANES_PER_BATCH + 3)]
    batches = backend.pack_lanes(reqs)
    assert sum(len(b) for b in batches) == len(reqs)
    assert sorted(i for b in batches for i in b) == list(range(len(reqs)))
    assert all(len(b) <= MAX_LANES_PER_BATCH for b in batches)
    assert len(batches) == 2


def _mixed_family_requests():
    pages = np.arange(200) % 64
    tr = _mk_trace(pages)
    config = UVMConfig()
    reqs = []
    for name in ("none", "tree", "block", "learned", "oracle",
                 "tree", "none", "learned", "oracle", "block"):
        reqs.append(ReplayRequest(_mk_trace(pages),
                                  golden_prefetcher(name, tr, config),
                                  config))
    return reqs


def test_pack_lanes_never_cobuckets_families():
    """Interleaved cells of every prefetcher family pack into
    family-homogeneous batches covering every request exactly once."""
    backend = PallasReplayBackend()
    reqs = _mixed_family_requests()
    batches = backend.pack_lanes(reqs)
    assert sorted(i for b in batches for i in b) == list(range(len(reqs)))
    for b in batches:
        fams = {lane_family(reqs[i].prefetcher) for i in b}
        assert len(fams) == 1, f"mixed-family batch: {fams}"
    # 4 families -> exactly 4 batches (shapes are identical, so nothing
    # else may force a flush)
    assert len(batches) == 4


# ---------------------------------------------------------------------------
# multi-lane equivalence (deterministic)
# ---------------------------------------------------------------------------

def test_lane_batch_matches_numpy_mixed_cells():
    """One batch mixing ragged lengths, both demand-family prefetchers,
    an oversubscribed cell, and a tight-MSHR fault storm."""
    rng = np.random.default_rng(7)
    cases = [
        # cyclic sweep, on-demand
        (np.tile(np.arange(300), 3), NoPrefetcher, None, 64),
        # block prefetch over strided faults
        (np.arange(0, 2000, 7), BlockPrefetcher, None, 64),
        # oversubscribed: working set ~2x capacity, LRU churn
        (np.tile(np.arange(400), 4), NoPrefetcher, 180, 64),
        # oversubscribed + block batches
        (np.tile(np.arange(500), 2), BlockPrefetcher, 300, 64),
        # clustered fault storm under a tiny MSHR
        (rng.integers(0, 4000, size=700), NoPrefetcher, None, 4),
        # short ragged lane
        (np.array([5, 5, 5, 900, 5]), BlockPrefetcher, None, 64),
    ]
    requests = [_req(pages, pf=pf_cls(), cap=cap, mshr=mshr)
                for pages, pf_cls, cap, mshr in cases]
    backend = get_backend("pallas")
    assert all(backend.can_replay(r) for r in requests)
    got = backend.replay(requests)
    want = [dispatch(_req(pages, pf=pf_cls(), cap=cap, mshr=mshr), "numpy")
            for pages, pf_cls, cap, mshr in cases]
    for i, (g, w) in enumerate(zip(got, want)):
        assert g.backend == "pallas"
        _assert_equivalent(g, w, context=f"lane {i}")


def test_all_family_lane_replay_matches_numpy():
    """Every prefetcher family through the lanes in one replay() call —
    tree escalation churn under oversubscription, learned decision
    streams, oracle lookahead windows — equals independent NumPy
    replays."""
    rng = np.random.default_rng(11)
    perm = (np.arange(3 * 512) * 7) % (3 * 512)
    cases = [
        ("tree", np.arange(0, 2000, 3), None, 64),
        ("tree", perm.repeat(2), 700, 16),      # escalate + evict churn
        ("learned", np.tile(np.arange(350), 3), None, 64),
        ("learned", np.tile(np.arange(400), 4), 180, 64),
        ("oracle", rng.integers(0, 3000, size=500), None, 64),
        ("oracle", np.tile(np.arange(400), 3), 220, 64),
        ("none", np.tile(np.arange(300), 2), None, 64),
        ("block", np.arange(0, 1500, 5), 200, 64),
    ]

    def build(name, pages):
        tr = _mk_trace(np.asarray(pages, dtype=np.int64))
        return tr, golden_prefetcher(name, tr, UVMConfig())

    backend = get_backend("pallas")
    requests = []
    for name, pages, cap, mshr in cases:
        tr, pf = build(name, pages)
        requests.append(ReplayRequest(
            tr, pf, UVMConfig(device_pages=cap, mshr_entries=mshr)))
    assert all(backend.can_replay(r) for r in requests)
    got = backend.replay(requests)
    want = []
    for name, pages, cap, mshr in cases:
        tr, pf = build(name, pages)
        want.append(dispatch(ReplayRequest(
            tr, pf, UVMConfig(device_pages=cap, mshr_entries=mshr)),
            "numpy"))
    for (name, _, cap, _), g, w in zip(cases, got, want):
        assert g.backend == "pallas"
        _assert_equivalent(g, w, context=f"{name} cap={cap}")


# ---------------------------------------------------------------------------
# property-based lane packing (skipped when hypothesis is absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - degraded environment
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    _cell = st_.tuples(
        st_.lists(st_.integers(0, 600), min_size=1, max_size=120),
        st_.sampled_from(["none", "block", "tree", "learned", "oracle"]),
        st_.sampled_from([None, 48, 200]),
        st_.sampled_from(["lru", "random", "hotcold"]),
    )

    @settings(max_examples=15, deadline=None)
    @given(st_.lists(_cell, min_size=1, max_size=5))
    def test_lane_batch_property(cells):
        """A lane-batched pallas replay of N random cells — every
        prefetcher family and eviction policy — equals N independent
        NumPy replays on every integer counter; ragged lengths and
        oversubscribed (cap=48/200) cells included.  Interleaved families
        and policies exercise the homogeneous packing."""
        def build(spec):
            pages, pf_name, cap, eviction = spec
            tr = _mk_trace(np.asarray(pages, dtype=np.int64))
            config = UVMConfig(device_pages=cap, mshr_entries=64,
                               eviction=eviction)
            return ReplayRequest(tr, golden_prefetcher(pf_name, tr, config),
                                 config)

        backend = get_backend("pallas")
        requests = [build(c) for c in cells]
        assert all(backend.can_replay(r) for r in requests)
        for b in backend.pack_lanes(requests):
            assert len({lane_family(requests[i].prefetcher)
                        for i in b}) == 1
            assert len({requests[i].config.eviction for i in b}) == 1
        got = backend.replay(requests)
        want = [dispatch(build(c), "numpy") for c in cells]
        for i, (g, w) in enumerate(zip(got, want)):
            _assert_equivalent(g, w, context=f"lane {i}/{cells[i][1:]}")

# ---------------------------------------------------------------------------
# kernel-executable disk cache (REPRO_KERNEL_CACHE)
# ---------------------------------------------------------------------------

def _exec_cache_files(d):
    import os
    return [f for f in os.listdir(d) if f.endswith(".jaxexec")]


def test_kernel_exec_cache_roundtrip(tmp_path, monkeypatch):
    """The compiled-lane cache: the first build serializes to
    REPRO_KERNEL_CACHE, a later process (simulated by clearing the
    in-process memo) deserializes bit-equal, a corrupt entry falls back
    to a fresh build (and is rewritten), and ``0`` disables the cache."""
    import os
    from repro.uvm.backends import pallas_backend as pb

    cache = tmp_path / "kernels"
    monkeypatch.setenv("REPRO_KERNEL_CACHE", str(cache))
    pages = np.tile(np.arange(40), 2)
    backend = get_backend("pallas")

    pb._lane_replay_exec.cache_clear()
    want = backend.replay([_req(pages, cap=24)])[0]     # build + serialize
    files = _exec_cache_files(cache)
    assert files, "no serialized executable written"

    pb._lane_replay_exec.cache_clear()                  # "new process"
    got = backend.replay([_req(pages, cap=24)])[0]      # deserialize path
    _assert_equivalent(got, want, "exec-cache deserialize")

    for f in files:                                     # corrupt the entry
        with open(os.path.join(str(cache), f), "wb") as fh:
            fh.write(b"not a serialized executable")
    pb._lane_replay_exec.cache_clear()
    got = backend.replay([_req(pages, cap=24)])[0]      # fallback build
    _assert_equivalent(got, want, "exec-cache corrupt fallback")

    monkeypatch.setenv("REPRO_KERNEL_CACHE", "0")       # disabled
    assert pb._kernel_cache_dir() is None
    pb._lane_replay_exec.cache_clear()
    got = backend.replay([_req(pages, cap=24)])[0]
    _assert_equivalent(got, want, "exec-cache disabled")
    pb._lane_replay_exec.cache_clear()
