import os
import sys

# src layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_trace():
    """One small, cached trace for cross-test reuse."""
    from repro.traces import GPUModel, generate_benchmark
    spec = generate_benchmark("ATAX", scale=0.25)
    return GPUModel().run(spec)


@pytest.fixture(scope="session")
def pathfinder_trace():
    from repro.traces import GPUModel, generate_benchmark
    spec = generate_benchmark("Pathfinder", scale=0.25)
    return GPUModel().run(spec)
