"""Predictor-family config layer (``repro.core.families``): block-factory
resolution, digests, the family axis on PredictorService, and the
windowed-attention kernel backing the transformer-local family."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as A
from repro.core import families


def test_family_registry_shape():
    assert families.MODEL_FAMILIES[0] == "simplified"
    assert set(families.MODEL_FAMILY_BLOCKS) == {"transformer",
                                                 "transformer-local"}
    assert set(families.MODEL_FAMILIES) == (
        {"simplified"} | set(families.MODEL_FAMILY_BLOCKS))


def test_validate_family_rejects_unknown():
    families.validate_family("transformer")        # no raise
    with pytest.raises(ValueError, match="unknown model family"):
        families.validate_family("lstm")


def test_family_config_resolution():
    """The block overrides resolve onto the paper's configs: simplified is
    the revised (quantized, bypassing) config; the transformer families
    are the full reference encoder, full vs windowed attention."""
    simp = families.family_config("simplified", n_classes=10)
    assert simp.attention == "hlsh" and simp.quantize
    assert simp.features == families.REVISED_FEATURES
    assert simp.n_layers == 1 and simp.revised_dims
    # the §6 bypass indicator: dominant-delta traces skip attention
    bypassed = families.family_config("simplified", n_classes=10,
                                      convergence=0.9)
    assert bypassed.attention == "bypass"

    tf = families.family_config("transformer", n_classes=10)
    assert tf.arch == "transformer" and tf.attention == "full"
    assert tf.n_layers == 2 and not tf.quantize
    assert set(tf.features) == set(families.EMB_DIMS)

    loc = families.family_config("transformer-local", n_classes=10)
    assert loc.attention == "local" and loc.local_window == 8
    # the families agree on everything except the block overrides
    assert dataclasses.replace(
        loc, attention="full", local_window=tf.local_window) == tf


def test_family_config_quantize_guard():
    """The reference Transformer is the paper's *unquantized* baseline:
    asking for a quantized transformer must not silently produce one."""
    cfg = families.family_config("transformer", n_classes=5, quantize=True)
    assert not cfg.quantize


def test_config_digests_distinct_and_stable():
    digests = {fam: families.config_digest(
        families.family_config(fam, n_classes=7))
        for fam in families.MODEL_FAMILIES}
    assert len(set(digests.values())) == len(families.MODEL_FAMILIES)
    # deterministic across calls (the predcache key depends on this)
    again = families.config_digest(
        families.family_config("transformer", n_classes=7))
    assert again == digests["transformer"]
    # and sensitive to any config axis, not just the family name
    moved = families.config_digest(dataclasses.replace(
        families.family_config("transformer", n_classes=7), n_heads=8))
    assert moved != digests["transformer"]


def test_service_model_config_property():
    """PredictorService.model_config digests the *resolved* family config
    with trace-determined fields pinned to sentinels — equal across
    traces, distinct across families, distinct across service knobs that
    reach the architecture."""
    from repro.core.service import PredictorService

    a = PredictorService(steps=5, model_family="transformer")
    b = PredictorService(steps=9, model_family="transformer")
    assert a.model_config == b.model_config        # steps is keyed separately
    c = PredictorService(steps=5, model_family="transformer-local")
    assert a.model_config != c.model_config


def test_local_attention_matches_full_when_window_covers():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 12, 8)), jnp.float32)
    full = A.full_attention(x, x, x)
    loc = A.local_attention(x, x, x, window=11)    # band covers everything
    np.testing.assert_allclose(np.asarray(loc), np.asarray(full), atol=1e-5)


def test_local_attention_windowed_semantics():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 16, 8)), jnp.float32)
    loc = A.local_attention(x, x, x, window=2)
    assert loc.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(loc)))
    # a small window really changes the output vs full attention
    full = A.full_attention(x, x, x)
    assert not np.allclose(np.asarray(loc), np.asarray(full), atol=1e-4)
    # window=0 attends only to self: softmax over one logit -> V itself
    self_only = A.local_attention(x, x, x, window=0)
    np.testing.assert_allclose(np.asarray(self_only), np.asarray(x),
                               atol=1e-5)
