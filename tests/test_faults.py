"""Fault-injection plane: deterministic draws, bounded ledgers, artifact
corruption, transient backend-fault semantics, and the chaos convergence
harness (SIGKILLed drivers resume to byte-identical grids)."""
import json
import os
import time

import pytest

from repro.uvm import faults
from repro.uvm.faults import (FaultPlan, FaultSpec, InjectedFault,
                              attempt_budget, rows_digest)


def _plan(tmp_path, *specs, seed=0):
    return FaultPlan(seed=seed, ledger_dir=str(tmp_path / "ledger"),
                     specs=tuple(specs)).validate()


# ---------------------------------------------------------------------------
# plan validation + env plumbing
# ---------------------------------------------------------------------------

def test_spec_and_plan_validation(tmp_path):
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("nope.site", "kill").validate()
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("cell.start", "explode").validate()
    with pytest.raises(ValueError, match="prob"):
        FaultSpec("cell.start", "kill", prob=1.5).validate()
    with pytest.raises(ValueError, match="max_count"):
        FaultSpec("cell.start", "kill", max_count=0).validate()
    with pytest.raises(ValueError, match="fraction"):
        FaultSpec("cell.result.artifact", "truncate",
                  fraction=1.0).validate()
    # bounded specs demand the shared ledger
    with pytest.raises(ValueError, match="ledger_dir"):
        FaultPlan(seed=0, specs=(
            FaultSpec("cell.start", "kill", max_count=1),)).validate()
    # round-trip through JSON (the REPRO_FAULT_PLAN wire format)
    plan = _plan(tmp_path, FaultSpec("cell.start", "raise", prob=0.5))
    assert faults.plan_from_dict(json.loads(plan.to_json())) == plan


def test_active_injector_follows_env(tmp_path, monkeypatch):
    faults.reset()
    monkeypatch.delenv(faults.FAULT_PLAN_ENV, raising=False)
    assert faults.active() is None
    plan = _plan(tmp_path, FaultSpec("cell.start", "delay", delay_s=0.0))
    monkeypatch.setenv(faults.FAULT_PLAN_ENV, plan.to_json())
    inj = faults.active()
    assert inj is not None and inj.plan == plan
    assert faults.active() is inj        # cached while the env is stable
    monkeypatch.delenv(faults.FAULT_PLAN_ENV)
    assert faults.active() is None
    faults.reset()


# ---------------------------------------------------------------------------
# determinism + the shared ledger
# ---------------------------------------------------------------------------

def test_draws_are_deterministic_and_seed_sensitive(tmp_path):
    spec = FaultSpec("cell.start", "raise", prob=0.5, max_count=None)
    fired = {}
    for seed in (0, 1):
        inj = faults.FaultInjector(FaultPlan(seed=seed, specs=(spec,)))
        hits = set()
        for key in (f"cell{i}" for i in range(64)):
            try:
                inj.fire("cell.start", key)
            except InjectedFault:
                hits.add(key)
        fired[seed] = hits
        # same plan, fresh injector: identical decisions
        inj2 = faults.FaultInjector(FaultPlan(seed=seed, specs=(spec,)))
        rehits = set()
        for key in (f"cell{i}" for i in range(64)):
            try:
                inj2.fire("cell.start", key)
            except InjectedFault:
                rehits.add(key)
        assert rehits == hits
    assert 8 < len(fired[0]) < 56        # prob=0.5 really is probabilistic
    assert fired[0] != fired[1]          # and the seed moves it


def test_ledger_bounds_firing_across_injectors(tmp_path):
    plan = _plan(tmp_path,
                 FaultSpec("cell.start", "raise", prob=1.0, max_count=2))
    n = 0
    for _ in range(5):
        # a fresh injector per attempt = a restarted worker/driver
        inj = faults.FaultInjector(plan)
        try:
            inj.fire("cell.start", "victim")
        except InjectedFault:
            n += 1
    assert n == 2                        # the on-disk ledger is shared
    # a different key has its own budget
    with pytest.raises(InjectedFault):
        faults.FaultInjector(plan).fire("cell.start", "other")


def test_match_narrows_and_delay_sleeps(tmp_path):
    plan = _plan(tmp_path,
                 FaultSpec("cell.start", "raise", prob=1.0, max_count=None,
                           match="abc"),
                 FaultSpec("worker.loop", "delay", prob=1.0,
                           max_count=None, delay_s=0.05))
    inj = faults.FaultInjector(plan)
    inj.fire("cell.start", "zzz")        # no match: no fault
    with pytest.raises(InjectedFault):
        inj.fire("cell.start", "xxabcxx")
    t0 = time.monotonic()
    inj.fire("worker.loop", "w0")
    assert time.monotonic() - t0 >= 0.05


def test_corrupt_truncates_and_flips_bits(tmp_path):
    data = bytes(range(256)) * 8
    plan = _plan(tmp_path,
                 FaultSpec("cell.result.artifact", "truncate", prob=1.0,
                           max_count=1, fraction=0.25),
                 FaultSpec("trace.artifact", "bitflip", prob=1.0,
                           max_count=1))
    inj = faults.FaultInjector(plan)

    p1 = str(tmp_path / "a.bin")
    with open(p1, "wb") as f:
        f.write(data)
    inj.corrupt("cell.result.artifact", p1, "k1")
    assert os.path.getsize(p1) == len(data) // 4
    inj.corrupt("cell.result.artifact", p1, "k1")   # budget spent
    assert os.path.getsize(p1) == len(data) // 4

    p2 = str(tmp_path / "b.bin")
    with open(p2, "wb") as f:
        f.write(data)
    inj.corrupt("trace.artifact", p2, "k2")
    with open(p2, "rb") as f:
        got = f.read()
    assert len(got) == len(data)
    diff = [i for i in range(len(data)) if got[i] != data[i]]
    assert len(diff) == 1                # exactly one flipped bit
    assert bin(got[diff[0]] ^ data[diff[0]]).count("1") == 1


# ---------------------------------------------------------------------------
# transient backend faults: retried, never degraded, never swallowed
# ---------------------------------------------------------------------------

def _small_request():
    from repro.uvm.replay_core import ReplayRequest
    from repro.uvm.sweep import SweepCell, prepare_cell

    trace, config, prefetcher, _ = prepare_cell(
        SweepCell("ATAX", "none", scale=0.25, backend="pallas"))
    return ReplayRequest(trace, prefetcher, config)


def test_injected_backend_fault_is_transient(tmp_path, monkeypatch):
    from repro.uvm.replay_core import TransientBackendFault

    plan = _plan(tmp_path, FaultSpec("backend.replay", "raise", prob=1.0,
                                     max_count=1))
    monkeypatch.setenv(faults.FAULT_PLAN_ENV, plan.to_json())
    faults.reset()
    try:
        with pytest.raises(TransientBackendFault) as exc:
            faults.fire("backend.replay", "8:ATAX")
        assert isinstance(exc.value, InjectedFault)
        faults.fire("backend.replay", "8:ATAX")      # ledger spent: clean
    finally:
        monkeypatch.delenv(faults.FAULT_PLAN_ENV)
        faults.reset()


def test_dispatch_reraises_transient_instead_of_degrading(monkeypatch):
    """A transient pallas fault must NOT degrade to numpy (that would
    permanently change the row's backend column); plain runtime faults
    still degrade with a warning, and numpy/legacy errors always
    propagate — the golden equivalence can never pass vacuously."""
    from repro.uvm.backends.numpy_backend import NumpyReplayBackend
    from repro.uvm.backends.pallas_backend import PallasReplayBackend
    from repro.uvm.replay_core import TransientBackendFault, dispatch

    req = _small_request()

    def _transient(self, requests):
        raise TransientBackendFault("device preempted")

    monkeypatch.setattr(PallasReplayBackend, "replay", _transient)
    with pytest.raises(TransientBackendFault):
        dispatch(req, "pallas")

    def _hard(self, requests):
        raise RuntimeError("lowering exploded")

    monkeypatch.setattr(PallasReplayBackend, "replay", _hard)
    with pytest.warns(RuntimeWarning, match="falling back"):
        stats = dispatch(req, "pallas")
    assert stats.hits + stats.late + stats.faults > 0

    # non-experimental backends are never degraded around — their
    # failures (transient or not) reach the caller
    monkeypatch.setattr(NumpyReplayBackend, "replay", _hard)
    with pytest.raises(RuntimeError, match="lowering exploded"):
        dispatch(req, "numpy")


# ---------------------------------------------------------------------------
# convergence digests + attempt budgets
# ---------------------------------------------------------------------------

def test_rows_digest_ignores_only_volatile_columns():
    base = [{"bench": "ATAX", "hit_rate": 0.5, "seconds": 1.0,
             "retries": 0, "backend": "pallas", "quarantined": False}]
    same = [dict(base[0], seconds=9.0, retries=3)]
    assert rows_digest(base) == rows_digest(same)
    for col, val in (("hit_rate", 0.6), ("backend", "numpy"),
                     ("quarantined", True)):
        assert rows_digest([dict(base[0], **{col: val})]) \
            != rows_digest(base)


def test_attempt_budget_covers_worst_case_sabotage(tmp_path):
    plan = _plan(tmp_path,
                 FaultSpec("cell.start", "kill", max_count=2),
                 FaultSpec("cell.result.write", "kill", max_count=1),
                 FaultSpec("cell.result.artifact", "bitflip", max_count=3),
                 FaultSpec("backend.replay", "raise", max_count=1),
                 FaultSpec("worker.loop", "kill", max_count=5),
                 FaultSpec("cell.start", "delay", max_count=7))
    # 2+1+3+1 consuming, worker kills and delays don't burn attempts
    assert attempt_budget(plan, margin=2) == 9


# ---------------------------------------------------------------------------
# the chaos convergence harness (SIGKILLed drivers, corrupted artifacts)
# ---------------------------------------------------------------------------

def test_chaos_sweep_converges_byte_identical(tmp_path):
    """End to end: a serial sweep driver is SIGKILLed mid-cell and mid
    cell-file write, its cached trace is truncated, a backend fault is
    injected — and the restarted/resumed grid is byte-identical to the
    fault-free baseline with an empty quarantine manifest."""
    out = str(tmp_path / "chaos")
    plan = FaultPlan(seed=1, ledger_dir=os.path.join(out, "ledger"), specs=(
        FaultSpec("cell.start", "kill", prob=0.6, max_count=1),
        FaultSpec("cell.result.write", "kill", prob=0.6, max_count=1),
        FaultSpec("cell.result.artifact", "bitflip", prob=0.6,
                  max_count=1),
        FaultSpec("trace.artifact", "truncate", prob=1.0, max_count=1),
    ))
    report = faults.run_chaos_check(
        out, benches="ATAX,Pathfinder", prefetchers="none,tree",
        backend="numpy", workers=1, scale=0.25, plan=plan, verbose=False)
    assert report["cells"] == 4
    assert report["faults_fired"] >= 3   # the plan really injected
    assert report["restarts"] >= 1       # the driver really died
