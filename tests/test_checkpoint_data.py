"""Checkpoint manager + data pipeline: atomicity, async, checksums, exact
resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import TokenPipeline


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    p = _params()
    mgr.save(10, p, {"note": "x"})
    restored, extra = mgr.restore(p)
    assert extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(p["a"]))


def test_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _params())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    p = _params()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, p, {"step": s})
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checksum_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    p = _params()
    path = mgr.save(5, p)
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr.flat[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError):
        mgr.restore(p)


def test_elastic_restore_with_shardings(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    p = _params()
    mgr.save(7, p)
    from repro.distributed.sharding import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda x: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), p)
    restored, _ = mgr.restore(p, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                  np.asarray(p["nested"]["b"]))


def test_pipeline_exact_resume():
    a = TokenPipeline(vocab=1000, seq_len=32, batch_size=4, seed=3)
    batches = [a.next_batch() for _ in range(5)]
    state = a.state()
    later = [a.next_batch() for _ in range(3)]

    b = TokenPipeline(vocab=1000, seq_len=32, batch_size=4, seed=3)
    b.restore(state)
    resumed = [b.next_batch() for _ in range(3)]
    for x, y in zip(later, resumed):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_pipeline_rank_disjoint():
    a = TokenPipeline(vocab=1000, seq_len=32, batch_size=4, seed=3, rank=0,
                      world=2)
    b = TokenPipeline(vocab=1000, seq_len=32, batch_size=4, seed=3, rank=1,
                      world=2)
    assert not np.array_equal(a.next_batch()["tokens"],
                              b.next_batch()["tokens"])
