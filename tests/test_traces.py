"""Trace substrate tests: generator validity, scheduling structure,
determinism."""
import numpy as np
import pytest

from repro.traces import BENCHMARKS, GPUModel, generate_benchmark
from repro.traces.gpu_model import GPUModelConfig


@pytest.mark.parametrize("name", sorted(BENCHMARKS))
def test_generator_valid(name):
    spec = generate_benchmark(name, scale=0.25)
    assert spec.total_accesses > 1000
    for s in spec.streams[:50]:
        assert len(s.pcs) == len(s.pages) == len(s.arrays)
        assert (s.pages >= 0).all()


def test_gpu_model_fields(small_trace):
    a = small_trace.accesses
    assert (a["tpc"] == a["sm"] // 2).all()
    assert a["sm"].max() < 28
    assert a["warp"].max() < 64
    assert len(small_trace) > 1000


def test_determinism():
    spec = generate_benchmark("NW", scale=0.2)
    t1 = GPUModel(GPUModelConfig(seed=3)).run(spec)
    t2 = GPUModel(GPUModelConfig(seed=3)).run(spec)
    assert np.array_equal(t1.accesses, t2.accesses)


def test_seed_changes_schedule():
    spec = generate_benchmark("NW", scale=0.2)
    t1 = GPUModel(GPUModelConfig(seed=1)).run(spec)
    t2 = GPUModel(GPUModelConfig(seed=2)).run(spec)
    assert not np.array_equal(t1.accesses["page"][:5000],
                              t2.accesses["page"][:5000])


def test_mv_kernels_have_dominant_delta():
    """The paper's §5.3 premise: ATAX/BICG/MVT per-SM streams have one
    dominant page delta (>95%)."""
    for name in ("ATAX", "BICG", "MVT"):
        tr = GPUModel().run(generate_benchmark(name, scale=0.5))
        sm0 = tr.accesses[tr.accesses["sm"] == 0]
        d = np.diff(sm0["page"].astype(np.int64))
        _, counts = np.unique(d, return_counts=True)
        assert counts.max() / counts.sum() > 0.9, name


def test_tlb_filter_drops_repeats():
    # single-kernel benchmark: the TLB flushes between kernel launches, so
    # uniqueness under an infinite window only holds within one kernel
    cfg = GPUModelConfig(tlb_window=10_000_000)
    tr = GPUModel(cfg).run(generate_benchmark("AddVectors", scale=0.1))
    for sm in range(4):
        pages = tr.accesses[tr.accesses["sm"] == sm]["page"]
        assert len(np.unique(pages)) == len(pages)


def test_split():
    tr = GPUModel().run(generate_benchmark("ATAX", scale=0.2))
    a, b = tr.split(0.8)
    assert len(a) + len(b) == len(tr)
    assert abs(len(a) - 0.8 * len(tr)) <= 1
